"""Paper Fig. 9 / App. D: retrieval stability during streaming generation.

We decode step-by-step against a drifting query stream with lazy updates
active and report the two paper metrics: step-to-step Jaccard similarity of
the retrieved cluster sets (Eqn. 3) and the window hit rate (Eqn. 4, w=32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import build_lychee, coherent_keys, emit, \
    structured_tokens
from repro.configs.base import LycheeConfig
from repro.core import retrieve
from repro.core.update import maybe_lazy_update


def run():
    rng = np.random.default_rng(6)
    N, d, steps, w = 4096, 64, 256, 32
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, sink=0, buffer_size=0,
                       budget=256, top_kg=8, max_coarse=32)
    keys0 = coherent_keys(rng, N, d)
    tokens = structured_tokens(rng, N)
    index, _ = build_lychee(keys0, tokens, cfg)

    # growing cache for lazy updates
    cap = N + steps + 16
    keys = jnp.concatenate(
        [keys0, jnp.zeros((1, cap - N, d), jnp.float32)], axis=1)

    retr = jax.jit(lambda idx, pb: retrieve(idx, pb, cfg))
    upd = jax.jit(lambda idx, kk, t: maybe_lazy_update(idx, kk, t, cfg))

    # drifting query: slow random walk through the semantic space
    q = np.asarray(keys0[0, rng.integers(0, N)]).copy()
    hist, jac, hits = [], [], []
    for t in range(steps):
        q = 0.95 * q + 0.35 * rng.standard_normal(d)
        ret = retr(index, jnp.asarray(q, jnp.float32)[None])
        cur = set(np.asarray(ret.fine_ids[0])[
            np.asarray(ret.fine_mask[0])].tolist())
        if hist:
            prev = hist[-1]
            jac.append(len(cur & prev) / max(len(cur | prev), 1))
            recent = set().union(*hist[-w:])
            hits.append(len(cur & recent) / max(len(cur), 1))
        hist.append(cur)
        # generated token's key lands near the current topic
        new_key = jnp.asarray(q + rng.standard_normal(d) * 0.3,
                              jnp.float32)
        keys = keys.at[0, N + t].set(new_key)
        index = upd(index, keys, N + t + 1)

    return emit([
        {"metric": "jaccard_mean", "value": float(np.mean(jac))},
        {"metric": "jaccard_last50", "value": float(np.mean(jac[-50:]))},
        {"metric": "window_hit_mean", "value": float(np.mean(hits))},
        {"metric": "window_hit_last50", "value": float(np.mean(hits[-50:]))},
        {"metric": "steps", "value": steps},
    ], "stability_fig9")
