"""Serving throughput: continuous vs static batching on a mixed-length trace.

Replays ONE request trace (prompt lengths drawn from {64, 256, 1024},
mixed generation budgets) through the same engine twice:

* **static** — lock-step waves: admission only when every slot is free, so
  a finished slot idles until the slowest request of its wave drains;
* **continuous** — a freed slot immediately admits the next FIFO request
  via the per-slot prefill splice (``model.prefill_into_slot``).

Reports tokens/s, p50/p99 request latency and decode-step counts for both,
checks the per-request greedy outputs are IDENTICAL across modes (decode is
per-slot independent; prefill is per-request at natural length), and prints
the throughput speedup. ``--policy`` runs the gate under any registered
cache policy (lychee | quest | clusterkv | streaming | dense) — the
continuous-batching win is policy-independent. Both runs follow a warmup trace so jit compilation
(one prefill specialisation per prompt length + the decode step) is paid
before any timer starts.

Run:  PYTHONPATH=src python benchmarks/throughput.py --reduced
"""
from __future__ import annotations

import argparse
import copy
import json
import platform

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, LycheeConfig, get_config
from repro.core.policy import list_policies
from repro.models import model as MD
from repro.serving import Engine, Request, make_trace


def build_engine(args):
    policy = "dense" if args.no_lychee else args.policy
    lychee = LycheeConfig(policy=policy, enabled=policy != "dense",
                          budget=args.budget, sink=16, buffer_size=64,
                          max_coarse=32, top_kg=8, full_attn_layers=0)
    cfg = get_config(args.arch, reduced=args.reduced).replace(
        dtype="float32", lychee=lychee)
    if args.paged:
        cfg = cfg.replace(serving=cfg.serving.replace(paged=True))
    params = MD.init_model(jax.random.key(0), cfg)
    n_cache = max(args.prompt_lens) + max(args.gen_lens) + 32
    return cfg, Engine(cfg, params, n_cache=n_cache, donate_state=True)


def run(engine, trace, mode, n_slots):
    return engine.serve(copy.deepcopy(trace), n_slots=n_slots, mode=mode)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced config (CPU-sized); --no-reduced for full")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-lens", type=int, nargs="+",
                    default=[64, 256, 1024])
    ap.add_argument("--gen-lens", type=int, nargs="+", default=[8, 96])
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--policy", default="lychee",
                    choices=list(list_policies()),
                    help="cache policy the continuous-vs-static gate "
                         "runs under")
    ap.add_argument("--no-lychee", action="store_true",
                    help="legacy alias for --policy dense")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (+ prefix cache); "
                         "pool stats land in the JSON artifact")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist the static/continuous numbers as a JSON "
                         "artifact (perf-trajectory record)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg, engine = build_engine(args)
    rng = np.random.default_rng(args.seed)
    trace = make_trace(rng, args.requests, cfg.vocab,
                       prompt_lens=args.prompt_lens, gen_lens=args.gen_lens)
    n_prompt = sum(r.prompt_len for r in trace)
    print(f"[throughput] {cfg.name} | policy={engine.policy} "
          f"slots={args.slots} "
          f"requests={args.requests} prompts={sorted(set(args.prompt_lens))} "
          f"gens={sorted(set(args.gen_lens))} "
          f"({n_prompt} prompt tokens total)")

    # warmup: one request PER prompt length compiles every prefill
    # specialisation + the decode step before any timed run
    wrng = np.random.default_rng(1)
    warm = [Request(uid=i,
                    prompt=wrng.integers(0, cfg.vocab, size=(S,))
                    .astype(np.int32), max_new=2)
            for i, S in enumerate(args.prompt_lens)]
    run(engine, warm, "continuous", args.slots)

    results = {m: run(engine, trace, m, args.slots)
               for m in ("static", "continuous")}

    for m, r in results.items():
        print(f"  {m:10s}: {r.tokens_per_s:8.1f} tok/s   "
              f"steps {r.n_steps:4d}   p50 {r.p50_latency_s:6.2f}s   "
              f"p99 {r.p99_latency_s:6.2f}s   ttft {r.mean_ttft_s:5.2f}s")

    mismatched = [uid for uid in results["static"].requests
                  if results["static"].requests[uid].tokens
                  != results["continuous"].requests[uid].tokens]
    identical = not mismatched
    speedup = (results["continuous"].tokens_per_s
               / results["static"].tokens_per_s)
    print(f"  greedy outputs identical across modes: {identical}"
          + (f" (mismatch: {mismatched})" if mismatched else ""))
    print(f"  continuous vs static speedup: {speedup:.2f}x tokens/s")
    if args.json:
        payload = {
            "benchmark": "throughput",
            "arch": cfg.name,
            "policy": engine.policy,
            "backend": jax.default_backend(),
            "host": platform.platform(),
            "jax": jax.__version__,
            "args": {k: v for k, v in vars(args).items() if k != "json"},
            "identical": identical,
            "speedup": speedup,
            "modes": {m: {"tokens_per_s": r.tokens_per_s,
                          "decode_s": r.decode_s, "n_steps": r.n_steps,
                          "tpot_ms": 1e3 * r.decode_s / max(r.n_steps, 1),
                          "p50_s": r.p50_latency_s, "p99_s": r.p99_latency_s,
                          "ttft_s": r.mean_ttft_s,
                          "pool": r.pool.to_dict() if r.pool else None,
                          "metrics": r.metrics.to_dict()
                          if r.metrics else None}
                      for m, r in results.items()},
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {args.json}")
    if not identical:
        raise SystemExit("FAIL: outputs differ between modes")
    if speedup < 1.2:
        raise SystemExit(f"FAIL: speedup {speedup:.2f}x < 1.2x")


if __name__ == "__main__":
    main()
