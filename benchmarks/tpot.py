"""Paper Fig. 4: per-step decode cost vs context length.

Full attention scans the whole cache every token (linear growth);
LycheeCluster's cost is bounded by the budget. We time the decode-attention
operator (the component the paper's speedup comes from) at growing context
lengths on CPU, for the dense reference and for the ``lychee`` and
``clusterkv`` cache policies — both driven through the same
:class:`~repro.core.policy.CachePolicy` select interface (ClusterKV's
token-granular scoring is the paper's ~3.5× selection-cost comparison
point). Absolute milliseconds are CPU numbers; the shape of the curves
(linear vs flat) is the reproduced claim, and the TPU-side magnitude comes
from §Roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (coherent_keys, emit, structured_tokens,
                               timeit)
from repro.configs.base import LycheeConfig
from repro.core import (chunk_sequence, full_decode_attention,
                        synthetic_delimiter_table)
from repro.core.attention import sparse_decode_attention
from repro.core.policy import make_policy, spans_to_tokens


def run():
    rng = np.random.default_rng(4)
    d, H, G = 64, 4, 4
    budget = 512
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, sink=16, buffer_size=64,
                       budget=budget, top_kg=8, max_coarse=32)
    table = jnp.asarray(synthetic_delimiter_table(997))
    rows = []
    for N in (2048, 4096, 8192, 16384):
        keys = coherent_keys(rng, N, d, H=H)
        values = jnp.asarray(rng.standard_normal((H, N, d)), jnp.float32)
        tokens = structured_tokens(rng, N)
        layout = chunk_sequence(tokens, table, cfg)
        pols = {m: make_policy(m, cfg) for m in ("lychee", "clusterkv")}
        states = {m: p.build(keys, layout if p.needs_layout else None, N)
                  for m, p in pols.items()}
        q = jnp.asarray(rng.standard_normal((H * G, d)), jnp.float32)
        probe = q.reshape(H, G, d).mean(1)

        full_fn = jax.jit(lambda qq, kk, vv: full_decode_attention(
            qq, kk, vv, N, d ** -0.5))
        t_full = timeit(full_fn, q, keys, values)

        t_pol = {}
        for m, pol in pols.items():
            state = states[m]

            @jax.jit
            def pol_fn(qq, pb, kk, vv, pol=pol, state=state):
                ti, tm = spans_to_tokens(*pol.select(state, pb, N),
                                         pol.span_len)
                return sparse_decode_attention(qq, kk, vv, ti, tm, N, cfg,
                                               d ** -0.5)
            t_pol[m] = timeit(pol_fn, q, probe, keys, values)

        rows.append({"context": N, "full_ms": t_full,
                     "lychee_ms": t_pol["lychee"],
                     "clusterkv_ms": t_pol["clusterkv"],
                     "speedup_vs_full": t_full / t_pol["lychee"]})
    return emit(rows, "tpot_fig4")
