"""Paper Fig. 4: per-step decode cost vs context length.

Full attention scans the whole cache every token (linear growth);
LycheeCluster's cost is bounded by the budget. We time the decode-attention
operator (the component the paper's speedup comes from) at growing context
lengths on CPU, plus ClusterKV-style selection for comparison. Absolute
milliseconds are CPU numbers; the shape of the curves (linear vs flat) is
the reproduced claim, and the TPU-side magnitude comes from §Roofline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (build_lychee, coherent_keys, emit,
                               structured_tokens, timeit)
from repro.configs.base import LycheeConfig
from repro.core import full_decode_attention, retrieve
from repro.core.attention import sparse_decode_attention
from repro.core.baselines import build_clusterkv, clusterkv_select


def run():
    rng = np.random.default_rng(4)
    d, H, G = 64, 4, 4
    budget = 512
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, sink=16, buffer_size=64,
                       budget=budget, top_kg=8, max_coarse=32)
    rows = []
    for N in (2048, 4096, 8192, 16384):
        keys = coherent_keys(rng, N, d, H=H)
        values = jnp.asarray(rng.standard_normal((H, N, d)), jnp.float32)
        tokens = structured_tokens(rng, N)
        index, _ = build_lychee(keys, tokens, cfg)
        cidx = build_clusterkv(keys, tokens_per_cluster=32, iters=4)
        q = jnp.asarray(rng.standard_normal((H * G, d)), jnp.float32)
        probe = q.reshape(H, G, d).mean(1)

        full_fn = jax.jit(lambda qq, kk, vv: full_decode_attention(
            qq, kk, vv, N, d ** -0.5))
        t_full = timeit(full_fn, q, keys, values)

        @jax.jit
        def lychee_fn(qq, pb, kk, vv):
            ret = retrieve(index, pb, cfg)
            return sparse_decode_attention(qq, kk, vv, ret.token_idx,
                                           ret.token_mask, N, cfg, d ** -0.5)
        t_ly = timeit(lychee_fn, q, probe, keys, values)

        @jax.jit
        def ckv_fn(qq, pb, kk, vv):
            ti, tm = clusterkv_select(cidx, pb, budget)
            return sparse_decode_attention(qq, kk, vv, ti, tm, N, cfg,
                                           d ** -0.5)
        t_ckv = timeit(ckv_fn, q, probe, keys, values)

        rows.append({"context": N, "full_ms": t_full, "lychee_ms": t_ly,
                     "clusterkv_ms": t_ckv,
                     "speedup_vs_full": t_full / t_ly})
    return emit(rows, "tpot_fig4")
