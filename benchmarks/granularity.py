"""Paper Fig. 10 / App. E: clustering-granularity sensitivity.

Sweep the average number of chunks per fine cluster (1 -> 8): recall falls
monotonically as centroids coarsen, while index construction gets cheaper
(fewer centroids). Paper picks 2 as the engineering optimum."""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (coherent_keys, emit, recall_rate,
                               structured_tokens, timeit)
from repro.configs.base import LycheeConfig
from repro.core import (build_index, chunk_sequence, retrieve,
                        synthetic_delimiter_table)


def run():
    rng = np.random.default_rng(7)
    N, d = 4096, 64
    keys = coherent_keys(rng, N, d)
    tokens = structured_tokens(rng, N)
    table = jnp.asarray(synthetic_delimiter_table(997))
    rows = []
    for avg in (1, 2, 4, 8):
        cfg = LycheeConfig(min_chunk=8, max_chunk=16, sink=0, buffer_size=0,
                           budget=256, top_kg=8, max_coarse=32,
                           avg_chunks_per_cluster=avg)
        layout = chunk_sequence(tokens, table, cfg)
        build = jax.jit(lambda kk: build_index(kk, layout, cfg))
        t_build = timeit(build, keys, iters=3)
        index = build(keys)
        rs = []
        for _ in range(24):
            qi = int(rng.integers(0, N))
            q = np.asarray(keys[0, qi]) + rng.standard_normal(d) * 0.2
            qj = jnp.asarray(q, jnp.float32)
            ret = retrieve(index, qj[None], cfg)
            rs.append(recall_rate(ret.token_idx[0], ret.token_mask[0],
                                  np.asarray(keys[0]), q))
        rows.append({"chunks_per_cluster": avg,
                     "recall": float(np.mean(rs)),
                     "build_ms": t_build})
    return emit(rows, "granularity_fig10")
