"""Paper Fig. 2 (pilot study) + Fig. 6 (ablation): structure-aware vs
fixed-size chunking at IDENTICAL scoring.

Synthetic "structured text": semantic runs whose boundaries coincide with
delimiter tokens (as in JSON/code, where a record ends at a delimiter).
Fixed pages sever those runs; boundary-aware chunks don't. We hold the
entire downstream pipeline constant and swap only the segmentation, then
report the paper's Recall Rate metric.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, recall_rate
from repro.configs.base import LycheeConfig
from repro.core import (build_index, chunk_sequence, fixed_chunking,
                        retrieve)


def _aligned_corpus(rng, N, d, vocab=997, delim=3):
    """Semantic runs of RANDOM length 6..20 whose ends carry a delimiter
    token (strength set below). Returns (keys (1,N,d), tokens (N,), table)."""
    table = np.zeros(vocab, np.int32)
    table[delim] = 3
    tokens = rng.integers(8, vocab, size=N)
    modes = rng.standard_normal((64, d)) * 3.0
    keys = np.zeros((N, d), np.float32)
    pos = 0
    while pos < N:
        ln = int(rng.integers(6, 21))
        ln = min(ln, N - pos)
        m = modes[rng.integers(0, 64)]
        keys[pos:pos + ln] = m + rng.standard_normal((ln, d)) * 0.3
        tokens[pos + ln - 1] = delim
        pos += ln
    return (jnp.asarray(keys[None]), jnp.asarray(tokens, jnp.int32),
            jnp.asarray(table))


def run():
    rng = np.random.default_rng(0)
    N, d = 2048, 64
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, sink=0, buffer_size=0,
                       budget=256, top_kg=8, max_coarse=32)
    keys, tokens, table = _aligned_corpus(rng, N, d)

    lay_sa = chunk_sequence(tokens, table, cfg)
    lay_fx = fixed_chunking(N, 16, cfg)

    rows = []
    for name, lay in [("structure_aware", lay_sa), ("fixed_16", lay_fx)]:
        index = build_index(keys, lay, cfg)
        rs = []
        for _ in range(32):
            # query near one random key (the paper's retrieval probe)
            qi = int(rng.integers(0, N))
            q = np.asarray(keys[0, qi]) + rng.standard_normal(d) * 0.2
            q = jnp.asarray(q, jnp.float32)
            ret = retrieve(index, q[None], cfg)
            rs.append(recall_rate(ret.token_idx[0], ret.token_mask[0],
                                  np.asarray(keys[0]), np.asarray(q)))
        rows.append({"variant": name, "recall": float(np.mean(rs)),
                     "n_queries": 32})
    gain = rows[0]["recall"] - rows[1]["recall"]
    rows.append({"variant": "gain_structure_minus_fixed", "recall": gain,
                 "n_queries": 32})
    return emit(rows, "chunking_fig2_fig6")
