"""Paper Fig. 5: kernel-level latency breakdown.

(a) prefill: index construction on top of the forward pass (paper: 10-15%).
(b) decode step: hierarchical retrieval + lazy update + sparse attention
    (paper: retrieval small, update <1%).
Components are timed in isolation with the same inputs the composed step
uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (coherent_keys, emit,
                               structured_tokens, timeit)
from repro.configs.base import LycheeConfig
from repro.core import (build_index, chunk_sequence, retrieve,
                        synthetic_delimiter_table)
from repro.core.attention import sparse_decode_attention
from repro.core.update import maybe_lazy_update


def run():
    rng = np.random.default_rng(5)
    N, d, H, G = 8192, 64, 4, 4
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, sink=16, buffer_size=64,
                       budget=512, top_kg=8, max_coarse=32)
    keys = coherent_keys(rng, N, d, H=H)
    values = jnp.asarray(rng.standard_normal((H, N, d)), jnp.float32)
    tokens = structured_tokens(rng, N)
    table = jnp.asarray(synthetic_delimiter_table(997))

    # ---- prefill side -----------------------------------------------------
    chunk_fn = jax.jit(lambda tk: chunk_sequence(tk, table, cfg))
    layout = chunk_fn(tokens)
    t_chunk = timeit(chunk_fn, tokens, iters=3)
    build_fn = jax.jit(lambda kk: build_index(kk, layout, cfg))
    t_build = timeit(build_fn, keys, iters=3)
    # proxy for the model's prefill forward at this size: one flash pass
    from repro.models.attention import flash_attention
    q4 = jnp.asarray(rng.standard_normal((1, H * G, N, d)),
                     jnp.float32) * 0.1
    kv4 = jnp.asarray(rng.standard_normal((1, H, N, d)), jnp.float32)
    pos = jnp.arange(N, dtype=jnp.int32)
    fwd_fn = jax.jit(lambda qq, kk, vv: flash_attention(
        qq, kk, vv, q_pos=pos, k_pos=pos, causal=True, scale=d ** -0.5))
    t_fwd = timeit(fwd_fn, q4, kv4, kv4, iters=3)

    # ---- decode side --------------------------------------------------------
    index = build_fn(keys)
    q = jnp.asarray(rng.standard_normal((H * G, d)), jnp.float32)
    probe = q.reshape(H, G, d).mean(1)
    retr_fn = jax.jit(lambda pb: retrieve(index, pb, cfg))
    ret = retr_fn(probe)
    t_retr = timeit(retr_fn, probe)
    attn_fn = jax.jit(lambda qq, kk, vv: sparse_decode_attention(
        qq, kk, vv, ret.token_idx, ret.token_mask, N, cfg, d ** -0.5))
    t_attn = timeit(attn_fn, q, keys, values)
    upd_fn = jax.jit(lambda kk: maybe_lazy_update(index, kk, N + 16, cfg))
    t_upd = timeit(upd_fn, keys)

    step_total = t_retr + t_attn + t_upd
    return emit([
        {"phase": "prefill", "component": "chunking_ms", "ms": t_chunk},
        {"phase": "prefill", "component": "index_build_ms", "ms": t_build},
        {"phase": "prefill", "component": "attention_fwd_ms", "ms": t_fwd},
        {"phase": "prefill", "component": "index_frac_of_prefill",
         "ms": (t_chunk + t_build) / (t_chunk + t_build + t_fwd)},
        {"phase": "decode", "component": "retrieval_ms", "ms": t_retr},
        {"phase": "decode", "component": "sparse_attention_ms", "ms": t_attn},
        {"phase": "decode", "component": "lazy_update_ms", "ms": t_upd},
        {"phase": "decode", "component": "update_frac_of_step",
         "ms": t_upd / step_total},
    ], "breakdown_fig5")
