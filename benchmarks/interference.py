"""Prefill–decode interference: inter-token stall on busy decode slots
while one LONG (>=4k) prompt admits — chunked vs monolithic admission.

This is the tentpole measurement of the chunked-prefill state machine
(``cfg.serving.prefill_chunk``): with monolithic admission every live
decode slot stalls for the ENTIRE long-prompt prefill (the gap between two
consecutive tokens of a busy slot equals the whole prefill), while chunked
admission interleaves one batched decode step between chunks, so the worst
stall is one chunk forward (plus, in the default ``chunk_state="rebuild"``
mode, one end-of-admission policy build). Greedy outputs are token-
identical between the two modes — the rebuild mode reproduces the
monolithic policy-state build bit-for-bit from the chunk-streamed cache —
so the comparison isolates SCHEDULING, not selection quality.

Trace: ``--busy`` short requests admit first and keep decoding; then one
``--long``-token request admits into the last slot. The reported stall is
the max / p99 inter-token gap (``Turn.itl_ms``) across the busy slots.

``--check`` (the acceptance gate) asserts, on the same trace and policy:
  * max busy-slot stall reduced >= --min-stall-reduction (default 5x);
  * chunked greedy tokens identical to monolithic for every session;
  * total trace tokens/s within --tps-tolerance of monolithic.

Run:  PYTHONPATH=src python benchmarks/interference.py --reduced --check
"""
from __future__ import annotations

import argparse
import json
import platform

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, LycheeConfig, get_config
from repro.core.policy import list_policies
from repro.models import model as MD
from repro.serving import Engine, Request


def make_trace(rng, vocab, n_busy, busy_prompt, busy_gen, long_s, long_gen):
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, vocab, size=(busy_prompt,))
                    .astype(np.int32), max_new=busy_gen)
            for i in range(n_busy)]
    reqs.append(Request(uid=n_busy,
                        prompt=rng.integers(0, vocab, size=(long_s,))
                        .astype(np.int32), max_new=long_gen))
    return reqs


def run_mode(engine, trace_factory, n_slots, n_busy):
    res = engine.serve(trace_factory(), n_slots=n_slots)
    busy_gaps = [g for uid in range(n_busy)
                 for t in res.requests[uid].turns for g in t.itl_ms]
    long_sess = res.requests[n_busy]
    return {
        "max_stall_ms": max(busy_gaps) if busy_gaps else 0.0,
        "p99_stall_ms": float(np.percentile(busy_gaps, 99))
        if busy_gaps else 0.0,
        "mean_busy_tpot_ms": float(np.mean(
            [t.tpot_ms for uid in range(n_busy)
             for t in res.requests[uid].turns if t.tpot_ms is not None])),
        "long_ttft_ms": 1e3 * long_sess.turns[0].ttft_s,
        "tokens_per_s": res.tokens_per_s,
        "wall_s": res.wall_s,
        "n_steps": res.n_steps,
        "pool": res.pool.to_dict() if res.pool else None,
        "metrics": res.metrics.to_dict() if res.metrics else None,
    }, {uid: s.tokens for uid, s in res.requests.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--policy", default="lychee",
                    choices=list(list_policies()))
    ap.add_argument("--long", type=int, default=4096,
                    help="long admission prompt length (>=4k is the claim)")
    ap.add_argument("--long-gen", type=int, default=8)
    ap.add_argument("--busy", type=int, default=3,
                    help="busy decode slots the admission interferes with")
    ap.add_argument("--busy-prompt", type=int, default=64)
    ap.add_argument("--busy-gen", type=int, default=0,
                    help="0 -> auto: enough tokens to decode through the "
                         "whole admission in both modes")
    ap.add_argument("--chunk", type=int, default=256,
                    help="prefill chunk for the chunked mode (256 keeps "
                         "the worst per-chunk stall comfortably under the "
                         "5x gate on CPU hosts; TPU deployments can afford "
                         "larger chunks)")
    ap.add_argument("--chunk-state", default="rebuild",
                    choices=("rebuild", "stream"))
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--repeat", type=int, default=3,
                    help="timed repeats per mode (best max-stall kept)")
    ap.add_argument("--min-stall-reduction", type=float, default=5.0)
    ap.add_argument("--tps-tolerance", type=float, default=0.35,
                    help="allowed tokens/s regression vs monolithic "
                         "(CPU hosts are noisy; the claim is the stall)")
    ap.add_argument("--check", action="store_true",
                    help="assert stall reduction, token identity and "
                         "throughput non-regression")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    busy_gen = args.busy_gen or (args.long // max(args.chunk, 1) + 24)
    lychee = LycheeConfig(policy=args.policy,
                          enabled=args.policy != "dense",
                          budget=args.budget, sink=16, buffer_size=64,
                          max_coarse=32, top_kg=8, full_attn_layers=0)
    base = get_config(args.arch, reduced=args.reduced).replace(
        dtype="float32", lychee=lychee)
    params = MD.init_model(jax.random.key(0), base)
    n_cache = args.long + args.long_gen + 64
    n_slots = args.busy + 1

    def factory():
        rng = np.random.default_rng(args.seed)
        return make_trace(rng, base.vocab, args.busy, args.busy_prompt,
                          busy_gen, args.long, args.long_gen)

    print(f"[interference] {base.name} | policy={args.policy} "
          f"long={args.long} chunk={args.chunk} ({args.chunk_state}) "
          f"busy={args.busy}x(S={args.busy_prompt}, gen={busy_gen})")

    rows = {}
    tokens = {}
    for mode, chunk in (("monolithic", 0), ("chunked", args.chunk)):
        cfg = base.replace(serving=base.serving.replace(
            prefill_chunk=chunk, chunk_state=args.chunk_state))
        engine = Engine(cfg, params, n_cache=n_cache, donate_state=True)
        run_mode(engine, factory, n_slots, args.busy)     # jit warmup
        best = None
        for _ in range(args.repeat):
            row, toks = run_mode(engine, factory, n_slots, args.busy)
            tokens[mode] = toks
            if best is None or row["max_stall_ms"] < best["max_stall_ms"]:
                best = row
        rows[mode] = best
        print(f"  {mode:10s} max stall {best['max_stall_ms']:8.1f}ms  "
              f"p99 {best['p99_stall_ms']:8.1f}ms  "
              f"busy TPOT {best['mean_busy_tpot_ms']:6.1f}ms  "
              f"long TTFT {best['long_ttft_ms']:7.1f}ms  "
              f"{best['tokens_per_s']:6.1f} tok/s")

    reduction = rows["monolithic"]["max_stall_ms"] / max(
        rows["chunked"]["max_stall_ms"], 1e-9)
    p99_reduction = rows["monolithic"]["p99_stall_ms"] / max(
        rows["chunked"]["p99_stall_ms"], 1e-9)
    identical = tokens["chunked"] == tokens["monolithic"]
    tps_ratio = rows["chunked"]["tokens_per_s"] / max(
        rows["monolithic"]["tokens_per_s"], 1e-9)
    print(f"  => max-stall reduction {reduction:.1f}x  "
          f"(p99 {p99_reduction:.1f}x)  tokens identical: {identical}  "
          f"tok/s ratio {tps_ratio:.2f}")

    failures = []
    if args.check:
        if reduction < args.min_stall_reduction:
            failures.append(f"max stall reduced only {reduction:.1f}x "
                            f"(< {args.min_stall_reduction}x)")
        if not identical:
            failures.append("chunked tokens != monolithic tokens")
        if tps_ratio < 1.0 - args.tps_tolerance:
            failures.append(f"tokens/s regressed to {tps_ratio:.2f}x")

    if args.json:
        payload = {
            "benchmark": "interference",
            "arch": base.name,
            "backend": jax.default_backend(),
            "host": platform.platform(),
            "jax": jax.__version__,
            "args": {k: v for k, v in vars(args).items() if k != "json"},
            "busy_gen": busy_gen,
            "checked": bool(args.check),
            "rows": rows,
            "max_stall_reduction": reduction,
            "p99_stall_reduction": p99_reduction,
            "tokens_identical": identical,
            "tokens_per_s_ratio": tps_ratio,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {args.json}")
    if failures:
        raise SystemExit("FAIL: " + "; ".join(failures))
    return rows


if __name__ == "__main__":
    main()
