"""Shared benchmark utilities: synthetic caches with controllable local
coherence, recall metric (paper Table 3 definition), and timing helpers.

All benchmarks run on CPU with small dimensions; they reproduce the paper's
*mechanisms and orderings* (which method recalls more, how overheads decompose,
how memory scales) rather than its absolute H20 wall-clock numbers — the
absolute-performance analysis for the TPU target lives in the §Roofline
dry-run pipeline.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LycheeConfig
from repro.core import (build_index, chunk_sequence,
                        synthetic_delimiter_table)


def coherent_keys(rng, N: int, d: int, H: int = 1, n_modes: int = 32,
                  run_len: int = 24, noise: float = 0.3) -> jnp.ndarray:
    """Key cache with paper-premise local coherence: contiguous runs share a
    semantic direction."""
    modes = rng.standard_normal((n_modes, d)) * 3.0
    ids = np.repeat(rng.integers(0, n_modes, size=N // run_len + 1),
                    run_len)[:N]
    keys = modes[ids] + rng.standard_normal((N, d)) * noise
    return jnp.asarray(np.broadcast_to(keys, (H, N, d)).copy(), jnp.float32)


def structured_tokens(rng, N: int, vocab: int = 997) -> jnp.ndarray:
    """Token stream with delimiter statistics of structured text."""
    return jnp.asarray(rng.integers(0, vocab, size=(N,)), jnp.int32)


def recall_rate(token_idx, token_mask, keys_h, q, k_truth: int = 64) -> float:
    """Paper Table 3 metric: fraction of the ground-truth top-k attention
    tokens (by exact dot product) retrieved within the budget."""
    scores = np.asarray(keys_h @ q)
    truth = set(np.argsort(-scores)[:k_truth].tolist())
    got = set(np.asarray(token_idx)[np.asarray(token_mask)].tolist())
    return len(got & truth) / k_truth


def build_lychee(keys, tokens, cfg: LycheeConfig, vocab: int = 997):
    table = jnp.asarray(synthetic_delimiter_table(vocab))
    layout = chunk_sequence(tokens, table, cfg)
    return build_index(keys, layout, cfg), layout


def timeit(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-time per call in milliseconds (jit-warmed)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return 1e3 * float(np.median(ts))


def emit(rows: List[Dict], name: str) -> List[Dict]:
    for r in rows:
        r["bench"] = name
    return rows
