"""Paper Table 1 proxy: selection-policy comparison at a fixed budget.

LongBench V2 accuracy cannot be reproduced offline (no pretrained LLM);
what CAN be isolated is the retrieval layer every method differs in. We
compare the registered cache policies — LycheeCluster vs Quest (fixed
pages, min-max scoring) vs ClusterKV (token-granular clusters) vs a
StreamingLLM-style recency window — with the paper's Recall Rate metric,
on the paper's hard case: VARIABLE-length semantic units (6–20 tokens,
like JSON records/code statements) whose boundaries do NOT align with any
fixed page grid. A TIGHT budget makes fragmentation costly: Quest wastes
budget on page halves that straddle two units; ClusterKV scatters a unit's
tokens across clusters. The secondary axis the paper argues (Fig. 4) —
selection cost — is measured in the tpot bench.

All four methods go through the SAME :class:`~repro.core.policy.CachePolicy`
interface (``build`` + ``select`` → spans → tokens) — no per-method wiring.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.chunking import _aligned_corpus
from benchmarks.common import emit, recall_rate
from repro.configs.base import LycheeConfig
from repro.core import chunk_sequence
from repro.core.attention import assemble_spans
from repro.core.policy import make_policy, spans_to_tokens


def run():
    rng = np.random.default_rng(3)
    N, d = 4096, 64
    budget = 192                      # tight: fragmentation is punished
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, sink=0, buffer_size=0,
                       budget=budget, top_kg=8, max_coarse=32,
                       quest_page=16, ckv_tokens_per_cluster=16)
    keys, tokens, table = _aligned_corpus(rng, N, d)
    layout = chunk_sequence(tokens, table, cfg)

    pols = {m: make_policy(m, cfg) for m in ("lychee", "quest", "clusterkv")}
    states = {m: p.build(keys, layout if p.needs_layout else None, N)
              for m, p in pols.items()}
    # StreamingLLM-style window baseline: the streaming policy selects
    # nothing, so its active set is exactly the assemble_spans recent
    # buffer — sized to the same budget for a fair row.
    wcfg = cfg.replace(buffer_size=budget, sink=0)
    wpol = make_policy("streaming", wcfg)

    rows = {m: [] for m in (*pols, "window")}
    neff = {m: [] for m in rows}
    for _ in range(32):
        qi = int(rng.integers(0, N))
        q = np.asarray(keys[0, qi]) + rng.standard_normal(d) * 0.2
        qj = jnp.asarray(q, jnp.float32)
        kh, qn = np.asarray(keys[0]), np.asarray(qj)

        for m, pol in pols.items():
            ti, tm = spans_to_tokens(*pol.select(states[m], qj[None], N),
                                     pol.span_len)
            rows[m].append(recall_rate(ti[0], tm[0], kh, qn))
            neff[m].append(int(tm.sum()))
        s, ln = wpol.select(None, qj[None], N)
        starts, lens = assemble_spans(s, ln, N, wcfg,
                                      max_chunk=wpol.span_len)
        ti, tm = spans_to_tokens(starts, lens, wpol.span_len)
        rows["window"].append(recall_rate(ti[0], tm[0], kh, qn))
        neff["window"].append(int(tm.sum()))
    out = [{"method": m, "recall": float(np.mean(v)), "budget": budget,
            "effective_tokens": float(np.mean(neff[m]))}
           for m, v in rows.items()]
    return emit(out, "retrieval_quality_tab1")
