"""Paper Table 1 proxy: selection-policy comparison at a fixed budget.

LongBench V2 accuracy cannot be reproduced offline (no pretrained LLM);
what CAN be isolated is the retrieval layer every method differs in. We
compare LycheeCluster vs Quest (fixed pages, min-max scoring) vs ClusterKV
(token-granular clusters) vs StreamingLLM (window only) with the paper's
Recall Rate metric, on the paper's hard case: VARIABLE-length semantic
units (6–20 tokens, like JSON records/code statements) whose boundaries do
NOT align with any fixed page grid. A TIGHT budget makes fragmentation
costly: Quest wastes budget on page halves that straddle two units;
ClusterKV scatters a unit's tokens across clusters. The secondary axis the
paper argues (Fig. 4) — selection cost — is measured in the tpot bench,
where ClusterKV's token-granular scoring is ~3.5× slower than Lychee's
two-level pruning.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.chunking import _aligned_corpus
from benchmarks.common import emit, recall_rate
from repro.configs.base import LycheeConfig
from repro.core import build_index, chunk_sequence, retrieve
from repro.core.baselines import (build_clusterkv, build_quest,
                                  clusterkv_select, quest_select)


def run():
    rng = np.random.default_rng(3)
    N, d = 4096, 64
    budget = 192                      # tight: fragmentation is punished
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, sink=0, buffer_size=0,
                       budget=budget, top_kg=8, max_coarse=32)
    keys, tokens, table = _aligned_corpus(rng, N, d)
    layout = chunk_sequence(tokens, table, cfg)
    index = build_index(keys, layout, cfg)
    qidx = build_quest(keys, page=16)
    cidx = build_clusterkv(keys, tokens_per_cluster=16)

    rows = {"lychee": [], "quest": [], "clusterkv": [], "window": []}
    neff = {m: [] for m in rows}
    for _ in range(32):
        qi = int(rng.integers(0, N))
        q = np.asarray(keys[0, qi]) + rng.standard_normal(d) * 0.2
        qj = jnp.asarray(q, jnp.float32)
        kh, qn = np.asarray(keys[0]), np.asarray(qj)

        ret = retrieve(index, qj[None], cfg)
        rows["lychee"].append(recall_rate(ret.token_idx[0],
                                          ret.token_mask[0], kh, qn))
        neff["lychee"].append(int(ret.token_mask.sum()))
        ti, tm = quest_select(qidx, qj[None], budget)
        rows["quest"].append(recall_rate(ti[0], tm[0], kh, qn))
        neff["quest"].append(int(tm.sum()))
        ti, tm = clusterkv_select(cidx, qj[None], budget,
                                  tokens_per_cluster=16)
        rows["clusterkv"].append(recall_rate(ti[0], tm[0], kh, qn))
        neff["clusterkv"].append(int(tm.sum()))
        wi = jnp.arange(N - budget, N)
        rows["window"].append(recall_rate(wi, jnp.ones(budget, bool),
                                          kh, qn))
        neff["window"].append(budget)
    out = [{"method": m, "recall": float(np.mean(v)), "budget": budget,
            "effective_tokens": float(np.mean(neff[m]))}
           for m, v in rows.items()]
    return emit(out, "retrieval_quality_tab1")
