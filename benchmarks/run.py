"""Benchmark harness entry point: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only <name>]`` prints a CSV of
every row and writes experiments/bench/<bench>.json. The roofline numbers
(the TPU-side performance report) come from ``repro.launch.dryrun`` +
``benchmarks.roofline`` instead — this harness covers the paper's
algorithmic tables/figures on CPU.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback

BENCHES = [
    "chunking",           # Fig. 2 pilot + Fig. 6 ablation
    "pooling",            # Table 3
    "budget",             # Fig. 7
    "retrieval_quality",  # Table 1 proxy (selection policies)
    "tpot",               # Fig. 4
    "breakdown",          # Fig. 5
    "memory",             # Fig. 8 / App. C
    "stability",          # Fig. 9 / App. D
    "granularity",        # Fig. 10 / App. E
    "ruler_proxy",        # Table 6 / Table 1 end-task proxy
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = [args.only] if args.only else BENCHES
    failures = []
    print("bench,key,value")
    for name in names:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            rows = mod.run()
        except Exception as e:      # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
            continue
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump(rows, f, indent=1, default=float)
        for r in rows:
            items = [f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                     for k, v in r.items() if k != "bench"]
            print(f"{name},{','.join(items)}")
        print(f"# {name}: {time.time() - t0:.1f}s")
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
