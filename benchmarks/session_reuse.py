"""Multi-turn KV/index reuse: turn-2 TTFT via ``extend_slot`` vs re-prefill.

The paper's lazy-update claim ("supports efficient streaming generation")
applied across turns: a follow-up turn should pay only for its prompt DELTA
— the slot's KV rows are reused and every cache policy's selection state is
extended through its streaming-update path (lychee lazy-grafts dynamic
chunks, quest extends tail pages, clusterkv assigns to nearest centroids) —
instead of re-running the full-history prefill + index rebuild that flat-
rebuild baselines (ClusterKV et al.) pay on every turn.

For each policy this benchmark replays the SAME two-turn session twice
through the engine — once with ``reuse="extend"`` and once with
``reuse="reprefill"`` — and reports the turn-2 TTFT (first token of turn 2
relative to the turn's start: the extend/prefill dispatch plus the first
sample) and the resulting speedup. Greedy turn-2 token identity between the
two paths is reported per policy; for the state-free policies (dense,
streaming) identity is REQUIRED (their selection cannot depend on how the
state was built), and ``--check`` additionally requires extend to be
strictly faster than re-prefill for every policy — the acceptance gate.

Run:  PYTHONPATH=src python benchmarks/session_reuse.py --reduced
"""
from __future__ import annotations

import argparse
import json
import platform

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, LycheeConfig, get_config
from repro.core.policy import list_policies
from repro.models import model as MD
from repro.serving import Engine, Session, Turn


def two_turn_session(rng, vocab, history, delta, gen1, gen2) -> Session:
    return Session(uid=0, turns=[
        Turn(prompt=rng.integers(0, vocab, size=(history,))
             .astype(np.int32), max_new=gen1),
        Turn(prompt=rng.integers(0, vocab, size=(delta,))
             .astype(np.int32), max_new=gen2)])


def run_once(engine, sess_factory, reuse):
    res = engine.serve([sess_factory()], n_slots=1, reuse=reuse)
    sess = res.requests[0]
    return (sess.turns[1].ttft_s, [t.tokens for t in sess.turns],
            res.pool, res.metrics)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--policies", default=",".join(list_policies()),
                    help="comma-separated subset of the policy registry")
    ap.add_argument("--history", type=int, default=1024,
                    help="turn-1 prompt length (the reused history)")
    ap.add_argument("--delta", type=int, default=64,
                    help="turn-2 prompt delta length")
    ap.add_argument("--gen1", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16,
                    help="turn-2 generation budget")
    ap.add_argument("--repeat", type=int, default=3,
                    help="timed repeats per path (min is reported)")
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--check", action="store_true",
                    help="assert extend TTFT < re-prefill TTFT per policy "
                         "(and token identity for the state-free policies)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist the per-policy table (+ run metadata) as "
                         "a JSON artifact — the perf-trajectory record CI "
                         "uploads per PR")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    policies = [p.strip() for p in args.policies.split(",") if p.strip()]
    unknown = set(policies) - set(list_policies())
    if unknown:
        raise SystemExit(f"unknown policies {sorted(unknown)}; "
                         f"registry has {list(list_policies())}")

    cfg0 = get_config(args.arch, reduced=args.reduced).replace(
        dtype="float32")
    params = MD.init_model(jax.random.key(0), cfg0)
    n_cache = args.history + args.delta + args.gen + 64
    print(f"[session_reuse] {cfg0.name} | history={args.history} "
          f"delta={args.delta} gen2={args.gen} budget={args.budget} "
          f"policies={policies}")

    rows = []
    failures = []
    for policy in policies:
        lychee = LycheeConfig(policy=policy, enabled=policy != "dense",
                              budget=args.budget, sink=16, buffer_size=64,
                              max_coarse=32, top_kg=8, full_attn_layers=0)
        engine = Engine(cfg0.replace(lychee=lychee), params,
                        n_cache=n_cache, donate_state=True)
        rng0 = np.random.default_rng(args.seed)
        prompts = (rng0.integers(0, cfg0.vocab, size=(args.history,)),
                   rng0.integers(0, cfg0.vocab, size=(args.delta,)))

        def factory():
            return Session(uid=0, turns=[
                Turn(prompt=prompts[0].astype(np.int32), max_new=args.gen1),
                Turn(prompt=prompts[1].astype(np.int32), max_new=args.gen)])

        # warmup pays jit for BOTH admission primitives (history-length
        # prefill, delta-length extend, concatenated-history re-prefill)
        # and the decode step
        for reuse in ("extend", "reprefill"):
            run_once(engine, factory, reuse)

        timings = {}
        tokens = {}
        pool = None
        for reuse in ("extend", "reprefill"):
            best = None
            for _ in range(args.repeat):
                ttft2, toks, pool, metrics = run_once(engine, factory,
                                                      reuse)
                best = ttft2 if best is None else min(best, ttft2)
                tokens[reuse] = toks
            timings[reuse] = best
        identical = tokens["extend"][1] == tokens["reprefill"][1]
        assert tokens["extend"][0] == tokens["reprefill"][0], \
            f"[{policy}] turn-1 must be identical (same prefill)"
        speedup = timings["reprefill"] / max(timings["extend"], 1e-9)
        rows.append({"policy": policy,
                     "ttft2_extend_ms": 1e3 * timings["extend"],
                     "ttft2_reprefill_ms": 1e3 * timings["reprefill"],
                     "speedup": speedup,
                     "turn2_identical": identical,
                     "pool": pool.to_dict() if pool else None,
                     "metrics": metrics.to_dict() if metrics else None})
        if args.check:
            if timings["extend"] >= timings["reprefill"]:
                failures.append(f"{policy}: extend TTFT "
                                f"{1e3 * timings['extend']:.1f}ms not below "
                                f"re-prefill "
                                f"{1e3 * timings['reprefill']:.1f}ms")
            if policy in ("dense", "streaming") and not identical:
                failures.append(f"{policy}: state-free policy diverged "
                                f"between extend and re-prefill")

    print(f"\n  {'policy':10s} {'extend ms':>10s} {'reprefill ms':>13s} "
          f"{'speedup':>8s} {'turn2 ==':>9s}")
    for r in rows:
        print(f"  {r['policy']:10s} {r['ttft2_extend_ms']:10.1f} "
              f"{r['ttft2_reprefill_ms']:13.1f} {r['speedup']:7.2f}x "
              f"{str(r['turn2_identical']):>9s}")

    if args.json:
        payload = {
            "benchmark": "session_reuse",
            "arch": cfg0.name,
            "backend": jax.default_backend(),
            "host": platform.platform(),
            "jax": jax.__version__,
            "args": {k: v for k, v in vars(args).items() if k != "json"},
            "checked": bool(args.check),
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {args.json}")
    if failures:
        raise SystemExit("FAIL: " + "; ".join(failures))
    return rows


if __name__ == "__main__":
    main()
