"""Paper Fig. 7: recall vs token budget (256 -> 2048). Accuracy saturates
once the budget covers the relevant region — we reproduce the saturating
recall curve."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (build_lychee, coherent_keys, emit,
                               recall_rate, structured_tokens)
from repro.configs.base import LycheeConfig
from repro.core import retrieve


def run():
    rng = np.random.default_rng(2)
    N, d = 4096, 64
    keys = coherent_keys(rng, N, d)
    tokens = structured_tokens(rng, N)
    base = LycheeConfig(min_chunk=8, max_chunk=16, sink=0, buffer_size=0,
                        top_kg=12, max_coarse=64)
    index, _ = build_lychee(keys, tokens, base)
    rows = []
    for budget in (128, 256, 512, 1024, 2048):
        rs = []
        for _ in range(24):
            qi = int(rng.integers(0, N))
            q = np.asarray(keys[0, qi]) + rng.standard_normal(d) * 0.2
            q = jnp.asarray(q, jnp.float32)
            ret = retrieve(index, q[None], base, budget=budget)
            rs.append(recall_rate(ret.token_idx[0], ret.token_mask[0],
                                  np.asarray(keys[0]), np.asarray(q),
                                  k_truth=128))
        rows.append({"budget": budget, "recall": float(np.mean(rs))})
    return emit(rows, "budget_fig7")
