"""Paper Fig. 8 / App. C: index memory overhead vs full KV cache.

Exact byte accounting of the LycheeIndex pytree against the KV tensors it
indexes (Llama-3.1-8B geometry: 32 layers, 8 kv heads, head_dim 128; first
2 layers full per App. A). Three columns:

* physical_pct   — everything our static-shape TPU index allocates,
* resident_pct   — what decode actually READS (drops ``chunk_key``:
                   Algorithm 1 scores only coarse/fine centroids; chunk
                   keys are build-time + write-only-at-graft),
* paper reports ~1% for its dynamic-shape CUDA variant; the gap is the
  static worst-case padding (M = N/min_chunk slots for ~N/12 real chunks)
  plus chunk_key retention — see EXPERIMENTS.md §Perf (memory iteration).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.base import LycheeConfig
from repro.core import empty_index


def run():
    cfg = LycheeConfig()
    H, dh, n_layers, full_layers = 8, 128, 32, 2
    rows = []
    for N in (8192, 16384, 32768, 65536):
        kv_bytes = 2 * H * N * dh * 2          # k+v, bf16
        idx = empty_index(N, H, dh, cfg, dtype=jnp.bfloat16)
        by_field = {k: np.prod(v.shape) * v.dtype.itemsize
                    for k, v in idx._asdict().items()}
        total = sum(by_field.values())
        resident = total - by_field["chunk_key"]
        centroids = by_field["fine_centroid"] + by_field["coarse_centroid"]
        scale = (n_layers - full_layers) / n_layers / kv_bytes * 100
        rows.append({
            "context": N,
            "kv_gb": kv_bytes * n_layers / 2**30,
            "physical_pct": total * scale,
            "resident_pct": resident * scale,
            "centroid_pct": centroids * scale,
            "chunk_key_pct": by_field["chunk_key"] * scale,
        })
    return emit(rows, "memory_fig8")
