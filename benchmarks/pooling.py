"""Paper Table 3: mean vs max pooling for chunk representative keys.

Same pipeline, only the pooling strategy differs; the paper's Recall Rate
metric decides. Mean pooling + L2-norm is the spherical centroid and should
dominate (the paper reports 40.4% vs 33.6%).
"""
from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from benchmarks.common import (build_lychee, coherent_keys, emit,
                               recall_rate, structured_tokens)
from repro.configs.base import LycheeConfig
from repro.core import retrieve


def run():
    rng = np.random.default_rng(1)
    N, d = 2048, 64
    rows = []
    for pooling in ("mean", "max"):
        cfg = LycheeConfig(min_chunk=8, max_chunk=16, sink=0, buffer_size=0,
                           budget=256, top_kg=8, max_coarse=32,
                           pooling=pooling)
        keys = coherent_keys(rng, N, d)
        tokens = structured_tokens(rng, N)
        index, _ = build_lychee(keys, tokens, cfg)
        rs = []
        for _ in range(32):
            qi = int(rng.integers(0, N))
            q = np.asarray(keys[0, qi]) + rng.standard_normal(d) * 0.2
            q = jnp.asarray(q, jnp.float32)
            ret = retrieve(index, q[None], cfg)
            rs.append(recall_rate(ret.token_idx[0], ret.token_mask[0],
                                  np.asarray(keys[0]), np.asarray(q)))
        rows.append({"pooling": pooling, "recall": float(np.mean(rs))})
    return emit(rows, "pooling_tab3")
