"""Prefix sharing: TTFT + pool bytes for N sessions sharing a system prompt.

The paged KV pool's radix prefix cache turns repeated prompt prefixes into
page sharing: the first session pays the full prefill and registers its
pages; every later session that repeats the prompt is a FULL hit (spliced
snapshot + stored logits — ZERO forward passes, bit-identical greedy
output) and every session that extends it with a unique suffix is a
PARTIAL hit (shared prefix pages + suffix-only extend). The contiguous
engine re-prefills the whole prompt every time.

Two scenarios over ``--sessions`` sequentially admitted sessions
(``n_slots=1`` so session 0 registers before anyone looks up):

* ``identical`` — every session sends the SAME ``--prefix-len`` prompt.
* ``suffix``    — shared prefix + a unique ``--suffix-len`` tail.

Reported per scenario: session-0 (cold) TTFT, mean warm-session TTFT for
paged-with-prefix-cache vs contiguous, the warm speedup, token identity,
and the pool's observability counters (hit rate, bytes saved by sharing,
pool vs contiguous cache bytes). ``--check`` gates the acceptance claims:
warm speedup >= 3x in the identical scenario, full-hit tokens
bit-identical, and every warm identical session an exact hit.

Run:  PYTHONPATH=src python benchmarks/prefix_reuse.py --reduced
"""
from __future__ import annotations

import argparse
import copy
import json
import platform

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, LycheeConfig, get_config
from repro.core.policy import list_policies
from repro.models import model as MD
from repro.serving import Engine, Session, Turn


def make_sessions(rng, n, prefix, suffix_len, gen, vocab):
    out = []
    for i in range(n):
        prompt = prefix if suffix_len == 0 else np.concatenate(
            [prefix, rng.integers(0, vocab, size=(suffix_len,))
             .astype(np.int32)])
        out.append(Session(uid=i, turns=[Turn(prompt=prompt.copy(),
                                              max_new=gen)]))
    return out


def run_once(engine, sessions, repeat):
    """Serve the trace ``repeat`` times (after one warmup that pays jit);
    per-session min TTFT plus the last run's tokens and pool stats."""
    engine.serve(copy.deepcopy(sessions), n_slots=1, mode="continuous")
    ttfts, res = None, None
    for _ in range(repeat):
        res = engine.serve(copy.deepcopy(sessions), n_slots=1,
                           mode="continuous")
        cur = [res.requests[s.uid].turns[0].ttft_s for s in sessions]
        ttfts = cur if ttfts is None else [min(a, b)
                                           for a, b in zip(ttfts, cur)]
    tokens = {s.uid: res.requests[s.uid].turns[0].tokens for s in sessions}
    return ttfts, tokens, res.pool, res.metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--policy", default="lychee",
                    choices=list(list_policies()))
    ap.add_argument("--prefix-len", type=int, default=1024,
                    help="shared system-prompt length")
    ap.add_argument("--suffix-len", type=int, default=64,
                    help="unique per-session tail (suffix scenario)")
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--repeat", type=int, default=3,
                    help="timed serve() repeats (min TTFT is kept)")
    ap.add_argument("--page-tokens", type=int, default=0,
                    help="logical page size (0 = auto)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="pool capacity in pages (0 = auto)")
    ap.add_argument("--check", action="store_true",
                    help="assert warm full-hit speedup >= 3x, bit-identical "
                         "full-hit tokens, and an exact hit per warm "
                         "identical session")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist the per-scenario numbers + pool stats as "
                         "a JSON artifact (perf-trajectory record)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    lychee = LycheeConfig(policy=args.policy,
                          enabled=args.policy != "dense",
                          budget=args.budget, sink=16, buffer_size=64,
                          max_coarse=32, top_kg=8, full_attn_layers=0)
    cfg = get_config(args.arch, reduced=args.reduced).replace(
        dtype="float32", lychee=lychee)
    params = MD.init_model(jax.random.key(0), cfg)
    total = args.prefix_len + args.suffix_len + args.gen
    n_cache = (-(-total // 128) + 1) * 128      # round up + one spare page
    rng = np.random.default_rng(args.seed)
    prefix = rng.integers(0, cfg.vocab, size=(args.prefix_len,)) \
        .astype(np.int32)
    print(f"[prefix_reuse] {cfg.name} | policy={args.policy} "
          f"prefix={args.prefix_len} suffix={args.suffix_len} "
          f"sessions={args.sessions} gen={args.gen} n_cache={n_cache}")

    eng_c = Engine(cfg, params, n_cache=n_cache, donate_state=True)
    cfg_p = cfg.replace(serving=cfg.serving.replace(
        paged=True, page_tokens=args.page_tokens,
        pool_pages=args.pool_pages, prefix_cache=True))
    eng_p = Engine(cfg_p, params, n_cache=n_cache, donate_state=True)
    if not eng_p.paged:
        raise SystemExit(f"policy {args.policy} cannot run paged "
                         f"(dense fallback) — nothing to measure")

    rows = []
    failures = []
    for scenario, suffix_len in (("identical", 0),
                                 ("suffix", args.suffix_len)):
        srng = np.random.default_rng(args.seed + 1)
        sessions = make_sessions(srng, args.sessions, prefix, suffix_len,
                                 args.gen, cfg.vocab)
        t_c, tok_c, _, _ = run_once(eng_c, sessions, args.repeat)
        t_p, tok_p, pool, metrics = run_once(eng_p, sessions, args.repeat)
        warm_c = float(np.mean(t_c[1:]))
        warm_p = float(np.mean(t_p[1:]))
        speedup = warm_c / max(warm_p, 1e-9)
        identical = tok_c == tok_p
        row = {
            "scenario": scenario,
            "cold_ttft_ms": {"contiguous": 1e3 * t_c[0],
                             "paged": 1e3 * t_p[0]},
            "warm_ttft_ms": {"contiguous": 1e3 * warm_c,
                             "paged": 1e3 * warm_p},
            "warm_speedup": speedup,
            "tokens_identical": identical,
            "pool": pool.to_dict(),
            "metrics": metrics.to_dict() if metrics else None,
            "pool_bytes": pool.bytes_per_page * (pool.n_pages + 1),
            "contiguous_bytes": pool.bytes_per_page // pool.page_rows
            * n_cache * 1,                       # n_slots=1 private slots
        }
        rows.append(row)
        if args.check:
            n_warm = args.sessions - 1
            if scenario == "identical":
                if speedup < 3.0:
                    failures.append(f"{scenario}: warm speedup "
                                    f"{speedup:.2f}x < 3x")
                if not identical:
                    failures.append(f"{scenario}: full-hit tokens diverged "
                                    f"from contiguous")
                if pool.prefix_hits < n_warm:
                    failures.append(f"{scenario}: {pool.prefix_hits} exact "
                                    f"hits < {n_warm} warm sessions")
            elif pool.prefix_hits + pool.prefix_partial_hits < n_warm:
                failures.append(f"{scenario}: only "
                                f"{pool.prefix_hits + pool.prefix_partial_hits}"
                                f" hits for {n_warm} warm sessions")

    print(f"\n  {'scenario':10s} {'cold ms (c/p)':>16s} "
          f"{'warm ms (c/p)':>16s} {'speedup':>8s} {'hit rate':>9s} "
          f"{'saved KiB':>10s} {'tok ==':>7s}")
    for r in rows:
        p = r["pool"]
        print(f"  {r['scenario']:10s} "
              f"{r['cold_ttft_ms']['contiguous']:7.1f}/"
              f"{r['cold_ttft_ms']['paged']:7.1f} "
              f"{r['warm_ttft_ms']['contiguous']:7.1f}/"
              f"{r['warm_ttft_ms']['paged']:7.1f} "
              f"{r['warm_speedup']:7.2f}x {p['prefix_hit_rate']:9.2f} "
              f"{p['peak_bytes_saved'] / 1024:10.1f} "
              f"{str(r['tokens_identical']):>7s}")

    if args.json:
        payload = {
            "benchmark": "prefix_reuse",
            "arch": cfg.name,
            "policy": args.policy,
            "backend": jax.default_backend(),
            "host": platform.platform(),
            "jax": jax.__version__,
            "args": {k: v for k, v in vars(args).items() if k != "json"},
            "n_cache": n_cache,
            "checked": bool(args.check),
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {args.json}")
    if failures:
        raise SystemExit("FAIL: " + "; ".join(failures))
    return rows


if __name__ == "__main__":
    main()
