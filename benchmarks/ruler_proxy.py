"""Paper Table 6 (RULER) proxy: does the retrieval layer FIND the queried
record under a tight budget, in a real model's key geometry?

Full RULER accuracy needs a pretrained LLM (induction heads do not form in
CPU-minutes — we verified: a 2-layer model trained here reaches the
uniform-over-values plateau, so end-task exact-match is uninformative at
this scale). What is measurable and faithful to the paper's mechanism is
**answer-record retrieval recall**: we briefly train the toy model on the
KV-lookup grammar so its key cache has task geometry, prefill real
prompts, and check whether the tokens of the QUERIED record are inside the
retrieved set, for

  * LycheeCluster with structure-aware chunks (delimiters = the grammar's
    separators),
  * LycheeCluster with fixed-size chunks (Fig. 6 ablation at task level),
  * Quest fixed pages at the same budget.

The paper's Table 6 claim (parity with full attention) follows whenever
the needed record is retrieved — full attention trivially "retrieves"
everything.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import LycheeConfig, get_config
from repro.core import chunk_sequence, fixed_chunking, retrieve
from repro.core.index import build_index
from repro.core.policy import make_policy, spans_to_tokens
from repro.models import model as MD
from repro.models.model import chunked_ce
from repro.training.data import (NL, QUERY, SEP, structured_retrieval_task)
from repro.training.optimizer import adamw_init, adamw_update, lr_schedule

_CKPT = "experiments/toy_ruler"
VOCAB = 256
N_RECORDS = 24
VAL_LEN = 4


def _cfg():
    return get_config("llama31-8b", reduced=True).replace(
        vocab=VOCAB, dtype="float32", n_layers=2,
        lychee=LycheeConfig(enabled=False))


def _delim_table():
    t = np.zeros(VOCAB, np.int32)
    t[NL] = 3
    t[SEP] = 2
    t[QUERY] = 4
    return jnp.asarray(t)


def _train(cfg, steps=150, batch=32):
    from repro.training.checkpoint import restore, save
    params = MD.init_model(jax.random.key(0), cfg)
    if os.path.exists(os.path.join(_CKPT, "manifest.json")):
        try:
            params, _ = restore(_CKPT, params)
            return params
        except Exception:   # noqa: BLE001 — stale layout: retrain
            pass
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, tok):
        def loss_fn(p):
            x, _ = MD.forward(p, tok, cfg)
            labels = tok[:, 1:]
            mask = jnp.ones_like(labels, jnp.float32)
            return chunked_ce(x[:, :-1], p["embed"], labels, mask, 0.0)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = lr_schedule(opt.step, base_lr=1e-3, total_steps=steps)
        params, opt, _ = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    loss = None
    for i in range(steps):
        tokens, answers, _ = structured_retrieval_task(
            VOCAB, batch, N_RECORDS, VAL_LEN, seed=1000 + i)
        tok = jnp.asarray(np.concatenate([tokens, answers], axis=1))
        params, opt, loss = step(params, opt, tok)
    save(_CKPT, params)
    print(f"  [ruler_proxy] toy model LM loss={float(loss):.3f}")
    return params


def run():
    cfg = _cfg()
    params = _train(cfg)
    table = _delim_table()
    ly = LycheeConfig(budget=48, sink=0, buffer_size=0, max_coarse=8,
                      top_kg=4, min_chunk=4, max_chunk=16,
                      full_attn_layers=0)

    tokens, answers, apos = structured_retrieval_task(
        VOCAB, 16, N_RECORDS, VAL_LEN, seed=9)
    S = tokens.shape[1]
    # real key geometry: prefill and take the first layer group's K cache
    _, state = jax.jit(lambda p, tk: MD.prefill(p, tk, cfg, S + 8))(
        params, jnp.asarray(tokens))
    k_all = state["groups"][0]["k"]            # (G, B, Hkv, n_cache, dh)

    # the model's REAL layer-0 queries at the last prompt position:
    # x0 = embed(tokens); q = RoPE(rmsnorm(x0) @ wq) — exact for layer 0
    from repro.models.attention import _project_qkv
    bp0 = jax.tree.map(lambda a: a[0], params["pattern"][0])
    from repro.models.layers import rmsnorm
    x0 = MD.embed_inputs(params, jnp.asarray(tokens), cfg)
    qf, _, _ = _project_qkv(bp0["attn"], rmsnorm(bp0["norm1"], x0),
                            jnp.arange(S, dtype=jnp.int32), cfg)
    Hq = qf.shape[1]
    Hkv = k_all.shape[2]
    q_last = qf[:, :, S - 1]                   # (B, Hq, dh)
    probe_all = q_last.reshape(tokens.shape[0], Hkv, Hq // Hkv, -1).mean(2)

    hits = {"lychee_structure_aware": [], "lychee_fixed": [], "quest": []}
    neff = {m: [] for m in hits}
    for b in range(tokens.shape[0]):
        keys = k_all[0, b][:, :S]              # (Hkv, S, dh)
        tk = jnp.asarray(tokens[b])
        probe = probe_all[b]
        # answer-record token span
        span = set(range(int(apos[b]) - 2, int(apos[b]) + VAL_LEN + 1))

        lay_sa = chunk_sequence(tk, table, ly)
        lay_fx = fixed_chunking(S, 16, ly)
        for name, lay in [("lychee_structure_aware", lay_sa),
                          ("lychee_fixed", lay_fx)]:
            idx = build_index(keys, lay, ly)
            # top_kc assumes full max_chunk-length chunks; this grammar's
            # records are ~7 tokens, so correct kc by the TRUE mean chunk
            # length to give every method the same effective token budget
            mean_len = float(np.asarray(lay.length).sum() /
                             max(int(lay.count), 1))
            eff_budget = int(ly.budget * ly.max_chunk / max(mean_len, 1.0))
            ret = retrieve(idx, probe, ly, budget=eff_budget)
            got = set(np.asarray(ret.token_idx)[
                np.asarray(ret.token_mask)].tolist())
            hits[name].append(len(got & span) / len(span))
            neff[name].append(len(got))
        qpol = make_policy("quest", ly)
        qstate = qpol.build(keys, None, S)
        ti, tm = spans_to_tokens(*qpol.select(qstate, probe, S),
                                 qpol.span_len)
        got = set(np.asarray(ti)[np.asarray(tm)].tolist())
        hits["quest"].append(len(got & span) / len(span))
        neff["quest"].append(len(got))

    rows = [{"method": m, "answer_record_recall": float(np.mean(v)),
             "budget": ly.budget,
             "effective_tokens": float(np.mean(neff[m]))}
            for m, v in hits.items()]
    return emit(rows, "ruler_proxy_tab6")
