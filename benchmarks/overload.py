"""Overload behaviour: SLO-aware scheduling vs blind FIFO at 4x load.

The tentpole measurement of the SLO scheduler (``cfg.serving.slo``): a
burst arrives at ``--overload``x the engine's measured service capacity,
with a mix of priority classes. Under FIFO every request waits behind the
whole backlog, so the high-priority (priority 0, premium) TTFT grows with
queue depth. Under the SLO policy, deadline-ordered admission pulls
premiums to the head and the overload ladder (optional budget
degradation -> chunk-boundary preemption -> shedding of hopeless
low-priority sessions) keeps the backlog from consuming the premiums'
slots — shed work is surfaced explicitly as ``ShedResult``s, never
silently dropped.

Capacity is calibrated on the same engine (an offline serve of the same
session shape), so the 4x factor means 4x over THIS host's throughput —
the benchmark is load-relative, not wall-clock-absolute.

``--check`` (the acceptance gate) asserts:
  * premium p99 TTFT under SLO <= --max-ttft-ratio (default 0.5) of the
    FIFO baseline's premium p99 TTFT;
  * zero invariant violations on the SLO run (terminal partition,
    shed-exactly-once, token budgets, paged refcount ledger + drain —
    ``serving.journeys.verify_drained_loop``);
  * every finished never-degraded session's greedy tokens bit-identical
    to the unloaded solo oracle;
  * at least one session finished per priority class, and no priority-0
    session was ever shed or degraded.

Run:  PYTHONPATH=src python benchmarks/overload.py --reduced --check
"""
from __future__ import annotations

import argparse
import copy
import json
import platform

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, LycheeConfig, SLOConfig, get_config
from repro.core.policy import list_policies
from repro.models import model as MD
from repro.serving import Engine, Request
from repro.serving.journeys import verify_drained_loop


def make_burst(rng, vocab, n, prompt_len, gen, rate_rps, premium_every):
    """``n`` single-turn greedy sessions, Poisson arrivals at ``rate_rps``;
    every ``premium_every``-th is priority 0, the rest priority 2."""
    reqs = []
    t = 0.0
    for uid in range(n):
        prompt = rng.integers(0, vocab, size=(prompt_len,)).astype(np.int32)
        r = Request(uid, prompt, gen,
                    priority=0 if uid % premium_every == 0 else 2)
        r.arrival_s = t
        t += float(rng.exponential(1.0 / rate_rps))
        reqs.append(r)
    return reqs


def priority_ttfts(res, trace):
    out = {0: [], 2: []}
    for r in trace:
        if r.uid in res.requests and r.ttft_s is not None:
            out[r.priority].append(r.ttft_s)
    return out


def p99(xs):
    return float(np.percentile(np.asarray(xs), 99)) if xs else float("nan")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--policy", default="lychee",
                    choices=list(list_policies()))
    ap.add_argument("--paged", action="store_true", default=True)
    ap.add_argument("--no-paged", dest="paged", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt", type=int, default=48)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--premium-every", type=int, default=4,
                    help="every k-th session is priority 0 (premium)")
    ap.add_argument("--overload", type=float, default=4.0,
                    help="offered load as a multiple of measured capacity")
    ap.add_argument("--ttft-slo", type=float, default=0.0,
                    help="TTFT target (s); 0 = auto from calibration")
    ap.add_argument("--max-ttft-ratio", type=float, default=0.5,
                    help="gate: premium p99 TTFT (slo/fifo) must be <=")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    lychee = LycheeConfig(policy=args.policy,
                          enabled=args.policy != "dense",
                          budget=args.budget, sink=4, buffer_size=16,
                          max_coarse=8, top_kg=4, full_attn_layers=0)
    base = get_config(args.arch, reduced=args.reduced).replace(
        dtype="float32", lychee=lychee)
    base = base.replace(serving=base.serving.replace(
        paged=args.paged, prefill_chunk=16))
    params = MD.init_model(jax.random.key(0), base)
    # round up to a span_base multiple the pager can page (span_base=16)
    n_cache = -(-(args.prompt + args.gen + 64) // 32) * 32
    engine = Engine(base, params, n_cache=n_cache, donate_state=True)

    def trace(rate):
        rng = np.random.default_rng(args.seed)
        return make_burst(rng, base.vocab, args.requests, args.prompt,
                          args.gen, rate, args.premium_every)

    # ---- calibration: measured service capacity (offline, warms jit) --
    calib = engine.serve(trace(1e9), n_slots=args.slots)
    cap_rps = len(calib.requests) / max(calib.wall_s, 1e-9)
    service_s = calib.wall_s / max(len(calib.requests), 1)
    rate = args.overload * cap_rps
    ttft_slo = args.ttft_slo or 4.0 * service_s * args.slots
    print(f"[overload] {base.name} | policy={args.policy} "
          f"paged={int(args.paged)} slots={args.slots} "
          f"n={args.requests} (premium every {args.premium_every})")
    print(f"  capacity {cap_rps:.2f} req/s -> offered "
          f"{rate:.2f} req/s ({args.overload:.0f}x)  "
          f"TTFT target {ttft_slo:.2f}s")

    # ---- FIFO baseline: same burst, SLO machinery off ------------------
    fifo_trace = trace(rate)
    res_fifo = engine.serve(copy.deepcopy(fifo_trace), n_slots=args.slots,
                            slo=SLOConfig())
    # engine.serve deep-copies nothing itself: serve mutated the trace
    # objects we passed, so re-read TTFTs off the served copies
    fifo_tt = priority_ttfts(res_fifo, list(res_fifo.requests.values()))

    # ---- SLO run: deadline order + full overload ladder ----------------
    slo = SLOConfig(enabled=True, ttft_target_s=ttft_slo,
                    max_pending=args.requests, queue_high=args.slots,
                    degrade_budget=False, preempt=True, shed=True,
                    shed_grace=2.0)
    slo_trace = trace(rate)
    loop = engine.serve_loop(slo_trace, n_slots=args.slots, slo=slo)
    loop.run()
    res_slo = loop.result()
    slo_tt = priority_ttfts(res_slo, slo_trace)

    rows = {}
    for name, res, tt in (("fifo", res_fifo, fifo_tt),
                          ("slo", res_slo, slo_tt)):
        c = res.metrics.to_dict()["counters"] if res.metrics else {}
        rows[name] = {
            "premium_p99_ttft_s": p99(tt[0]),
            "premium_mean_ttft_s": float(np.mean(tt[0])) if tt[0]
            else float("nan"),
            "bulk_p99_ttft_s": p99(tt[2]),
            "finished": len(res.requests),
            "shed": len(res.shed),
            "tokens_per_s": res.tokens_per_s,
            "wall_s": res.wall_s,
            "counters": c,
            "pool": res.pool.to_dict() if res.pool else None,
            "metrics": res.metrics.to_dict() if res.metrics else None,
        }
        print(f"  {name:4s} premium p99 TTFT "
              f"{rows[name]['premium_p99_ttft_s']:6.2f}s  bulk p99 "
              f"{rows[name]['bulk_p99_ttft_s']:6.2f}s  finished "
              f"{rows[name]['finished']:2d}  shed "
              f"{rows[name]['shed']:2d}  wall {res.wall_s:5.2f}s")

    ratio = rows["slo"]["premium_p99_ttft_s"] / max(
        rows["fifo"]["premium_p99_ttft_s"], 1e-9)
    print(f"  => premium p99 TTFT ratio (slo/fifo) {ratio:.2f}")

    # ---- invariants + oracle identity on the SLO run -------------------
    violations = []
    try:
        verify_drained_loop(loop, slo_trace)
    except AssertionError as e:
        violations.append(str(e))
    oracle_checked = oracle_ok = 0
    for r in slo_trace:
        if r.outcome != "finished" or any(t.degraded for t in r.turns):
            continue
        alone = engine.generate(r.prompt[None], args.gen)
        oracle_checked += 1
        if r.turns[0].sampled == alone.tokens[0].tolist():
            oracle_ok += 1
        else:
            violations.append(f"sess{r.uid} tokens diverged from the "
                              f"unloaded solo oracle")
    prem_shed = [u for u, sr in res_slo.shed.items() if sr.priority == 0]
    if prem_shed:
        violations.append(f"premium sessions shed: {prem_shed}")
    print(f"  oracle identity {oracle_ok}/{oracle_checked}  "
          f"violations {len(violations)}")

    failures = []
    if args.check:
        if not ratio <= args.max_ttft_ratio:
            failures.append(f"premium p99 TTFT ratio {ratio:.2f} > "
                            f"{args.max_ttft_ratio}")
        failures += violations
        for prio, tt in slo_tt.items():
            if not tt:
                failures.append(f"no priority-{prio} session finished "
                                f"under the SLO policy")

    if args.json:
        payload = {
            "benchmark": "overload",
            "arch": base.name,
            "policy": args.policy,
            "backend": jax.default_backend(),
            "host": platform.platform(),
            "jax": jax.__version__,
            "args": {k: v for k, v in vars(args).items() if k != "json"},
            "capacity_rps": cap_rps,
            "offered_rps": rate,
            "ttft_slo_s": ttft_slo,
            "checked": bool(args.check),
            "rows": rows,
            "premium_p99_ttft_ratio": ratio,
            "oracle_identity": [oracle_ok, oracle_checked],
            "violations": violations,
            "shed": [{"uid": u, "priority": sr.priority,
                      "reason": sr.reason,
                      "projected_ttft_s": sr.projected_ttft_s}
                     for u, sr in sorted(res_slo.shed.items())],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {args.json}")
    if failures:
        raise SystemExit("FAIL: " + "; ".join(failures))
    return rows


if __name__ == "__main__":
    main()
