"""Paper §5.1 end-to-end proxy: every registered cache policy through the
SAME continuous-batching engine on the same mixed-length trace.

The pre-policy repo could only compare Quest/ClusterKV offline (selection
recall / operator microbenchmarks); the CachePolicy redesign runs them — and
StreamingLLM and dense full attention — through the identical prefill /
decode / slot-splice machinery as LycheeCluster, so tokens/s and TPOT are an
apples-to-apples comparison of the *selection policy* alone. Absolute CPU
milliseconds are not the paper's H20 numbers; the orderings are the
reproduced claim.

Reports per policy: tokens/s over the trace replay, TPOT (decode-only
wall-clock per lock-step token — admission prefills and host scheduling
excluded, so ClusterKV's heavy k-means prefill does not pollute its decode
number), p50/p99 request latency and mean TTFT. ``--check``
additionally asserts each request's greedy output equals the request served
alone (the slot-splice correctness invariant, per policy).

Run:  PYTHONPATH=src python benchmarks/policy_e2e.py --reduced
"""
from __future__ import annotations

import argparse
import copy
import json
import platform

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, LycheeConfig, get_config
from repro.core.policy import list_policies
from repro.models import model as MD
from repro.serving import Engine, Request, make_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--policies", default=",".join(list_policies()),
                    help="comma-separated subset of the policy registry")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-lens", type=int, nargs="+",
                    default=[64, 256, 1024])
    ap.add_argument("--gen-lens", type=int, nargs="+", default=[8, 96])
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--check", action="store_true",
                    help="assert serve == solo generate per request")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="persist the per-policy table (+ run metadata) as "
                         "a JSON artifact — the perf-trajectory record CI "
                         "uploads per PR")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (+ prefix cache); "
                         "policies that cannot page fall back contiguous, "
                         "and pool stats land in the JSON artifact")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    policies = [p.strip() for p in args.policies.split(",") if p.strip()]
    unknown = set(policies) - set(list_policies())
    if unknown:
        raise SystemExit(f"unknown policies {sorted(unknown)}; "
                         f"registry has {list(list_policies())}")

    cfg0 = get_config(args.arch, reduced=args.reduced).replace(
        dtype="float32")
    params = MD.init_model(jax.random.key(0), cfg0)
    n_cache = max(args.prompt_lens) + max(args.gen_lens) + 32
    rng = np.random.default_rng(args.seed)
    trace = make_trace(rng, args.requests, cfg0.vocab,
                       prompt_lens=args.prompt_lens, gen_lens=args.gen_lens)
    print(f"[policy_e2e] {cfg0.name} | slots={args.slots} "
          f"requests={args.requests} prompts={sorted(set(args.prompt_lens))} "
          f"budget={args.budget} policies={policies}")

    wrng = np.random.default_rng(1)
    warm = [Request(uid=i, prompt=wrng.integers(
        0, cfg0.vocab, size=(S,)).astype(np.int32), max_new=2)
        for i, S in enumerate(args.prompt_lens)]

    rows = []
    for policy in policies:
        lychee = LycheeConfig(policy=policy, enabled=policy != "dense",
                              budget=args.budget, sink=16, buffer_size=64,
                              max_coarse=32, top_kg=8, full_attn_layers=0)
        cfg = cfg0.replace(lychee=lychee)
        if args.paged:
            cfg = cfg.replace(serving=cfg.serving.replace(paged=True))
        engine = Engine(cfg, params, n_cache=n_cache, donate_state=True)
        # warmup pays jit (one prefill per prompt length + the decode step)
        engine.serve(copy.deepcopy(warm), n_slots=args.slots,
                     mode="continuous")
        res = engine.serve(copy.deepcopy(trace), n_slots=args.slots,
                           mode="continuous")
        tpot_ms = 1e3 * res.decode_s / max(res.n_steps, 1)
        rows.append({"policy": policy, "tokens_per_s": res.tokens_per_s,
                     "tpot_ms": tpot_ms, "p50_s": res.p50_latency_s,
                     "p99_s": res.p99_latency_s, "ttft_s": res.mean_ttft_s,
                     "pool": res.pool.to_dict() if res.pool else None,
                     "metrics": res.metrics.to_dict()
                     if res.metrics else None})
        if args.check:
            bad = []
            for req in trace:
                alone = engine.generate(req.prompt[None], req.max_new)
                if res.requests[req.uid].tokens != alone.tokens[0].tolist():
                    bad.append(req.uid)
            if bad:
                raise SystemExit(
                    f"FAIL[{policy}]: serve != solo for requests {bad}")
            print(f"  {policy}: serve == solo generate for all "
                  f"{len(trace)} requests")

    print(f"\n  {'policy':10s} {'tok/s':>8s} {'TPOT ms':>9s} "
          f"{'p50 s':>7s} {'p99 s':>7s} {'TTFT s':>7s}")
    for r in rows:
        print(f"  {r['policy']:10s} {r['tokens_per_s']:8.1f} "
              f"{r['tpot_ms']:9.1f} {r['p50_s']:7.2f} {r['p99_s']:7.2f} "
              f"{r['ttft_s']:7.2f}")
    if args.json:
        payload = {
            "benchmark": "policy_e2e",
            "arch": cfg0.name,
            "backend": jax.default_backend(),
            "host": platform.platform(),
            "jax": jax.__version__,
            "args": {k: v for k, v in vars(args).items() if k != "json"},
            "checked": bool(args.check),
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"  wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
