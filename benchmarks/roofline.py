"""§Roofline: derive the three roofline terms per (arch × shape × mesh)
from the dry-run's compiled artifacts (experiments/dryrun/*.json).

TPU v5e-class hardware constants:
  peak 197 TFLOP/s bf16/chip, 819 GB/s HBM/chip, ~50 GB/s/link ICI.

``compiled.cost_analysis()`` on the SPMD-partitioned module reports
PER-DEVICE flops/bytes (verified: granite decode_32k flops ≈ 2·P·B/chips),
so the terms are:

  compute_s    = flops_per_device / 197e12
  memory_s     = bytes_per_device / 819e9
  collective_s = collective_bytes_per_device / 50e9
                 (op-output bytes as the transfer proxy: ring all-gather
                  moves ~out·(n-1)/n ≈ out; all-reduce ~2·in — we report
                  the unweighted sum and note the approximation)

MODEL_FLOPS (useful work) = c·N·D with c=6 for train (fwd+bwd), 2 for
prefill/decode forward; N = active params (MoE: routed experts counted at
top_k/E + shared), D = global tokens processed. The ratio
MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy waste.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": (6, 256 * 4096),
    "prefill_32k": (2, 32 * 32768),
    "decode_32k": (2, 128),
    "long_500k": (2, 1),
}

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def param_counts(arch: str) -> Dict[str, float]:
    """(total, active) parameter counts via eval_shape over init_model."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    import jax
    from repro.configs.base import get_config
    from repro.models import model as MD
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: MD.init_model(jax.random.key(0), cfg))
    total = expert = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        n = float(np.prod(leaf.shape))
        total += n
        name = ""
        for k in reversed(path):
            if isinstance(k, jax.tree_util.DictKey):
                name = k.key
                break
        if name in ("we_gate", "we_in", "we_out"):
            expert += n
    active = total
    if cfg.n_experts:
        active = total - expert * (1.0 - cfg.top_k / cfg.n_experts)
    _PARAM_CACHE[arch] = {"total": total, "active": active}
    return _PARAM_CACHE[arch]


def scan_trips(arch: str) -> int:
    """XLA's cost_analysis counts while-loop bodies ONCE (verified
    empirically: scan(f, len=10) reports 1x f's flops, unroll=10 reports
    10x). Our decoder scans ``cfg.groups`` times, so flops/bytes/collective
    of the body — which dominates the program — are undercounted by ~G.
    We report raw and xG-corrected terms; the dominant-term classification
    is invariant (same multiplier on all three terms)."""
    from repro.configs.base import get_config
    return max(1, get_config(arch).groups)


def analyse(rec: dict) -> dict:
    chips = rec["chips"]
    G = scan_trips(rec["arch"])
    compute_s = rec["flops"] / PEAK_FLOPS * G
    memory_s = rec["bytes_accessed"] / HBM_BW * G
    coll = rec["collective_bytes"]
    coll_total = sum(v for k, v in coll.items() if k != "count")
    collective_s = coll_total / ICI_BW * G
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    c, tokens = SHAPE_TOKENS[rec["shape"]]
    pc = param_counts(rec["arch"])
    model_flops_dev = c * pc["active"] * tokens / chips
    useful = model_flops_dev / (rec["flops"] * G) if rec["flops"] else 0.0
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "chips")},
        "scan_trips": G,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops_per_dev": model_flops_dev,
        "useful_flops_ratio": useful,
        "raw_flops": rec["flops"],
        "peak_gib_per_dev": rec.get("temp_bytes_per_device", 0) / 2**30,
        "fits_16g": rec.get("temp_bytes_per_device", 0) / 2**30 < 16.0,
    }


SUGGEST = {
    "compute": "compute-bound: raise MXU utilisation (tile alignment, "
               "bf16 everywhere, batch more work per chip)",
    "memory": "HBM-bound: cut bytes (fuse elementwise chains, avoid "
              "f32 intermediates, quantise the cache, shrink remat)",
    "collective": "ICI-bound: re-balance sharding (avoid per-step "
                  "reshards, reduce-scatter instead of all-reduce, "
                  "overlap collectives with compute)",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if args.mesh and rec["mesh"] != args.mesh:
            continue
        rows.append(analyse(rec))

    hdr = ("arch", "shape", "mesh", "compute_s", "memory_s",
           "collective_s", "dominant", "useful_flops_ratio",
           "peak_gib_per_dev", "fits_16g")
    if args.markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for r in rows:
            cells = [f"{r[h]:.3e}" if isinstance(r[h], float) and
                     h.endswith("_s") else
                     (f"{r[h]:.3f}" if isinstance(r[h], float) else str(r[h]))
                     for h in hdr]
            print("| " + " | ".join(cells) + " |")
    else:
        print(",".join(hdr))
        for r in rows:
            print(",".join(f"{r[h]:.4g}" if isinstance(r[h], float)
                           else str(r[h]) for h in hdr))
    # summary: worst useful-flops, most collective-bound
    if rows:
        worst = min(rows, key=lambda r: r["useful_flops_ratio"] or 1e9)
        collb = max(rows, key=lambda r: r["collective_s"] /
                    max(r["compute_s"], 1e-12))
        print(f"\n# worst useful-flops: {worst['arch']}×{worst['shape']} "
              f"({worst['useful_flops_ratio']:.3f})")
        print(f"# most collective-bound: {collb['arch']}×{collb['shape']}")
        for dom in ("compute", "memory", "collective"):
            n = sum(1 for r in rows if r["dominant"] == dom)
            print(f"# {dom}-dominated: {n}/{len(rows)} — {SUGGEST[dom]}")


if __name__ == "__main__":
    main()
