"""Synthetic data pipeline (no external corpora exist offline).

Two generators:

* ``synthetic_lm_batches`` — an infinite stream of learnable token
  sequences: a mixture of (a) k-order Markov chains with structural
  delimiter tokens injected at natural-language-like rates (so the
  structure-aware chunker sees realistic boundaries) and (b) copy/recall
  spans that give long-range dependencies a model can actually learn.
* ``structured_retrieval_task`` — key-value lookup prompts (the RULER /
  StrucText-style probe): N key:value records followed by a query key; the
  answer is the value. Used by the retrieval-quality benchmarks and the
  trained-toy-model experiments in EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

# reserved token layout for the synthetic grammar
PAD, BOS, SEP, NL, QUERY = 0, 1, 2, 3, 4
_RESERVED = 8


def synthetic_lm_batches(vocab: int, batch: int, seq: int, *,
                         seed: int = 0, order: int = 2,
                         copy_frac: float = 0.3
                         ) -> Iterator[np.ndarray]:
    """Infinite stream of (batch, seq) int32 token arrays."""
    rng = np.random.default_rng(seed)
    V = vocab - _RESERVED
    # sparse Markov transition: each state has ~16 plausible successors
    fanout = min(16, V)
    succ = rng.integers(0, V, size=(V, fanout))
    while True:
        out = np.empty((batch, seq), np.int64)
        for b in range(batch):
            toks = [BOS]
            state = int(rng.integers(0, V))
            while len(toks) < seq:
                if rng.random() < copy_frac and len(toks) > 24:
                    # recall: repeat an earlier span, introduced by SEP
                    lo = int(rng.integers(0, len(toks) - 12))
                    ln = int(rng.integers(4, 12))
                    toks.append(SEP)
                    toks.extend(toks[lo:lo + ln])
                    toks.append(NL)
                else:
                    state = int(succ[state, rng.integers(0, fanout)])
                    toks.append(_RESERVED + state)
                    if rng.random() < 0.08:          # sentence-ish breaks
                        toks.append(NL if rng.random() < 0.5 else SEP)
            out[b] = toks[:seq]
        yield out.astype(np.int32)


def structured_retrieval_task(vocab: int, batch: int, n_records: int,
                              val_len: int = 4, *, seed: int = 0
                              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """KV-lookup prompts.

    Returns (tokens (B, S), answer (B, val_len), answer_pos (B,)): each
    prompt is ``BOS [key SEP v1..vk NL] * n QUERY key_q`` and the target is
    key_q's value. ``answer_pos`` is the position where the queried record's
    value starts (for retrieval-recall scoring).
    """
    rng = np.random.default_rng(seed)
    V = vocab - _RESERVED
    rec_len = 2 + val_len + 1            # key SEP vals NL
    S = 1 + n_records * rec_len + 2
    tokens = np.zeros((batch, S), np.int64)
    answers = np.zeros((batch, val_len), np.int64)
    apos = np.zeros((batch,), np.int64)
    for b in range(batch):
        keys = rng.choice(V, size=n_records, replace=False) + _RESERVED
        vals = rng.integers(0, V, size=(n_records, val_len)) + _RESERVED
        row = [BOS]
        for i in range(n_records):
            row += [int(keys[i]), SEP] + [int(x) for x in vals[i]] + [NL]
        q = int(rng.integers(0, n_records))
        row += [QUERY, int(keys[q])]
        tokens[b, :len(row)] = row
        answers[b] = vals[q]
        apos[b] = 1 + q * rec_len + 2
    return tokens.astype(np.int32), answers.astype(np.int32), apos
