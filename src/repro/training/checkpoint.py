"""Checkpointing: flat-key npz shards with a JSON manifest.

Params/optimizer pytrees are flattened to ``path.to.leaf`` keys and written
in size-bounded npz shards (one file per ~1GB by default) so restore can be
streamed. On a real multi-host cluster each host writes the shards of its
addressable data; on this single-host runtime that's shard 0 of 1.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}.{k}" if prefix else str(k), v)
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                rec(f"{prefix}[{i}]", v)
        elif node is None:
            pass
        else:
            flat[prefix] = node

    rec("", tree)
    return flat


def save(path: str, tree, *, step: int = 0,
         shard_bytes: int = 1 << 30) -> None:
    os.makedirs(path, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    shards, cur, cur_bytes = [], {}, 0
    for k, v in flat.items():
        if cur and cur_bytes + v.nbytes > shard_bytes:
            shards.append(cur)
            cur, cur_bytes = {}, 0
        cur[k] = v
        cur_bytes += v.nbytes
    if cur:
        shards.append(cur)
    manifest = {"step": step, "n_shards": len(shards),
                "keys": {k: [list(v.shape), str(v.dtype)]
                         for k, v in flat.items()}}
    for i, sh in enumerate(shards):
        np.savez(os.path.join(path, f"shard_{i:05d}.npz"), **sh)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def restore(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a pytree of arrays)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    for i in range(manifest["n_shards"]):
        with np.load(os.path.join(path, f"shard_{i:05d}.npz")) as z:
            flat.update({k: z[k] for k in z.files})

    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}.{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(rec(f"{prefix}[{i}]", v)
                         for i, v in enumerate(node))
        if isinstance(node, list):
            return [rec(f"{prefix}[{i}]", v) for i, v in enumerate(node)]
        if node is None:
            return None
        arr = flat[prefix]
        return jax.numpy.asarray(arr).astype(node.dtype).reshape(node.shape)

    return rec("", like), manifest["step"]
