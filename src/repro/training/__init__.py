from repro.training.data import synthetic_lm_batches
from repro.training.optimizer import (adamw_init, adamw_update, lr_schedule)
from repro.training.train_step import make_train_step

__all__ = ["adamw_init", "adamw_update", "lr_schedule",
           "make_train_step", "synthetic_lm_batches"]
