"""AdamW with cosine or WSD (warmup-stable-decay, MiniCPM) schedules.

Plain pytree implementation (no optax dependency). Optimizer state dtype is
configurable per-arch (``opt_state_dtype``): the 671B-class archs store
moments in bf16 so the optimizer fits the 512x16GB production mesh —
documented in DESIGN.md §5.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array     # () int32
    mu: object          # pytree like params
    nu: object          # pytree like params


def adamw_init(params, dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(z, params),
                      nu=jax.tree.map(z, params))


def lr_schedule(step, *, base_lr: float, total_steps: int,
                warmup: int = 100, kind: str = "cosine",
                stable_frac: float = 0.8) -> jax.Array:
    """kind: "cosine" | "wsd" (warmup -> stable plateau -> 1/sqrt decay,
    MiniCPM [arXiv:2404.06395 §4])."""
    s = jnp.asarray(step, jnp.float32)
    w = jnp.minimum(s / max(warmup, 1), 1.0)
    if kind == "wsd":
        stable_end = total_steps * stable_frac
        decay = jnp.where(
            s <= stable_end, 1.0,
            jnp.maximum(1.0 - (s - stable_end) /
                        max(total_steps - stable_end, 1), 0.1) ** 0.5)
        return base_lr * w * decay
    prog = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
    return base_lr * w * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def adamw_update(params, grads, state: AdamWState, lr,
                 *, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state, grad_norm)."""
    gflat = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in gflat))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    # three passes (XLA CSEs the shared moment math inside the jit)
    def moments(g, m, v):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        return m_new, v_new

    def upd_p(p, g, m, v):
        m_new, v_new = moments(g, m, v)
        delta = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
        decay = weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) - lr * (delta + decay *
                                              p.astype(jnp.float32))
        return p_new.astype(p.dtype)

    new_params = jax.tree.map(upd_p, params, grads, state.mu, state.nu)
    new_mu = jax.tree.map(
        lambda g, m, v: moments(g, m, v)[0].astype(m.dtype),
        grads, state.mu, state.nu)
    new_nu = jax.tree.map(
        lambda g, m, v: moments(g, m, v)[1].astype(v.dtype),
        grads, state.mu, state.nu)
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), gnorm
