"""Jitted, mesh-aware training step.

``make_train_step`` builds the pjit'd update function: grads of
``model.train_forward`` + AdamW, with in/out shardings derived from
``sharding.rules.param_specs`` when a mesh is supplied. This is the function
the multi-pod dry-run lowers for the ``train_4k`` input shape.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as MD
from repro.sharding.ctx import mesh_context
from repro.sharding.rules import param_specs
from repro.training.optimizer import (AdamWState, adamw_init, adamw_update,
                                      lr_schedule)


def make_train_step(cfg: ModelConfig, *, base_lr: float = 3e-4,
                    total_steps: int = 1000, mesh: Optional[Mesh] = None,
                    microbatch: int = 0):
    """Returns (train_step, init_state).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatch`` > 1 enables gradient accumulation (§Perf iteration 2b):
    the global batch is split into ``microbatch`` slices processed by a
    lax.scan that accumulates mean gradients — live activation memory
    shrinks ~microbatch× for one extra params-sized buffer. Numerics are
    identical (mean of per-slice mean grads at equal slice sizes).
    """

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = MD.train_forward(p, batch, cfg)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step_fn(params, opt_state: AdamWState, batch):
        if microbatch > 1:
            def split(x):
                return x.reshape((microbatch, x.shape[0] // microbatch)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_step(carry, b):
                loss_acc, mets_acc, grads_acc = carry
                (loss, mets), g = grads_of(params, b)
                grads_acc = jax.tree.map(
                    lambda a, gi: a + gi / microbatch, grads_acc, g)
                mets_acc = jax.tree.map(
                    lambda a, m: a + m / microbatch, mets_acc, mets)
                return (loss_acc + loss / microbatch, mets_acc,
                        grads_acc), None

            out_shapes = jax.eval_shape(
                grads_of, params, jax.tree.map(lambda x: x[0], mb))
            (_, mets_s), grads_s = out_shapes
            init = (jnp.zeros(()),
                    jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 mets_s),
                    jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                 grads_s))
            (loss, metrics, grads), _ = jax.lax.scan(acc_step, init, mb)
        else:
            (loss, metrics), grads = grads_of(params, batch)
        lr = lr_schedule(opt_state.step, base_lr=base_lr,
                         total_steps=total_steps, kind=cfg.lr_schedule)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    def init_state(params) -> AdamWState:
        return adamw_init(params, cfg.opt_state_dtype)

    if mesh is None:
        return jax.jit(step_fn), init_state

    with mesh_context(mesh):
        pspecs = param_specs(jax.eval_shape(
            lambda: MD.init_model(jax.random.key(0), cfg)), cfg, mesh)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    opt_shard = AdamWState(step=NamedSharding(mesh, P()),
                           mu=p_shard, nu=p_shard)
    batch_spec = P(("pod", "data") if "pod" in mesh.axis_names else "data")
    b_shard = NamedSharding(mesh, batch_spec)

    def batch_shardings(batch):
        return {k: b_shard for k in batch}

    def jitted(params, opt_state, batch):
        fn = jax.jit(
            step_fn,
            in_shardings=(p_shard, opt_shard, batch_shardings(batch)),
            out_shardings=(p_shard, opt_shard, None))
        with mesh_context(mesh):
            return fn(params, opt_state, batch)

    return jitted, init_state
