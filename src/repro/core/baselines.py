"""Baseline sparse-attention selectors the paper compares against (§5.1).

All emit the same ``(token_idx, token_mask)`` per-kv-head interface as
:mod:`repro.core.retrieval`, so they share the exact-attention executor and
the Pallas kernel — the comparison isolates the *selection* policy, exactly
like the paper's pilot study holds the scoring metric fixed.

* Quest (Tang et al., 2024): fixed-size pages with per-page min/max key
  statistics; page score = Σ_d max(q_d·min_d, q_d·max_d) (their Eq. 3 upper
  bound); top-(budget/page) pages retrieved.
* ClusterKV (Liu et al., 2025a): token-level spherical k-means in semantic
  space; clusters ranked by qᵀμ; tokens of the top clusters retrieved until
  the budget is filled.
* StreamingLLM (Xiao et al., 2024): sinks + sliding window only (an
  eviction-style lower bound — selection returns nothing extra).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LycheeConfig
from repro.core.kmeans import spherical_kmeans
from repro.core.pooling import l2_normalize

_NEG = -1e30


# ---------------------------------------------------------------------------
# Quest
# ---------------------------------------------------------------------------
class QuestIndex(NamedTuple):
    kmin: jax.Array   # (H, Pg, d) per-page elementwise min of keys
    kmax: jax.Array   # (H, Pg, d)
    valid: jax.Array  # (H, Pg)
    page: int


def build_quest(keys: jax.Array, page: int = 16, n_tokens=None) -> QuestIndex:
    """keys: (H, N, d). Pages are fixed [i*page, (i+1)*page) ranges."""
    H, N, d = keys.shape
    Pg = (N + page - 1) // page
    pad = Pg * page - N
    kp = jnp.pad(keys, ((0, 0), (0, pad), (0, 0)))
    t = jnp.int32(N) if n_tokens is None else jnp.asarray(n_tokens, jnp.int32)
    pos = jnp.arange(Pg * page)
    tmask = (pos < t).reshape(Pg, page)
    kp = kp.reshape(H, Pg, page, d)
    big = jnp.where(tmask[None, :, :, None], kp, jnp.inf)
    small = jnp.where(tmask[None, :, :, None], kp, -jnp.inf)
    kmin = jnp.min(big, axis=2)
    kmax = jnp.max(small, axis=2)
    valid = jnp.any(tmask, axis=1)[None].repeat(H, 0)
    kmin = jnp.where(valid[..., None], kmin, 0.0)
    kmax = jnp.where(valid[..., None], kmax, 0.0)
    return QuestIndex(kmin=kmin, kmax=kmax, valid=valid, page=page)


def quest_select(qidx: QuestIndex, probe: jax.Array, budget: int):
    """probe: (H, d). Returns (token_idx (H, S), token_mask)."""
    H, Pg, d = qidx.kmin.shape
    page = qidx.page
    k_pages = max(1, min(budget // page, Pg))

    def per_head(h):
        q = probe[h]
        score = jnp.sum(jnp.maximum(q * qidx.kmin[h], q * qidx.kmax[h]), -1)
        score = jnp.where(qidx.valid[h], score, _NEG)
        top_s, top_p = jax.lax.top_k(score, k_pages)
        pmask = top_s > _NEG / 2
        tok = (top_p[:, None] * page
               + jnp.arange(page, dtype=jnp.int32)).reshape(-1)
        mask = jnp.repeat(pmask, page)
        return tok, mask

    return jax.vmap(per_head)(jnp.arange(H))


# ---------------------------------------------------------------------------
# ClusterKV
# ---------------------------------------------------------------------------
class ClusterKVIndex(NamedTuple):
    centroid: jax.Array   # (H, C, d)
    valid: jax.Array      # (H, C)
    members: jax.Array    # (H, C, cap) token ids, -1 pad
    nmember: jax.Array    # (H, C)


def build_clusterkv(keys: jax.Array, tokens_per_cluster: int = 32,
                    cap_factor: int = 4, iters: int = 10,
                    n_tokens=None) -> ClusterKVIndex:
    """Token-granular spherical clustering. keys: (H, N, d)."""
    from repro.core.index import build_member_lists
    H, N, d = keys.shape
    C = max(1, N // tokens_per_cluster)
    cap = tokens_per_cluster * cap_factor
    t = jnp.int32(N) if n_tokens is None else jnp.asarray(n_tokens, jnp.int32)
    mask = jnp.arange(N) < t
    kn = l2_normalize(keys) * mask[None, :, None]

    def per_head(kh):
        km = spherical_kmeans(kh, mask, C, iters)
        members, nm = build_member_lists(km.assign, mask, C, cap)
        return km.centroid, km.valid, members, nm

    cent, valid, members, nm = jax.vmap(per_head)(kn)
    return ClusterKVIndex(centroid=cent, valid=valid, members=members,
                          nmember=nm)


def clusterkv_select(cidx: ClusterKVIndex, probe: jax.Array, budget: int,
                     tokens_per_cluster: int = 32):
    H, C, d = cidx.centroid.shape
    cap = cidx.members.shape[-1]
    k_cl = max(1, min(budget // tokens_per_cluster, C))

    def per_head(h):
        score = jnp.einsum("cd,d->c", cidx.centroid[h], probe[h])
        score = jnp.where(cidx.valid[h], score, _NEG)
        top_s, top_c = jax.lax.top_k(score, k_cl)
        cmask = top_s > _NEG / 2
        tok = cidx.members[h][top_c].reshape(-1)
        mask = (tok >= 0) & jnp.repeat(cmask, cap)
        return jnp.maximum(tok, 0), mask

    return jax.vmap(per_head)(jnp.arange(H))


# ---------------------------------------------------------------------------
# StreamingLLM (sink + window only)
# ---------------------------------------------------------------------------
def streaming_select(H: int, cfg: LycheeConfig):
    """Retrieves nothing: active set = sinks + recent buffer only."""
    tok = jnp.zeros((H, 1), jnp.int32)
    mask = jnp.zeros((H, 1), bool)
    return tok, mask
