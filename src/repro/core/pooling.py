"""Chunk representative keys (paper §4.1, §4.3, Table 3 ablation).

Mean pooling over each chunk's token keys followed by L2 normalisation
("the geometric centroid of the chunk on the unit sphere"), with a max-pool
variant for the Table-3 ablation. The Pallas fast path lives in
``repro.kernels.chunk_pool``; this module is the pure-jnp implementation
used as its oracle and as the general fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ChunkLayout

_EPS = 1e-6


def l2_normalize(x: jax.Array, axis: int = -1) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.sum(x * x, axis=axis, keepdims=True) + _EPS)


def pool_chunks(keys: jax.Array, layout: ChunkLayout, M: int,
                pooling: str = "mean", n_tokens=None) -> jax.Array:
    """Pool token keys into chunk representative keys.

    keys: (..., N, d) — arbitrary leading dims (e.g. kv heads).
    Returns (..., M, d), L2-normalised; padding chunks are zero.
    """
    N = keys.shape[-2]
    seg = layout.seg_id                                   # (N,)
    token_valid = jnp.arange(N) < (jnp.int32(N) if n_tokens is None
                                   else jnp.asarray(n_tokens, jnp.int32))
    seg_safe = jnp.where(token_valid, seg, M)             # dump pad into slot M

    def _pool(k2d):                                       # (N, d) -> (M, d)
        if pooling == "mean":
            s = jax.ops.segment_sum(k2d, seg_safe, num_segments=M + 1)
            cnt = jax.ops.segment_sum(
                jnp.ones((N, 1), k2d.dtype), seg_safe, num_segments=M + 1)
            pooled = s / jnp.maximum(cnt, 1.0)
        elif pooling == "max":
            pooled = jax.ops.segment_max(
                jnp.where(token_valid[:, None], k2d, -jnp.inf),
                seg_safe, num_segments=M + 1)
            pooled = jnp.where(jnp.isfinite(pooled), pooled, 0.0)
        else:
            raise ValueError(f"unknown pooling {pooling!r}")
        return pooled[:M]

    flat = keys.reshape((-1,) + keys.shape[-2:])
    pooled = jax.vmap(_pool)(flat)
    pooled = l2_normalize(pooled)
    pooled = jnp.where(layout.valid[:, None], pooled, 0.0)
    return pooled.reshape(keys.shape[:-2] + (M, keys.shape[-1]))
