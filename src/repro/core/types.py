"""Pytree containers for the hierarchical KV index (paper §4.1/§4.3).

All shapes are STATIC (TPU adaptation, DESIGN.md §2): variable-length
structures become fixed-capacity arrays + validity masks. Leading dims may be
batched/stacked: a per-layer index inside a scanned decoder carries a
``(groups, batch, ...)`` prefix; the functions in core/ operate on the
*unbatched* layout documented below and are vmapped/scanned by callers.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LycheeConfig


class ChunkLayout(NamedTuple):
    """Result of structure-aware chunking over one token sequence.

    M = static max number of chunks (= ceil(N / min_chunk)).
    """

    start: jax.Array    # (M,) int32 — first token position of each chunk
    length: jax.Array   # (M,) int32 — number of tokens (0 for padding slots)
    valid: jax.Array    # (M,) bool
    seg_id: jax.Array   # (N,) int32 — token -> chunk id (M-1 clamp for pad)
    count: jax.Array    # ()  int32 — number of real chunks


class LycheeIndex(NamedTuple):
    """Three-tier index for ONE (layer, batch element): coarse -> fine -> chunk.

    H = kv heads, M = max chunks, L = max fine clusters, P = max coarse
    units, CC = chunk capacity per fine cluster, FC = fine capacity per
    coarse unit, d = head_dim.
    """

    # chunk level -----------------------------------------------------------
    chunk_key: jax.Array      # (H, M, d)  pooled + L2-normalised keys
    chunk_start: jax.Array    # (M,) int32
    chunk_len: jax.Array      # (M,) int32
    chunk_valid: jax.Array    # (M,) bool
    chunk_count: jax.Array    # () int32   cursor for lazy appends

    # fine cluster level ----------------------------------------------------
    fine_centroid: jax.Array  # (H, L, d)
    fine_radius: jax.Array    # (H, L)
    fine_size: jax.Array      # (H, L) int32   members (for moving average)
    fine_valid: jax.Array     # (H, L) bool
    fine_chunks: jax.Array    # (H, L, CC) int32  member chunk ids
    fine_nchunks: jax.Array   # (H, L) int32

    # coarse unit level -----------------------------------------------------
    coarse_centroid: jax.Array  # (H, P, d)
    coarse_radius: jax.Array    # (H, P)
    coarse_size: jax.Array      # (H, P) int32
    coarse_valid: jax.Array     # (H, P) bool
    coarse_children: jax.Array  # (H, P, FC) int32  member fine-cluster ids
    coarse_nchild: jax.Array    # (H, P) int32
    fine2coarse: jax.Array      # (H, L) int32


def cache_slack(cfg: LycheeConfig) -> int:
    """Tail-slack rows RESERVED at the end of every policy-capable KV cache.

    The Pallas sparse-attention kernel fetches each retrieved span with ONE
    contiguous DMA of ``span_len`` rows (``span_len`` = ``max_chunk`` for
    lychee/streaming, ``quest_page`` for quest, 1 for clusterkv). A span may
    start at any written position ``<= t - 1``, so the last ``max(max_chunk,
    quest_page)`` rows of the allocation are kept write-free (the serving
    engine admits requests only up to :func:`usable_rows`) and the DMA past
    ``t`` lands on allocated, zero rows *by construction* — the alternative
    (the pre-slack design) was an O(N) ``jnp.pad`` copy of the whole cache
    on every decode step. Rounded up to a multiple of 8 to keep the
    boundary sublane-aligned.

    The slack lives INSIDE the ``n_cache`` allocation rather than extending
    it: cache row counts — and everything derived from them (index/page/
    cluster capacities, context-dim shard splits) — stay exactly as they
    were, so the 512-way mesh divisibility of the decode dry-runs is
    untouched. Slack rows are zero, never written, never selected, and
    masked by every executor, so numerics are unchanged everywhere.
    """
    return -(-max(cfg.max_chunk, cfg.quest_page, 1) // 8) * 8


def usable_rows(n_cache: int, cfg: LycheeConfig) -> int:
    """Serveable positions of an ``n_cache``-row cache: the tail
    ``cache_slack`` rows are the kernel's DMA-overrun region and must never
    be written (``prompt_len + max_new <= usable_rows`` — enforced by the
    engine)."""
    return n_cache - cache_slack(cfg)


def index_dims(N: int, cfg: LycheeConfig):
    """Static capacities for a context of N tokens. The chunk capacity per
    fine cluster (CC) comes from ``cfg.chunk_cap`` — capacity planning has
    one source of truth."""
    M = max(1, (N + cfg.min_chunk - 1) // cfg.min_chunk)
    L = max(1, M // cfg.avg_chunks_per_cluster)
    P = min(cfg.max_coarse, L)
    FC = max(cfg.child_cap, 2 * ((L + P - 1) // P))
    return M, L, P, cfg.chunk_cap, FC


def empty_index_like(index: LycheeIndex) -> LycheeIndex:
    """A fresh (all-invalid, cursor-0) index with the same static shapes.

    Zero leaves ARE the empty index: every validity mask is False and both
    count cursors are 0, so retrieval masks everything and ``lazy_update``
    appends from slot 0 — the contract a recycled serving slot relies on.
    """
    return jax.tree.map(jnp.zeros_like, index)


def pad_index(index: LycheeIndex, N_cap: int, cfg: LycheeConfig
              ) -> LycheeIndex:
    """Grow an index built over a short prompt to the STATIC capacities of an
    ``N_cap``-token cache (continuous batching: every serving slot must carry
    identical leaf shapes regardless of the admitted prompt's length, so a
    freed slot can be overwritten by any request's state).

    Padded chunk/fine/coarse slots are invalid (``valid=False``); member
    lists pad with -1 (the "no member" sentinel the retrieval masks honour).
    The ``chunk_count`` cursor is untouched, so decode-time ``lazy_update``
    grafts dynamic chunks into the new headroom.
    """
    H, M, d = index.chunk_key.shape
    L = index.fine_centroid.shape[1]
    P = index.coarse_centroid.shape[1]
    CC = index.fine_chunks.shape[-1]
    FC = index.coarse_children.shape[-1]
    M2, L2, P2, CC2, FC2 = index_dims(N_cap, cfg)
    M2, L2, P2, FC2 = (max(M2, M), max(L2, L), max(P2, P), max(FC2, FC))
    if (M2, L2, P2, FC2) == (M, L, P, FC):
        return index

    def pad(x, axis, n, fill=0):
        if n == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, n)
        return jnp.pad(x, widths, constant_values=fill)

    return index._replace(
        chunk_key=pad(index.chunk_key, 1, M2 - M),
        chunk_start=pad(index.chunk_start, 0, M2 - M),
        chunk_len=pad(index.chunk_len, 0, M2 - M),
        chunk_valid=pad(index.chunk_valid, 0, M2 - M),
        fine_centroid=pad(index.fine_centroid, 1, L2 - L),
        fine_radius=pad(index.fine_radius, 1, L2 - L),
        fine_size=pad(index.fine_size, 1, L2 - L),
        fine_valid=pad(index.fine_valid, 1, L2 - L),
        fine_chunks=pad(pad(index.fine_chunks, 1, L2 - L, fill=-1),
                        2, CC2 - CC, fill=-1),
        fine_nchunks=pad(index.fine_nchunks, 1, L2 - L),
        coarse_centroid=pad(index.coarse_centroid, 1, P2 - P),
        coarse_radius=pad(index.coarse_radius, 1, P2 - P),
        coarse_size=pad(index.coarse_size, 1, P2 - P),
        coarse_valid=pad(index.coarse_valid, 1, P2 - P),
        coarse_children=pad(pad(index.coarse_children, 1, P2 - P, fill=-1),
                            2, FC2 - FC, fill=-1),
        coarse_nchild=pad(index.coarse_nchild, 1, P2 - P),
        fine2coarse=pad(index.fine2coarse, 1, L2 - L),
    )


def empty_index(N: int, H: int, d: int, cfg: LycheeConfig,
                dtype=jnp.float32) -> LycheeIndex:
    M, L, P, CC, FC = index_dims(N, cfg)
    f = jnp.zeros
    return LycheeIndex(
        chunk_key=f((H, M, d), dtype),
        chunk_start=f((M,), jnp.int32),
        chunk_len=f((M,), jnp.int32),
        chunk_valid=f((M,), bool),
        chunk_count=jnp.zeros((), jnp.int32),
        fine_centroid=f((H, L, d), dtype),
        fine_radius=f((H, L), dtype),
        fine_size=f((H, L), jnp.int32),
        fine_valid=f((H, L), bool),
        fine_chunks=f((H, L, CC), jnp.int32),
        fine_nchunks=f((H, L), jnp.int32),
        coarse_centroid=f((H, P, d), dtype),
        coarse_radius=f((H, P), dtype),
        coarse_size=f((H, P), jnp.int32),
        coarse_valid=f((H, P), bool),
        coarse_children=f((H, P, FC), jnp.int32),
        coarse_nchild=f((H, P), jnp.int32),
        fine2coarse=f((H, L), jnp.int32),
    )
