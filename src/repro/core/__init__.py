"""LycheeCluster core: the paper's contribution as composable JAX modules."""
from repro.core.attention import (full_decode_attention,
                                  sparse_decode_attention)
from repro.core.chunking import (byte_delimiter_table, chunk_sequence,
                                 fixed_chunking, synthetic_delimiter_table)
from repro.core.index import build_index
from repro.core.kmeans import spherical_kmeans
from repro.core.policy import (CachePolicy, list_policies, make_policy,
                               policy_for, register_policy, spans_to_tokens)
from repro.core.pooling import l2_normalize, pool_chunks
from repro.core.retrieval import Retrieval, retrieve, retrieve_dense, ub_scores
from repro.core.types import (ChunkLayout, LycheeIndex, empty_index,
                              empty_index_like, index_dims, pad_index)
from repro.core.update import lazy_update, maybe_lazy_update, reset_index

__all__ = [
    "CachePolicy", "ChunkLayout", "LycheeIndex", "Retrieval", "build_index",
    "byte_delimiter_table", "chunk_sequence", "empty_index",
    "empty_index_like", "fixed_chunking", "full_decode_attention",
    "index_dims", "l2_normalize", "lazy_update", "list_policies",
    "make_policy", "maybe_lazy_update", "pad_index", "policy_for",
    "pool_chunks", "register_policy", "reset_index", "retrieve",
    "retrieve_dense", "sparse_decode_attention", "spans_to_tokens",
    "spherical_kmeans", "synthetic_delimiter_table", "ub_scores",
]
