"""Top-down pruning retrieval (paper §4.4, Algorithm 1 steps 1-2).

Score upper bound (Eqn. 2):  UB(q, u) = qᵀμ_u + ‖q‖₂ · r_u.

Coarse level: score all P units (one small matvec per kv head), keep top-k_g.
Fine level: gather ONLY the children lists of the surviving units (static
(k_g · FC) candidates) and keep top-k_c. Chunk level: the selected clusters'
member chunks expand into token indices. All shapes static; padding is
masked to -inf before every top-k. ``retrieve_dense`` scores every fine
cluster (no coarse pruning) — it is the exactness oracle for the capped
child lists and the ClusterKV-style single-level comparison point.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LycheeConfig
from repro.core.types import LycheeIndex

_NEG = -1e30


class Retrieval(NamedTuple):
    token_idx: jax.Array    # (H, S) int32 gathered token positions
    token_mask: jax.Array   # (H, S) bool
    fine_ids: jax.Array     # (H, kc) selected fine clusters (for stability
    fine_mask: jax.Array    # (H, kc)  metrics, Fig. 9)
    coarse_ids: jax.Array   # (H, kg)


def ub_scores(q: jax.Array, centroid: jax.Array, radius: jax.Array,
              valid: jax.Array) -> jax.Array:
    """UB(q, u) per Eqn. 2. q: (d,), centroid: (n, d), radius/valid: (n,)."""
    qn = jnp.linalg.norm(q)
    s = centroid @ q + qn * radius
    return jnp.where(valid, s, _NEG)


def _expand_tokens(index: LycheeIndex, head: int, fine_ids: jax.Array,
                   fine_mask: jax.Array, max_chunk: int):
    """fine cluster ids (kc,) -> token indices (kc * CC * max_chunk,)."""
    CC = index.fine_chunks.shape[-1]
    chunks = index.fine_chunks[head][fine_ids]              # (kc, CC)
    cmask = (chunks >= 0) & fine_mask[:, None]
    chunks_safe = jnp.maximum(chunks, 0)
    start = index.chunk_start[chunks_safe]                  # (kc, CC)
    length = jnp.where(cmask, index.chunk_len[chunks_safe], 0)
    offs = jnp.arange(max_chunk, dtype=jnp.int32)
    tok = start[..., None] + offs                           # (kc, CC, mc)
    tmask = offs < length[..., None]
    return tok.reshape(-1), tmask.reshape(-1)


def retrieve(index: LycheeIndex, probe: jax.Array, cfg: LycheeConfig,
             budget: int | None = None) -> Retrieval:
    """Hierarchical retrieval for one (layer, batch element).

    probe: (H, d) one query probe per kv head (GQA group mean).
    """
    H, d = probe.shape
    kg = cfg.top_kg
    kc = cfg.top_kc(budget)
    FC = index.coarse_children.shape[-1]

    def per_head(h):
        q = probe[h]
        # ---- Step 1: coarse-level pruning ------------------------------
        sg = ub_scores(q, index.coarse_centroid[h], index.coarse_radius[h],
                       index.coarse_valid[h])
        _, top_g = jax.lax.top_k(sg, min(kg, sg.shape[0]))          # (kg,)
        # ---- Step 2: fine-level pruning over gathered children ---------
        cand = index.coarse_children[h][top_g].reshape(-1)          # (kg*FC,)
        cmask = cand >= 0
        cand_safe = jnp.maximum(cand, 0)
        mu = index.fine_centroid[h][cand_safe]
        rr = index.fine_radius[h][cand_safe]
        vv = index.fine_valid[h][cand_safe] & cmask
        sc = ub_scores(q, mu, rr, vv)
        k_eff = min(kc, sc.shape[0])
        top_s, top_i = jax.lax.top_k(sc, k_eff)
        fine_ids = cand_safe[top_i]
        fine_mask = top_s > _NEG / 2
        if k_eff < kc:  # pad to static kc
            fine_ids = jnp.pad(fine_ids, (0, kc - k_eff))
            fine_mask = jnp.pad(fine_mask, (0, kc - k_eff))
        # ---- Step 3 prep: expand chunks into token indices -------------
        tok, tmask = _expand_tokens(index, h, fine_ids, fine_mask,
                                    cfg.max_chunk)
        return tok, tmask, fine_ids, fine_mask, top_g

    tok, tmask, fids, fmask, gids = jax.vmap(per_head)(jnp.arange(H))
    return Retrieval(token_idx=tok, token_mask=tmask, fine_ids=fids,
                     fine_mask=fmask, coarse_ids=gids)


def retrieve_spans(index: LycheeIndex, probe: jax.Array, cfg: LycheeConfig,
                   budget: int | None = None):
    """Like :func:`retrieve` but emits CHUNK SPANS — the TPU-native active-set
    form consumed by the Pallas sparse-attention kernel (each span is one
    contiguous DMA). Returns (starts (H, kc*CC), lens (H, kc*CC), ret).
    """
    ret = retrieve(index, probe, cfg, budget)
    H, kc = ret.fine_ids.shape
    CC = index.fine_chunks.shape[-1]

    def per_head(h):
        chunks = index.fine_chunks[h][ret.fine_ids[h]]          # (kc, CC)
        cmask = (chunks >= 0) & ret.fine_mask[h][:, None]
        cs = jnp.maximum(chunks, 0)
        starts = jnp.where(cmask, index.chunk_start[cs], 0)
        lens = jnp.where(cmask, index.chunk_len[cs], 0)
        return starts.reshape(-1), lens.reshape(-1)

    starts, lens = jax.vmap(per_head)(jnp.arange(H))
    return starts, lens, ret


def retrieve_dense(index: LycheeIndex, probe: jax.Array, cfg: LycheeConfig,
                   budget: int | None = None) -> Retrieval:
    """Single-level oracle: scores ALL fine clusters (no coarse pruning)."""
    H, d = probe.shape
    kc = cfg.top_kc(budget)
    kg = cfg.top_kg

    def per_head(h):
        q = probe[h]
        sc = ub_scores(q, index.fine_centroid[h], index.fine_radius[h],
                       index.fine_valid[h])
        k_eff = min(kc, sc.shape[0])
        top_s, fine_ids = jax.lax.top_k(sc, k_eff)
        fine_mask = top_s > _NEG / 2
        if k_eff < kc:
            fine_ids = jnp.pad(fine_ids, (0, kc - k_eff))
            fine_mask = jnp.pad(fine_mask, (0, kc - k_eff))
        tok, tmask = _expand_tokens(index, h, fine_ids, fine_mask,
                                    cfg.max_chunk)
        P = index.coarse_valid.shape[-1]
        return tok, tmask, fine_ids, fine_mask, jnp.zeros((min(kg, P),),
                                                          jnp.int32)

    tok, tmask, fids, fmask, gids = jax.vmap(per_head)(jnp.arange(H))
    return Retrieval(token_idx=tok, token_mask=tmask, fine_ids=fids,
                     fine_mask=fmask, coarse_ids=gids)
