"""Top-down pruning retrieval (paper §4.4, Algorithm 1 steps 1-2).

Score upper bound (Eqn. 2):  UB(q, u) = qᵀμ_u + ‖q‖₂ · r_u.

Coarse level: score all P units (one small matvec per kv head), keep top-k_g.
Fine level: gather ONLY the children lists of the surviving units (static
(k_g · FC) candidates) and keep top-k_c. Chunk level: the selected clusters'
member chunks expand into token indices. All shapes static; padding is
masked to -inf before every top-k. ``retrieve_dense`` scores every fine
cluster (no coarse pruning) — it is the exactness oracle for the capped
child lists and the ClusterKV-style single-level comparison point.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import LycheeConfig
from repro.core.types import LycheeIndex

_NEG = -1e30


class Retrieval(NamedTuple):
    token_idx: jax.Array    # (H, S) int32 gathered token positions
    token_mask: jax.Array   # (H, S) bool
    fine_ids: jax.Array     # (H, kc) selected fine clusters (for stability
    fine_mask: jax.Array    # (H, kc)  metrics, Fig. 9)
    coarse_ids: jax.Array   # (H, kg)


def ub_scores(q: jax.Array, centroid: jax.Array, radius: jax.Array,
              valid: jax.Array) -> jax.Array:
    """UB(q, u) per Eqn. 2. q: (d,), centroid: (n, d), radius/valid: (n,)."""
    qn = jnp.linalg.norm(q)
    s = centroid @ q + qn * radius
    return jnp.where(valid, s, _NEG)


def _expand_tokens(index: LycheeIndex, head: int, fine_ids: jax.Array,
                   fine_mask: jax.Array, max_chunk: int):
    """fine cluster ids (kc,) -> token indices (kc * CC * max_chunk,)."""
    CC = index.fine_chunks.shape[-1]
    chunks = index.fine_chunks[head][fine_ids]              # (kc, CC)
    cmask = (chunks >= 0) & fine_mask[:, None]
    chunks_safe = jnp.maximum(chunks, 0)
    start = index.chunk_start[chunks_safe]                  # (kc, CC)
    length = jnp.where(cmask, index.chunk_len[chunks_safe], 0)
    offs = jnp.arange(max_chunk, dtype=jnp.int32)
    tok = start[..., None] + offs                           # (kc, CC, mc)
    tmask = offs < length[..., None]
    return tok.reshape(-1), tmask.reshape(-1)


def _select_fine(index: LycheeIndex, head: int, q: jax.Array,
                 cfg: LycheeConfig, budget: int | None):
    """Steps 1-2 of Algorithm 1 for ONE head: coarse pruning then fine
    top-k over the survivors' gathered children. Shared by the token-level
    (:func:`retrieve`) and span-level (:func:`retrieve_spans`) consumers.
    Returns (fine_ids (kc,), fine_mask (kc,), coarse_ids (kg,))."""
    kg = cfg.top_kg
    kc = cfg.top_kc(budget)
    # ---- Step 1: coarse-level pruning ----------------------------------
    sg = ub_scores(q, index.coarse_centroid[head], index.coarse_radius[head],
                   index.coarse_valid[head])
    _, top_g = jax.lax.top_k(sg, min(kg, sg.shape[0]))              # (kg,)
    # ---- Step 2: fine-level pruning over gathered children -------------
    cand = index.coarse_children[head][top_g].reshape(-1)           # (kg*FC,)
    cmask = cand >= 0
    cand_safe = jnp.maximum(cand, 0)
    mu = index.fine_centroid[head][cand_safe]
    rr = index.fine_radius[head][cand_safe]
    vv = index.fine_valid[head][cand_safe] & cmask
    sc = ub_scores(q, mu, rr, vv)
    k_eff = min(kc, sc.shape[0])
    top_s, top_i = jax.lax.top_k(sc, k_eff)
    fine_ids = cand_safe[top_i]
    fine_mask = top_s > _NEG / 2
    if k_eff < kc:  # pad to static kc
        fine_ids = jnp.pad(fine_ids, (0, kc - k_eff))
        fine_mask = jnp.pad(fine_mask, (0, kc - k_eff))
    return fine_ids, fine_mask, top_g


def retrieve(index: LycheeIndex, probe: jax.Array, cfg: LycheeConfig,
             budget: int | None = None) -> Retrieval:
    """Hierarchical retrieval for one (layer, batch element).

    probe: (H, d) one query probe per kv head (GQA group mean).
    """
    H, d = probe.shape

    def per_head(h):
        fine_ids, fine_mask, top_g = _select_fine(index, h, probe[h], cfg,
                                                  budget)
        # ---- Step 3 prep: expand chunks into token indices -------------
        tok, tmask = _expand_tokens(index, h, fine_ids, fine_mask,
                                    cfg.max_chunk)
        return tok, tmask, fine_ids, fine_mask, top_g

    tok, tmask, fids, fmask, gids = jax.vmap(per_head)(jnp.arange(H))
    return Retrieval(token_idx=tok, token_mask=tmask, fine_ids=fids,
                     fine_mask=fmask, coarse_ids=gids)


class SpanRetrieval(NamedTuple):
    """Cluster-selection record of a span-form retrieval (stability
    metrics); the token expansion the span path never materialises is
    deliberately absent."""

    fine_ids: jax.Array     # (H, kc)
    fine_mask: jax.Array    # (H, kc)
    coarse_ids: jax.Array   # (H, kg)


def retrieve_spans(index: LycheeIndex, probe: jax.Array, cfg: LycheeConfig,
                   budget: int | None = None):
    """Like :func:`retrieve` but emits CHUNK SPANS — the TPU-native active-set
    form consumed by the Pallas sparse-attention kernel (each span is one
    contiguous DMA). The decode hot path: unlike :func:`retrieve`, the
    (H, kc*CC*max_chunk) token expansion is never built — span consumers
    gather/DMA whole chunks, so only the (kc*CC,) span table materialises.
    Returns (starts (H, kc*CC), lens (H, kc*CC), :class:`SpanRetrieval`).
    """
    H, d = probe.shape

    def per_head(h):
        fine_ids, fine_mask, top_g = _select_fine(index, h, probe[h], cfg,
                                                  budget)
        chunks = index.fine_chunks[h][fine_ids]                 # (kc, CC)
        cmask = (chunks >= 0) & fine_mask[:, None]
        cs = jnp.maximum(chunks, 0)
        starts = jnp.where(cmask, index.chunk_start[cs], 0)
        lens = jnp.where(cmask, index.chunk_len[cs], 0)
        return (starts.reshape(-1), lens.reshape(-1), fine_ids, fine_mask,
                top_g)

    starts, lens, fids, fmask, gids = jax.vmap(per_head)(jnp.arange(H))
    return starts, lens, SpanRetrieval(fine_ids=fids, fine_mask=fmask,
                                       coarse_ids=gids)


def retrieve_dense(index: LycheeIndex, probe: jax.Array, cfg: LycheeConfig,
                   budget: int | None = None) -> Retrieval:
    """Single-level oracle: scores ALL fine clusters (no coarse pruning)."""
    H, d = probe.shape
    kc = cfg.top_kc(budget)
    kg = cfg.top_kg

    def per_head(h):
        q = probe[h]
        sc = ub_scores(q, index.fine_centroid[h], index.fine_radius[h],
                       index.fine_valid[h])
        k_eff = min(kc, sc.shape[0])
        top_s, fine_ids = jax.lax.top_k(sc, k_eff)
        fine_mask = top_s > _NEG / 2
        if k_eff < kc:
            fine_ids = jnp.pad(fine_ids, (0, kc - k_eff))
            fine_mask = jnp.pad(fine_mask, (0, kc - k_eff))
        tok, tmask = _expand_tokens(index, h, fine_ids, fine_mask,
                                    cfg.max_chunk)
        P = index.coarse_valid.shape[-1]
        return tok, tmask, fine_ids, fine_mask, jnp.zeros((min(kg, P),),
                                                          jnp.int32)

    tok, tmask, fids, fmask, gids = jax.vmap(per_head)(jnp.arange(H))
    return Retrieval(token_idx=tok, token_mask=tmask, fine_ids=fids,
                     fine_mask=fmask, coarse_ids=gids)
