"""Hierarchical KV index construction (paper §4.3, Algorithm 1 phase 1).

Bottom-up build: pooled chunk keys -> spherical k-means into L fine clusters
(avg ``avg_chunks_per_cluster`` chunks each) -> the L centroids re-clustered
into P <= 64 coarse units. Membership lists (fine -> chunks, coarse -> fine)
are materialised as fixed-capacity index arrays so decode-time traversal is
pure gathers (TPU adaptation, DESIGN.md §2).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LycheeConfig
from repro.core.chunking import ChunkLayout
from repro.core.kmeans import spherical_kmeans
from repro.core.pooling import pool_chunks
from repro.core.types import LycheeIndex, index_dims


def build_member_lists(assign: jax.Array, mask: jax.Array, L: int,
                       cap: int) -> Tuple[jax.Array, jax.Array]:
    """Invert an assignment vector into fixed-capacity membership lists.

    assign: (M,) int32 parent ids in [0, L); mask: (M,) bool.
    Returns (lists (L, cap) int32 with -1 padding, counts (L,) int32).
    Members beyond ``cap`` are dropped (counted in ``counts`` though, so
    callers can monitor overflow).
    """
    M = assign.shape[0]
    parked = jnp.where(mask, assign, L)
    order = jnp.argsort(parked)                  # stable, groups members
    sorted_parent = parked[order]
    counts_full = jax.ops.segment_sum(
        jnp.ones((M,), jnp.int32), parked, num_segments=L + 1)
    starts = jnp.cumsum(counts_full) - counts_full          # (L+1,)
    rank = jnp.arange(M, dtype=jnp.int32) - starts[sorted_parent]
    keep = (sorted_parent < L) & (rank < cap)
    lists = jnp.full((L, cap), -1, jnp.int32)
    lists = lists.at[
        jnp.where(keep, sorted_parent, L),
        jnp.where(keep, rank, 0)].set(order.astype(jnp.int32), mode="drop")
    return lists, counts_full[:L]


def build_index(keys: jax.Array, layout: ChunkLayout, cfg: LycheeConfig,
                n_tokens=None) -> LycheeIndex:
    """Build the three-tier index for one (layer, batch element).

    keys: (H, N, d) token keys. Returns a :class:`LycheeIndex`.
    """
    H, N, d = keys.shape
    M, L, P, CC, FC = index_dims(N, cfg)

    chunk_key = pool_chunks(keys, layout, M, cfg.pooling, n_tokens)  # (H,M,d)

    def per_head(ck):
        fine = spherical_kmeans(ck, layout.valid, L, cfg.kmeans_iters)
        fine_chunks, fine_nch = build_member_lists(
            fine.assign, layout.valid, L, CC)
        coarse = spherical_kmeans(fine.centroid * fine.valid[:, None],
                                  fine.valid, P, cfg.kmeans_iters)
        children, nchild = build_member_lists(
            coarse.assign, fine.valid, P, FC)
        return (fine.centroid, fine.radius, fine.size, fine.valid,
                fine_chunks, fine_nch,
                coarse.centroid, coarse.radius, coarse.size, coarse.valid,
                children, nchild, coarse.assign)

    (f_cent, f_rad, f_size, f_valid, f_chunks, f_nch,
     c_cent, c_rad, c_size, c_valid, c_children, c_nchild,
     fine2coarse) = jax.vmap(per_head)(chunk_key)

    return LycheeIndex(
        chunk_key=chunk_key,
        chunk_start=layout.start, chunk_len=layout.length,
        chunk_valid=layout.valid, chunk_count=layout.count,
        fine_centroid=f_cent, fine_radius=f_rad, fine_size=f_size,
        fine_valid=f_valid, fine_chunks=f_chunks, fine_nchunks=f_nch,
        coarse_centroid=c_cent, coarse_radius=c_rad, coarse_size=c_size,
        coarse_valid=c_valid, coarse_children=c_children,
        coarse_nchild=c_nchild, fine2coarse=fine2coarse)
