"""Structure-aware chunking (paper §4.3, App. B).

The algorithm accumulates tokens greedily; once ``min_chunk`` tokens have
accumulated it searches the look-ahead window (up to ``max_chunk``) for the
*highest-priority* natural delimiter and splits right after it. If none is
found, a forced split at ``max_chunk`` is applied — so on delimiter-free
(minified/adversarial) input the method degrades to fixed-size chunking,
exactly as App. B promises.

Delimiters follow the paper's 4-level hierarchy (Table 4):
  Level 1 (strength 4): structural — paragraph breaks, ``}`` ``]`` ``>``,
  markdown fences; Level 2 (strength 3): sentence terminators ``. ? !`` and
  single newlines; Level 3 (strength 2): phrasal ``, ; :``; Level 4
  (strength 1): whitespace. Strength 0 = not a delimiter.

Everything is jit-compatible: the chunk loop is a ``lax.fori_loop`` over M
static chunk slots, each step doing a tiny static-width window scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LycheeConfig
from repro.core.types import ChunkLayout

# ---------------------------------------------------------------------------
# Delimiter tables
# ---------------------------------------------------------------------------

_BYTE_LEVELS = {
    # Level-1: structural
    **{ord(c): 4 for c in "}])>"},
    # Level-2: sentence terminators + newline
    **{ord(c): 3 for c in ".?!\n"},
    # Level-3: phrasal
    **{ord(c): 2 for c in ",;:"},
    # Level-4: whitespace
    **{ord(c): 1 for c in " \t"},
}


def byte_delimiter_table() -> np.ndarray:
    """Priority strengths for a byte-level tokenizer (used by the toy model
    and the benchmarks; real deployments supply a table for their tokenizer)."""
    t = np.zeros(256, dtype=np.int32)
    for b, s in _BYTE_LEVELS.items():
        t[b] = s
    return t


def synthetic_delimiter_table(vocab: int, delim_frac: float = 0.12,
                              seed: int = 0) -> np.ndarray:
    """Deterministic pseudo-delimiter table for synthetic token streams.

    Marks ``delim_frac`` of ids as delimiters with strengths distributed
    like natural text (whitespace ≫ phrasal ≫ sentence ≫ structural). Used
    by the dry-run input specs and synthetic benchmarks.
    """
    rng = np.random.default_rng(seed)
    t = np.zeros(vocab, dtype=np.int32)
    n = int(vocab * delim_frac)
    ids = rng.choice(vocab, size=n, replace=False)
    strengths = rng.choice([1, 2, 3, 4], size=n, p=[0.5, 0.25, 0.15, 0.1])
    t[ids] = strengths
    return t


# ---------------------------------------------------------------------------
# Chunking
# ---------------------------------------------------------------------------

def chunk_sequence(tokens: jax.Array, table: jax.Array,
                   cfg: LycheeConfig, n_tokens=None) -> ChunkLayout:
    """Segment ``tokens`` (N,) into variable-length chunks.

    ``n_tokens`` (scalar, optional) allows right-padding: positions >=
    n_tokens are ignored. Returns a :class:`ChunkLayout` with M =
    ceil(N / min_chunk) static slots.
    """
    N = tokens.shape[0]
    if n_tokens is None:
        n_tokens = jnp.int32(N)
    n_tokens = jnp.asarray(n_tokens, jnp.int32)
    M = max(1, (N + cfg.min_chunk - 1) // cfg.min_chunk)
    W = cfg.max_chunk - cfg.min_chunk + 1   # look-ahead window width

    strength = table[tokens]                       # (N,)
    # pad so dynamic_slice at the tail is safe
    pad = jnp.zeros((cfg.max_chunk,), strength.dtype)
    strength_p = jnp.concatenate([strength, pad])

    def body(i, state):
        start, starts, lengths = state
        # window of candidate split lengths: min_chunk .. max_chunk
        # position of a length-l split's last token: start + l - 1
        win = jax.lax.dynamic_slice(
            strength_p, (start + cfg.min_chunk - 1,), (W,))      # (W,)
        best = jnp.max(win)
        # earliest occurrence of the highest strength
        off = jnp.argmax(win == best)
        length = jnp.where(best > 0, cfg.min_chunk + off, cfg.max_chunk)
        # clip the final chunk to the sequence end
        length = jnp.minimum(length, jnp.maximum(n_tokens - start, 0))
        starts = starts.at[i].set(start)
        lengths = lengths.at[i].set(length)
        return (start + length, starts, lengths)

    start0 = jnp.int32(0)
    starts0 = jnp.zeros((M,), jnp.int32)
    lengths0 = jnp.zeros((M,), jnp.int32)
    _, starts, lengths = jax.lax.fori_loop(
        0, M, body, (start0, starts0, lengths0))

    valid = lengths > 0
    count = jnp.sum(valid.astype(jnp.int32))

    # token -> chunk segment ids: 1 at each chunk start, cumsum - 1
    onehot = jnp.zeros((N,), jnp.int32)
    onehot = onehot.at[jnp.where(valid, starts, N)].add(
        valid.astype(jnp.int32), mode="drop")
    seg_id = jnp.cumsum(onehot) - 1
    seg_id = jnp.clip(seg_id, 0, M - 1)

    return ChunkLayout(start=starts, length=lengths, valid=valid,
                       seg_id=seg_id, count=count)


def fixed_chunking(N: int, size: int, cfg: LycheeConfig,
                   n_tokens=None) -> ChunkLayout:
    """Fixed-size chunking baseline (ablation, Fig. 6 / pilot study Fig. 2).

    Uses the same static M = ceil(N / min_chunk) slot count as
    :func:`chunk_sequence` so downstream shapes match.
    """
    if n_tokens is None:
        n_tokens = jnp.int32(N)
    n_tokens = jnp.asarray(n_tokens, jnp.int32)
    M = max(1, (N + cfg.min_chunk - 1) // cfg.min_chunk)
    idx = jnp.arange(M, dtype=jnp.int32)
    starts = idx * size
    lengths = jnp.clip(n_tokens - starts, 0, size)
    valid = lengths > 0
    seg_id = jnp.minimum(jnp.arange(N, dtype=jnp.int32) // size, M - 1)
    return ChunkLayout(start=starts, length=lengths, valid=valid,
                       seg_id=seg_id,
                       count=jnp.sum(valid.astype(jnp.int32)))
