"""Sparse exact attention over retrieved tokens (Algorithm 1 step 3).

The active set per kv head is [attention sinks | retrieved chunk tokens |
recent-token buffer] — the paper keeps ``sink``=16 initial tokens and a
``buffer``=128 recent window always resident (App. A). Retrieved indices
overlapping the sink/recent ranges are masked out so no position is counted
twice in the softmax.

This module is the pure-jnp implementation — the oracle for the Pallas
``sparse_attention`` kernel and the path used on CPU. It serves every
registered :class:`~repro.core.policy.CachePolicy` (LycheeCluster, Quest,
ClusterKV, StreamingLLM), which all emit the same span / (token_idx,
token_mask) interfaces and share the sink/recent assembly below.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LycheeConfig

_NEG = -1e30


def _shard_map():
    """jax.shard_map landed after the experimental module; take either."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn
    return fn


def assemble_active_set(token_idx: jax.Array, token_mask: jax.Array,
                        t, sink: int, buffer: int, n_ctx: int):
    """Build the final gather list for one kv head.

    token_idx/mask: (S,) retrieved positions; t: scalar current length.
    Returns (idx (sink+S+buffer,), mask) with overlaps removed.
    """
    t = jnp.asarray(t, jnp.int32)
    sink_idx = jnp.arange(sink, dtype=jnp.int32)
    sink_mask = sink_idx < t
    recent_idx = t - buffer + jnp.arange(buffer, dtype=jnp.int32)
    recent_mask = recent_idx >= jnp.minimum(sink, t)
    recent_idx = jnp.clip(recent_idx, 0, n_ctx - 1)
    ret_mask = (token_mask & (token_idx >= sink)
                & (token_idx < jnp.maximum(t - buffer, sink)))
    idx = jnp.concatenate([sink_idx, token_idx, recent_idx])
    mask = jnp.concatenate([sink_mask, ret_mask, recent_mask])
    return jnp.clip(idx, 0, n_ctx - 1), mask


def assemble_spans(ret_starts: jax.Array, ret_lens: jax.Array, t,
                   cfg: LycheeConfig, max_chunk: int | None = None):
    """Combine retrieved chunk spans with the sink span and recent-window
    spans into one overlap-free span list (per kv head).

    Layout of coverage (r0 = max_chunk-aligned start of the recent window):
      [0, sink_len) sink | [sink, r0) retrieved (clipped) | [r0, t) recent.
    Head/tail clipping keeps every position counted at most once in the
    softmax. ret_starts/ret_lens: (H, S). Returns (starts (H, C), lens).
    """
    mc = max_chunk or cfg.max_chunk
    t = jnp.asarray(t, jnp.int32)
    r0 = jnp.maximum((t - cfg.buffer_size) // mc * mc, 0)
    sink_len = jnp.minimum(jnp.int32(cfg.sink), r0)

    # clip retrieved spans to [sink_len, r0)
    s2 = jnp.maximum(ret_starts, sink_len)
    l2 = jnp.clip(ret_lens - (s2 - ret_starts), 0, mc)
    l2 = jnp.clip(jnp.minimum(l2, r0 - s2), 0, mc)

    H = ret_starts.shape[0]
    R = cfg.buffer_size // mc + 1
    recent_s = r0 + jnp.arange(R, dtype=jnp.int32) * mc        # (R,)
    recent_l = jnp.clip(t - recent_s, 0, mc)
    sink_s = jnp.zeros((1,), jnp.int32)
    sink_l = sink_len[None]

    starts = jnp.concatenate(
        [jnp.broadcast_to(sink_s, (H, 1)), s2,
         jnp.broadcast_to(recent_s, (H, R))], axis=1)
    lens = jnp.concatenate(
        [jnp.broadcast_to(sink_l, (H, 1)), l2,
         jnp.broadcast_to(recent_l, (H, R))], axis=1)
    return starts, lens


def fused_policy_decode(q, k_cache, v_cache, pstate, t, pol,
                        ly: LycheeConfig, *, scale: float,
                        softcap: float = 0.0, budget=None):
    """THE policy-managed decode hot path, fused (Algorithm 1 steps 1-4):

        select (retrieval) -> assemble_spans (sink/recent merge)
          -> span executor -> ``update_batched`` (lazy graft / page extend)

    One call per managed layer per decode step; every registered
    :class:`~repro.core.policy.CachePolicy` (lychee, quest, clusterkv,
    streaming — dense short-circuits earlier) flows through it, so the whole
    chain traces into the engine's single jitted ``serve_step``. The span
    executor is picked once at trace time:

    * Pallas kernel (``ly.use_kernel``; ``None`` = auto, i.e. TPU): ONE
      ``pallas_call`` whose grid covers (B, Hkv, span tiles) — the cache is
      passed as-is; its reserved ``cache_slack`` tail rows (never written,
      see ``core.types.usable_rows``) make every span DMA in-bounds with no
      per-step copy;
    * context-sharded shard_map flash-combine when the cache's context dim
      is sharded;
    * pure-jnp gather oracle otherwise (CPU default).

    q: (B, Hq, dk); k_cache/v_cache: (B, Hkv, N, d*) — or a
    :class:`~repro.core.paging.PagedKV` pair (batchless shared pool +
    per-slot page-table rows), in which case the span table is translated
    to physical pool rows (a pure base swap — spans never straddle pages,
    the halo contract) and the executors run against the pool unchanged,
    so outputs are bitwise identical to the contiguous layout; pstate:
    batched policy state (None for stateless policies); t: (B,) per-slot
    lengths BEFORE this token. Returns (out (B, Hq, dv), updated state).

    ``budget`` is the serving engine's overload-degradation valve: a (B,)
    int32 per-slot cap (in tokens) on the RETRIEVED part of the active set,
    0 meaning uncapped. Every registered policy emits its spans in
    descending score rank (lychee: top-k fine clusters cluster-major;
    quest: top-k pages; clusterkv: top-k clusters member-major), so zeroing
    the trailing spans past the cap keeps exactly the highest-scored subset
    — a smaller but still best-first retrieval. Sink and recent spans are
    appended by ``assemble_spans`` afterwards and never shrink. The mask is
    elementwise per slot inside the per-slot vmap, so slots with cap 0 are
    bitwise unaffected by other slots' degradation.
    """
    from repro.core.paging import PagedKV, translate_starts
    from repro.kernels import ops as kops
    from repro.sharding.ctx import kv_axes

    paged = isinstance(k_cache, PagedKV)
    B, Hq, dk = q.shape
    Hkv = k_cache.pool.shape[0] if paged else k_cache.shape[1]
    G = Hq // Hkv
    probe = q.reshape(B, Hkv, G, dk).mean(axis=2)           # (B, Hkv, dk)

    if budget is None:
        def per_b(st_b, probe_b, t_b):
            s, ln = pol.select(st_b, probe_b, t_b)
            return assemble_spans(s, ln, t_b, ly, max_chunk=pol.span_len)

        starts, lens = jax.vmap(per_b)(pstate, probe, t)    # (B, Hkv, C)
    else:
        cap = jnp.broadcast_to(jnp.asarray(budget, jnp.int32), t.shape)

        def per_b(st_b, probe_b, t_b, cap_b):
            s, ln = pol.select(st_b, probe_b, t_b)
            # overload valve: drop the lowest-ranked retrieved spans past
            # the cap (0 = uncapped); sink/recent are added below and
            # never shrink
            off = jnp.arange(s.shape[-1], dtype=jnp.int32) * pol.span_len
            keep = (off < cap_b) | (cap_b <= 0)
            ln = jnp.where(keep[None, :], ln, 0)
            return assemble_spans(s, ln, t_b, ly, max_chunk=pol.span_len)

        starts, lens = jax.vmap(per_b)(pstate, probe, t, cap)
    qg = q.reshape(B, Hkv, G, dk)
    ctx_ax = kv_axes()[2]
    use_kernel = ly.use_kernel
    if use_kernel is None:
        # auto: the single-device kernel must not shadow the context-
        # sharded executor — indexing the full cache from one pallas_call
        # would force XLA to replicate the sharded context dim
        use_kernel = jax.default_backend() == "tpu" and ctx_ax is None
    elif use_kernel and ctx_ax is not None:
        raise ValueError(
            "use_kernel=True is incompatible with a context-sharded KV "
            "cache: the single pallas_call would replicate the sharded "
            "context dim on every device. Use use_kernel=None (auto) so "
            "sharded decode takes the shard_map flash-combine executor.")
    if paged:
        if ctx_ax is not None:
            raise ValueError(
                "paged KV is incompatible with a context-sharded cache: "
                "the page table indirects the context dim, so a pool row "
                "has no fixed shard. Serve paged requests without "
                "context_parallel(), or fall back to the contiguous "
                "layout for ctx-sharded decode.")
        phys = translate_starts(k_cache.tbl, starts, k_cache.spec)
        pool_k, pool_v = k_cache.pool[None], v_cache.pool[None]
        if use_kernel:
            out = kops.chunk_attention(qg, pool_k, pool_v, phys, lens,
                                       max_chunk=pol.span_len, scale=scale,
                                       softcap=softcap, shared_cache=True)
        else:
            out = sparse_span_attention(qg, pool_k, pool_v, phys, lens,
                                        max_chunk=pol.span_len, scale=scale,
                                        softcap=softcap)
        if v_cache.dlim is not None:
            # lazy MLA value view: the executors ran over the FULL pool
            # feature dim (slicing the pool would be a pool-sized copy per
            # step); feature columns are independent in the p @ v
            # contraction, so slicing the (B, Hq, dv) output afterwards is
            # bitwise identical to slicing the values first
            out = out[..., :v_cache.dlim]
    elif use_kernel:
        out = kops.chunk_attention(qg, k_cache, v_cache, starts, lens,
                                   max_chunk=pol.span_len, scale=scale,
                                   softcap=softcap)
    elif ctx_ax is not None:
        # §Perf iteration 1d: shard_map flash-combine over the context
        # shards — collective is O(B·H·G·dv), not O(gathered block)
        out = sparse_span_attention_ctxsharded(
            qg, k_cache, v_cache, starts, lens, ctx_ax,
            max_chunk=pol.span_len, scale=scale, softcap=softcap)
    else:
        out = sparse_span_attention(qg, k_cache, v_cache, starts, lens,
                                    max_chunk=pol.span_len, scale=scale,
                                    softcap=softcap)
    # streaming update (lychee: Algorithm 1 step 4 lazy graft; quest: tail-
    # page min/max extension; clusterkv: nearest-centroid assignment).
    # t + 1 = per-slot length after this token's cache append.
    pstate = pol.update_batched(pstate, k_cache, t + 1)
    return out.reshape(B, Hq, -1), pstate


def sparse_span_attention(q, k_cache, v_cache, starts, lens, *,
                          max_chunk: int = 16, scale: float = 1.0,
                          softcap: float = 0.0) -> jax.Array:
    """Production (GSPMD) span attention: same contract as
    ``kernels.ref.sparse_chunk_attention_ref`` but keeps the gathered K/V
    in the CACHE dtype (§Perf iteration 1c).

    With the context dim sharded (decode_32k: 'model'), GSPMD lowers the
    gathers to zero-filled per-shard partials + an all-reduce of the
    gathered block — bf16 partials HALVE that all-reduce, which is the
    dominant decode collective (measured 2×3.2 GiB/step on granite
    decode_32k). Accuracy is preserved via f32 accumulation
    (preferred_element_type), the MXU's native bf16-in/f32-acc mode.
    """
    B, Hkv, G, dk = q.shape
    N = k_cache.shape[2]
    C = starts.shape[-1]
    offs = jnp.arange(max_chunk, dtype=jnp.int32)
    tok = jnp.clip(starts[..., None], 0, N) + offs
    mask = offs < jnp.clip(lens, 0, max_chunk)[..., None]
    tok = jnp.clip(tok, 0, N - 1).reshape(B, Hkv, C * max_chunk)
    mask = mask.reshape(B, Hkv, C * max_chunk)

    k_sel = jnp.take_along_axis(k_cache, tok[..., None], axis=2)
    v_sel = jnp.take_along_axis(v_cache, tok[..., None], axis=2)
    logits = jnp.einsum("bhgd,bhsd->bhgs", q.astype(k_sel.dtype), k_sel,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[:, :, None, :], logits, _NEG)
    m = jnp.max(logits, -1, keepdims=True)
    p = jnp.where(mask[:, :, None, :], jnp.exp(logits - m), 0.0)
    den = jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgs,bhsd->bhgd", (p / den).astype(v_sel.dtype),
                     v_sel, preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _span_attend_partial(q, k_loc, v_loc, starts, lens, lo, hi, *,
                         max_chunk: int, scale: float, softcap: float):
    """Flash-style PARTIAL attention of one context shard.

    q: (B, H, G, dk); k_loc/v_loc: (B, H, n_loc, d*) — the rows this shard
    owns, covering global positions [lo, hi); starts/lens: (B, H, C)
    GLOBAL span table (replicated across context shards). Rows outside
    [lo, hi) are masked; the caller combines (m, l, acc) across shards.
    Returns m (B,H,G,1) f32, l (B,H,G,1) f32, acc (B,H,G,dv) f32.
    """
    B, Hkv, G, dk = q.shape
    n_loc = k_loc.shape[2]
    C = starts.shape[-1]
    offs = jnp.arange(max_chunk, dtype=jnp.int32)
    row = starts[..., None] + offs                       # (B, H, C, mc) global
    valid = offs < jnp.clip(lens, 0, max_chunk)[..., None]
    mine = (row >= lo) & (row < hi)
    tok = jnp.clip(row - lo, 0, n_loc - 1).reshape(B, Hkv, C * max_chunk)
    mask = (valid & mine).reshape(B, Hkv, C * max_chunk)

    k_sel = jnp.take_along_axis(k_loc, tok[..., None], axis=2)
    v_sel = jnp.take_along_axis(v_loc, tok[..., None], axis=2)
    logits = jnp.einsum("bhgd,bhsd->bhgs", q.astype(k_sel.dtype), k_sel,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[:, :, None, :], logits, _NEG)
    m = jnp.max(logits, -1, keepdims=True)               # (B,H,G,1)
    p = jnp.where(mask[:, :, None, :], jnp.exp(logits - m), 0.0)
    l = jnp.sum(p, -1, keepdims=True)
    acc = jnp.einsum("bhgs,bhsd->bhgd", p.astype(v_sel.dtype), v_sel,
                     preferred_element_type=jnp.float32)
    return m, l, acc


def sparse_span_attention_ctxsharded(q, k_cache, v_cache, starts, lens,
                                     ctx_axes, *, max_chunk: int = 16,
                                     scale: float = 1.0,
                                     softcap: float = 0.0) -> jax.Array:
    """Context-sharded decode attention via shard_map flash-combine
    (§Perf iteration 1d — a collective schedule the paper doesn't use).

    GSPMD's gather-from-sharded-context lowers to zero-filled full-size
    partials + an all-reduce of the ENTIRE gathered block (measured
    2×3.2 GiB/step on granite decode_32k). Here each context shard attends
    over the spans it OWNS (one local gather, no communication), and only
    the online-softmax statistics (m, l, acc) — O(B·H·G·dv) bytes — are
    combined across shards: the collective shrinks by ~S_sel·heads/dv ≈
    three orders of magnitude. Identical math to the oracle (exact
    max-shifted combine, not an approximation).

    q: (B, Hkv, G, dk); k_cache/v_cache: (B, Hkv, N, d*) sharded over
    ``ctx_axes`` on dim 2 (and optionally batch-sharded on dim 0);
    starts/lens: (B, Hkv, C) global span table.
    """
    from repro.sharding.ctx import batch_axes, current_mesh, \
        is_context_parallel
    shard_map = _shard_map()
    mesh = current_mesh()
    P = jax.sharding.PartitionSpec
    baxes = None if is_context_parallel() else batch_axes()
    B = q.shape[0]
    bspec = baxes if (baxes and B % _axes_size(mesh, baxes) == 0) else None

    qs = P(bspec, None, None, None)
    kvs = P(bspec, None, ctx_axes, None)
    sp = P(bspec, None, None)

    n_shards = _axes_size(mesh, ctx_axes)
    shard_n = k_cache.shape[2] // n_shards

    def body(q_l, k_l, v_l, st_l, ln_l):
        # linear index of this shard along the (possibly multi-axis) ctx
        idx = jnp.zeros((), jnp.int32)
        for ax in (ctx_axes if isinstance(ctx_axes, tuple) else (ctx_axes,)):
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        lo = idx * shard_n
        m, l, acc = _span_attend_partial(
            q_l, k_l, v_l, st_l, ln_l, lo, lo + shard_n,
            max_chunk=max_chunk, scale=scale, softcap=softcap)
        # exact flash combine across context shards
        m_g = jax.lax.pmax(m, ctx_axes)
        alpha = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * alpha, ctx_axes)
        acc_g = jax.lax.psum(acc * alpha, ctx_axes)
        return (acc_g / jnp.maximum(l_g, 1e-30)).astype(q_l.dtype)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(qs, kvs, kvs, sp, sp),
                   out_specs=qs)
    return fn(q, k_cache, v_cache, starts, lens)


def full_decode_attention_ctxsharded(q, k_cache, v_cache, t, ctx_axes, *,
                                     scale: float, softcap: float = 0.0):
    """Dense (full-attention) decode over a context-sharded cache via the
    same shard_map flash-combine as the sparse path (§Perf iteration 4).

    The GSPMD dense path materialises the (B, H, G, N) f32 logits of the
    WHOLE cache per step (minicpm decode_32k: 15 GiB/device at B=128,
    36 heads); here each shard computes logits over its local slab and
    only (m, l, acc) stats cross shards. q: (B, Hq, dk); caches
    (B, Hkv, N, d*) sharded over ``ctx_axes`` on dim 2; t: scalar or (B,)
    valid lengths (per-slot under continuous batching). Returns (B, Hq, dv).
    """
    from repro.sharding.ctx import batch_axes, current_mesh, \
        is_context_parallel
    mesh = current_mesh()
    P = jax.sharding.PartitionSpec
    B, Hq, dk = q.shape
    Hkv, N = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    baxes = None if is_context_parallel() else batch_axes()
    bspec = baxes if (baxes and B % _axes_size(mesh, baxes) == 0) else None
    qs = P(bspec, None, None)
    kvs = P(bspec, None, ctx_axes, None)
    ts = P(bspec)
    n_shards = _axes_size(mesh, ctx_axes)
    shard_n = N // n_shards
    tt = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))

    def body(q_l, k_l, v_l, t_l):
        idx = jnp.zeros((), jnp.int32)
        for ax in (ctx_axes if isinstance(ctx_axes, tuple) else (ctx_axes,)):
            idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
        lo = idx * shard_n
        pos = lo + jnp.arange(shard_n, dtype=jnp.int32)
        mask = pos[None, :] < t_l[:, None]                 # (B_l, n_loc)
        B_l = q_l.shape[0]                                 # batch LOCAL shape
        qg = q_l.reshape(B_l, Hkv, G, dk)
        logits = jnp.einsum("bhgd,bhnd->bhgn", qg.astype(k_l.dtype), k_l,
                            preferred_element_type=jnp.float32) * scale
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        logits = jnp.where(mask[:, None, None, :], logits, _NEG)
        m = jnp.max(logits, -1, keepdims=True)
        p = jnp.where(mask[:, None, None, :],
                      jnp.exp(logits - m), 0.0)
        l = jnp.sum(p, -1, keepdims=True)
        acc = jnp.einsum("bhgn,bhnd->bhgd", p.astype(v_l.dtype), v_l,
                         preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m, ctx_axes)
        alpha = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * alpha, ctx_axes)
        acc_g = jax.lax.psum(acc * alpha, ctx_axes)
        out = acc_g / jnp.maximum(l_g, 1e-30)
        return out.reshape(B_l, Hq, -1).astype(q_l.dtype)

    fn = _shard_map()(body, mesh=mesh, in_specs=(qs, kvs, kvs, ts),
                      out_specs=qs)
    return fn(q, k_cache, v_cache, tt)


def _axes_size(mesh, axes) -> int:
    if axes is None or mesh is None:
        return 1
    s = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        s *= mesh.shape[a]
    return s


def attend_gathered(q: jax.Array, k_sel: jax.Array, v_sel: jax.Array,
                    mask: jax.Array, scale: float,
                    softcap: float = 0.0) -> jax.Array:
    """q: (G, d) query group; k_sel/v_sel: (S, dk)/(S, dv); mask: (S,)."""
    logits = (q @ k_sel.T) * scale                    # (G, S)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[None, :], logits, _NEG)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(mask[None, :], w, 0.0)              # all-masked safety
    return w @ v_sel


def sparse_decode_attention(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, token_idx: jax.Array,
                            token_mask: jax.Array, t, cfg: LycheeConfig,
                            scale: float, softcap: float = 0.0) -> jax.Array:
    """One decode step of budgeted sparse attention.

    q: (Hq, dk); k_cache: (Hkv, N, dk); v_cache: (Hkv, N, dv);
    token_idx/mask: (Hkv, S). Returns (Hq, dv).
    """
    Hq, dk = q.shape
    Hkv, N, _ = k_cache.shape
    G = Hq // Hkv
    qg = q.reshape(Hkv, G, dk)

    def per_head(h):
        idx, mask = assemble_active_set(token_idx[h], token_mask[h], t,
                                        cfg.sink, cfg.buffer_size, N)
        k_sel = k_cache[h][idx]
        v_sel = v_cache[h][idx]
        return attend_gathered(qg[h], k_sel, v_sel, mask, scale, softcap)

    out = jax.vmap(per_head)(jnp.arange(Hkv))         # (Hkv, G, dv)
    return out.reshape(Hq, -1)


def full_decode_attention(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, t, scale: float,
                          softcap: float = 0.0) -> jax.Array:
    """Dense reference: attends to all positions < t. Same signature family."""
    Hq, dk = q.shape
    Hkv, N, dv = v_cache.shape
    G = Hq // Hkv
    qg = q.reshape(Hkv, G, dk)
    mask = jnp.arange(N) < jnp.asarray(t, jnp.int32)
    logits = jnp.einsum("hgd,hnd->hgn", qg, k_cache) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[None, None, :], logits, _NEG)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hgn,hnd->hgd", w, v_cache)
    return out.reshape(Hq, dv)
