"""Paged KV layout: halo pages, page-table translation, bit-identical spans.

The contiguous engine gives every slot ``n_cache`` private KV rows, so
concurrency is bounded by ``n_slots x usable_rows`` even when most sessions
are short and even when they share a system prompt.  This module is the
device-side half of the paged subsystem (the host-side allocator/prefix
cache lives in ``repro.serving.pagepool``): a single global pool of
fixed-size pages plus a per-slot page table that the span executors resolve
through.

Bit-identity contract (the hard part)
-------------------------------------
Span selection emits ``(start, len)`` ranges with ``len <= cache_slack``
(``core.types.cache_slack``).  Translating a span that *straddles* a page
boundary would require splitting it into two reads, changing the attention
reduction order and breaking bitwise identity with the contiguous layout.
Instead every physical page carries a **halo**: page ``p`` stores its own
``P = page_tokens`` rows followed by ``slack`` duplicate copies of logical
page ``p+1``'s first rows.  A span starting inside page ``p`` then always
fits inside page ``p``'s ``P + slack`` physical rows, so translation is a
single base-address swap::

    phys_start = tbl[start // P] * (P + slack) + start % P

and the executor maths (gather order, mask, accumulation) is untouched —
outputs are bitwise identical to the contiguous layout.

Dump page
---------
Physical page ``n_pages`` (the last one) is a sacrificial **dump** page:
page-table rows of unallocated logical pages point at it, so garbage writes
(masked slots, the nonexistent left-neighbour of logical page 0) and reads
past the allocated frontier land somewhere harmless instead of aliasing
page 0.  It is never reference-counted and never read by a live span.

Sharing contract
----------------
Page ``q`` of a prefix of length ``Lc`` is safe to share read-only iff
``(q + 1) * P + slack <= Lc``: neither the donor's nor the reader's future
appends can touch it (appends at position ``t >= Lc`` halo-write page
``t//P - 1``, which fails that inequality).  The unsafe tail pages are
copied, never shared — see ``serving.pagepool``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import LycheeConfig
from repro.core.types import cache_slack


class PageSpec(NamedTuple):
    """Static page-pool geometry (hashable; safe as a jit static / pytree
    aux datum).

    ``page_tokens`` logical tokens per page; ``slack`` halo rows duplicated
    from the next page (== ``cache_slack(cfg)``, the max span length);
    ``n_pages`` allocatable physical pages (the dump page is extra);
    ``max_pages`` logical pages per slot (``n_cache // page_tokens``).
    """

    page_tokens: int
    slack: int
    n_pages: int
    max_pages: int

    @property
    def page_rows(self) -> int:
        return self.page_tokens + self.slack

    @property
    def dump_page(self) -> int:
        return self.n_pages

    @property
    def dump_row(self) -> int:
        return self.n_pages * self.page_rows

    @property
    def pool_rows(self) -> int:
        """Physical rows in the pool incl. the dump page."""
        return (self.n_pages + 1) * self.page_rows

    @property
    def logical_rows(self) -> int:
        """Per-slot logical capacity (== n_cache)."""
        return self.max_pages * self.page_tokens


def resolve_page_spec(n_cache: int, cfg: LycheeConfig, *,
                      page_tokens: int = 0, pool_pages: int = 0,
                      n_slots: int = 1) -> PageSpec:
    """Pick a page geometry for ``n_cache``-row slots.

    ``page_tokens == 0`` auto-selects the smallest multiple of
    ``span_base = max(max_chunk, quest_page, 1)`` that divides ``n_cache``,
    is >= ``cache_slack`` (so a span never outgrows one page's halo
    window), and is >= 128 when possible — the halo costs ``slack / P``
    extra rows per page, so tiny pages would double the pool.
    ``pool_pages == 0`` sizes the pool to ``n_slots`` full slots — the
    break-even point; sharing makes it go further.
    """
    slack = cache_slack(cfg)
    base = max(cfg.max_chunk, cfg.quest_page, 1)
    if page_tokens <= 0:
        divisors = [p for p in range(base, n_cache + 1, base)
                    if p >= slack and n_cache % p == 0]
        if not divisors:
            raise ValueError(
                f"no page size: n_cache={n_cache} has no multiple of "
                f"span_base={base} >= slack={slack} dividing it")
        target = max(slack, min(128, n_cache))
        page_tokens = next((p for p in divisors if p >= target),
                           divisors[-1])
    if n_cache % page_tokens != 0:
        raise ValueError(f"page_tokens={page_tokens} must divide "
                         f"n_cache={n_cache}")
    if page_tokens % base != 0:
        raise ValueError(f"page_tokens={page_tokens} must be a multiple of "
                         f"span base {base} (max_chunk/quest_page)")
    if page_tokens < slack:
        raise ValueError(f"page_tokens={page_tokens} < slack={slack}: a "
                         f"span could straddle the halo")
    max_pages = n_cache // page_tokens
    if pool_pages <= 0:
        pool_pages = n_slots * max_pages
    if pool_pages < max_pages:
        raise ValueError(f"pool_pages={pool_pages} cannot hold one full "
                         f"slot ({max_pages} pages)")
    return PageSpec(page_tokens=page_tokens, slack=slack,
                    n_pages=pool_pages, max_pages=max_pages)


@jax.tree_util.register_pytree_node_class
class PagedKV:
    """A (pool, page-table) pair that stands in for a contiguous
    ``(B, H, N, d)`` KV cache in policy code.

    ``pool`` is batchless — ``(H, pool_rows, d)`` (GQA) or
    ``(1, pool_rows, D)`` (MLA latent) — and ``tbl`` is ``(B, max_pages)``
    int32 (or ``(max_pages,)`` under vmap).  Policy ``update`` code indexes
    single rows / short windows via :func:`kv_row` / :meth:`window`;
    everything resolves through the table.

    ``dlim`` is a LAZY feature-dim limit (static): the view behaves as if
    the pool were ``pool[..., :dlim]`` but the slice is applied only to
    per-row/window *gathered* blocks, never to the pool itself — slicing
    the pool up front would materialize a pool-sized copy per decode step
    (the MLA value view ``latent[..., :kvl]`` is the one user).
    """

    __slots__ = ("pool", "tbl", "spec", "dlim")

    def __init__(self, pool, tbl, spec: PageSpec, dlim: Optional[int] = None):
        self.pool = pool
        self.tbl = tbl
        self.spec = spec
        self.dlim = dlim

    def tree_flatten(self):
        return (self.pool, self.tbl), (self.spec, self.dlim)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    # -- contiguous-cache stand-ins (per-slot view: tbl is (max_pages,)) --
    @property
    def shape(self):  # mirrors keys.shape[1] uses via kv_len()
        d = self.pool.shape[-1] if self.dlim is None else self.dlim
        return (self.pool.shape[0], self.spec.logical_rows, d)

    @property
    def dtype(self):
        return self.pool.dtype

    def row(self, t):
        """Logical row ``t`` -> ``(H, d)`` (per-slot view)."""
        sp = self.spec
        t = jnp.clip(jnp.asarray(t, jnp.int32), 0, sp.logical_rows - 1)
        phys = self.tbl[t // sp.page_tokens] * sp.page_rows \
            + t % sp.page_tokens
        row = jax.vmap(
            lambda h: jax.lax.dynamic_index_in_dim(h, phys, axis=0,
                                                   keepdims=False)
        )(self.pool)
        return row if self.dlim is None else row[..., :self.dlim]

    def window(self, start, length: int):
        """Logical rows ``[start, start+length)`` -> ``(H, length, d)``.

        Requires ``length <= slack`` (the halo guarantee); one
        ``dynamic_slice`` per head, no span splitting.
        """
        sp = self.spec
        if length > sp.slack + sp.page_tokens:
            raise ValueError(f"window length {length} exceeds page_rows")
        # clip like the contiguous gather path does: out-of-range starts
        # (e.g. the discarded branch of a lowered lax.cond) must still
        # index the table in bounds
        start = jnp.clip(jnp.asarray(start, jnp.int32), 0,
                         sp.logical_rows - 1)
        phys = self.tbl[start // sp.page_tokens] * sp.page_rows \
            + start % sp.page_tokens
        win = jax.vmap(
            lambda h: jax.lax.dynamic_slice_in_dim(h, phys, length, axis=0)
        )(self.pool)
        return win if self.dlim is None else win[..., :self.dlim]


def kv_len(keys) -> int:
    """Logical context length of a cache operand (``keys.shape[1]``)."""
    if isinstance(keys, PagedKV):
        return keys.spec.logical_rows
    return keys.shape[1]


def kv_row(keys, t):
    """Row ``t`` of a ``(H, N, d)``-like cache operand -> ``(H, d)``."""
    if isinstance(keys, PagedKV):
        return keys.row(t)
    return keys[:, jnp.clip(jnp.asarray(t, jnp.int32), 0,
                            keys.shape[1] - 1)]


def kv_batch_axes(keys):
    """vmap ``in_axes`` entry for a batched cache operand: the pool is
    shared (None) and only the page-table row is mapped."""
    if isinstance(keys, PagedKV):
        # aux data (spec, dlim) must match the mapped tree's exactly
        return PagedKV(None, 0, keys.spec, keys.dlim)
    return 0


def translate_starts(tbl: jnp.ndarray, starts: jnp.ndarray,
                     spec: PageSpec) -> jnp.ndarray:
    """Translate logical span starts to physical pool rows.

    ``tbl`` is ``(B, max_pages)``, ``starts`` is ``(B, H, C)`` (or any
    ``(B, ...)``); spans never straddle pages (halo contract), so this is
    a pure base swap.  Starts are clipped to the logical range first so
    sentinel/over-range starts resolve through a valid table entry (which
    is the dump page when unallocated).
    """
    P = spec.page_tokens
    starts = jnp.clip(starts, 0, spec.logical_rows - 1)
    page = starts // P
    bdims = starts.shape[1:-1]
    idx = page.reshape((page.shape[0], -1))
    phys_page = jnp.take_along_axis(tbl, idx, axis=1)
    phys_page = phys_page.reshape((page.shape[0],) + bdims
                                  + (page.shape[-1],))
    return phys_page * spec.page_rows + starts % P


def append_rows(tbl: jnp.ndarray, t: jnp.ndarray,
                spec: PageSpec) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Physical rows for appending token ``t``: (direct, halo).

    ``tbl`` is ``(B, max_pages)``, ``t`` ``(B,)``.  The direct write lands
    in page ``t // P``; when ``t % P < slack`` the row is also a halo row
    of page ``t//P - 1`` and must be duplicated there.  For page 0 (no
    left neighbour) the halo write routes to the dump row.
    """
    P, pr = spec.page_tokens, spec.page_rows
    t = jnp.asarray(t, jnp.int32)
    page = jnp.clip(t // P, 0, spec.max_pages - 1)
    off = t % P
    direct = jnp.take_along_axis(tbl, page[:, None], axis=1)[:, 0] * pr + off
    prev = jnp.take_along_axis(tbl, jnp.maximum(page - 1, 0)[:, None],
                               axis=1)[:, 0]
    halo = jnp.where((off < spec.slack) & (page >= 1),
                     prev * pr + P + off, spec.dump_row)
    return direct, halo


def slot_write_rows(tbl_row: jnp.ndarray,
                    spec: PageSpec) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter indices installing a full contiguous slot image into the
    pool: ``(direct, halo)``, each ``(n_cache,)`` physical rows for logical
    rows ``0..n_cache-1``.  ``tbl_row`` is this slot's ``(max_pages,)``
    table row; unallocated entries point at the dump page, so rows past
    the reserved frontier are scattered harmlessly there.
    """
    sp = spec
    r = jnp.arange(sp.logical_rows, dtype=jnp.int32)
    page, off = r // sp.page_tokens, r % sp.page_tokens
    direct = tbl_row[page] * sp.page_rows + off
    halo = jnp.where((off < sp.slack) & (page >= 1),
                     tbl_row[jnp.maximum(page - 1, 0)] * sp.page_rows
                     + sp.page_tokens + off, sp.dump_row)
    return direct, halo


def slot_gather_rows(tbl_row: jnp.ndarray, spec: PageSpec) -> jnp.ndarray:
    """Gather indices reassembling a slot's contiguous ``(n_cache,)`` view
    from the pool (admission-class only — never in the decode step)."""
    r = jnp.arange(spec.logical_rows, dtype=jnp.int32)
    return tbl_row[r // spec.page_tokens] * spec.page_rows \
        + r % spec.page_tokens


def scatter_slot(pool: jnp.ndarray, rows: jnp.ndarray,
                 vals: jnp.ndarray) -> jnp.ndarray:
    """``pool.at[:, rows].set(vals)`` for a batchless ``(H, R, d)`` pool
    with ``rows (N,)`` and ``vals (H, N, d)``."""
    return pool.at[:, rows, :].set(vals.astype(pool.dtype))


def copy_page_rows(spec: PageSpec, src_pages, dst_pages) -> jnp.ndarray:
    """Physical (src_rows, dst_rows) copying whole pages incl. halos."""
    src = jnp.asarray(src_pages, jnp.int32)
    dst = jnp.asarray(dst_pages, jnp.int32)
    off = jnp.arange(spec.page_rows, dtype=jnp.int32)
    src_rows = (src[:, None] * spec.page_rows + off[None, :]).reshape(-1)
    dst_rows = (dst[:, None] * spec.page_rows + off[None, :]).reshape(-1)
    return src_rows, dst_rows


PagedOrArray = Union[PagedKV, jnp.ndarray]
