"""Spherical k-means (Hornik et al., 2012) — paper §4.3.

Inner-product assignment over unit-norm points, fixed iteration count
(App. A: 10 iterations; init "has negligible impact", so we use a
deterministic strided init which is reproducible and jit-friendly).
Centroids are re-normalised each step; covering radii are the max Euclidean
distance from the centroid to any member (paper Eqn. 2 slack term).

Shapes are static: invalid points (mask=False) never contribute; empty
clusters keep their previous centroid and get radius 0 / valid=False.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.pooling import l2_normalize


class KMeansResult(NamedTuple):
    centroid: jax.Array     # (L, d) unit-norm
    radius: jax.Array       # (L,)
    assign: jax.Array       # (M,) int32 cluster id per point
    size: jax.Array         # (L,) int32 member count
    valid: jax.Array        # (L,) bool


def spherical_kmeans(points: jax.Array, mask: jax.Array, L: int,
                     iters: int = 10) -> KMeansResult:
    """points: (M, d) unit-norm (invalid rows are zero); mask: (M,) bool."""
    M, d = points.shape
    # deterministic strided init over the (padded) point list: centroids
    # start at every (M // L)-th point. Invalid seeds are fine — they die
    # out after the first assignment step.
    stride = max(1, M // L)
    init_idx = (jnp.arange(L) * stride) % M
    cent0 = points[init_idx]
    # avoid all-zero seed centroids (degenerate dot products)
    cent0 = jnp.where(jnp.sum(cent0 * cent0, -1, keepdims=True) > 0.5,
                      cent0, l2_normalize(jnp.ones((L, d), points.dtype)))

    neg = jnp.asarray(-1e30, points.dtype)

    def step(cent, _):
        sim = points @ cent.T                         # (M, L)
        assign = jnp.argmax(sim, axis=-1).astype(jnp.int32)
        assign_safe = jnp.where(mask, assign, L)      # park invalid in slot L
        s = jax.ops.segment_sum(points, assign_safe, num_segments=L + 1)[:L]
        cnt = jax.ops.segment_sum(mask.astype(points.dtype), assign_safe,
                                  num_segments=L + 1)[:L]
        new = l2_normalize(s)
        cent = jnp.where(cnt[:, None] > 0, new, cent)
        return cent, None

    cent, _ = jax.lax.scan(step, cent0, None, length=iters)

    sim = points @ cent.T
    assign = jnp.argmax(sim, axis=-1).astype(jnp.int32)
    assign_safe = jnp.where(mask, assign, L)
    size = jax.ops.segment_sum(
        mask.astype(jnp.int32), assign_safe, num_segments=L + 1)[:L]
    # covering radius: max_{member} ||p - mu||
    dist = jnp.linalg.norm(points - cent[assign], axis=-1)
    dist = jnp.where(mask, dist, neg)
    radius = jax.ops.segment_max(dist, assign_safe, num_segments=L + 1)[:L]
    radius = jnp.where(size > 0, radius, 0.0).astype(points.dtype)
    return KMeansResult(centroid=cent, radius=radius,
                        assign=jnp.where(mask, assign, 0),
                        size=size, valid=size > 0)
