"""Lazy incremental index update (paper §4.4, Algorithm 1 steps 4).

Newly generated tokens accumulate in the recent buffer; every ``max_chunk``
steps they are packed into a *dynamic chunk*, whose pooled key is grafted
onto the nearest existing fine cluster (and transitively its coarse unit):
centroids move by a running average, radii expand monotonically to keep the
Eqn. 2 bound valid, and the chunk is appended to the cluster's member list
if capacity allows. No global re-clustering ever happens at decode time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LycheeConfig
from repro.core.pooling import l2_normalize
from repro.core.types import LycheeIndex, empty_index_like


def reset_index(index: LycheeIndex) -> LycheeIndex:
    """Restart the index of ONE (layer, batch element): every tier emptied,
    chunk cursor back to 0, all validity masks False.

    This is the per-slot lifecycle hook for continuous batching — when a
    serving slot drains, its index must not leak stale chunks into the next
    admitted request's retrieval. Shapes are preserved so the reset composes
    with batched/stacked state surgery (``models.model.reset_slot``).
    """
    return empty_index_like(index)


def pack_dynamic_chunk(keys: jax.Array, start, length: int) -> jax.Array:
    """Pool the keys of the freshly generated chunk.

    keys: (H, N, d) full key cache; start: scalar; length: static chunk size.
    Returns (H, d) unit-norm representative keys.

    Uses a GATHER of ``length`` rows rather than dynamic_slice: with the
    context dim sharded (decode), a traced-offset dynamic_slice makes GSPMD
    all-gather the WHOLE cache to slice 16 rows (measured 1.3 GiB/step on
    granite decode_32k); the gather lowers to per-shard partials + an
    all-reduce of just the (H, length, d) block (§Perf iteration 1c).

    Under the paged layout ``keys`` is a ``core.paging.PagedKV`` view; the
    chunk window fits in one page's halo span (``length == max_chunk <=
    slack``), so it is a single translated dynamic_slice per head.
    """
    from repro.core.paging import PagedKV
    if isinstance(keys, PagedKV):
        seg = keys.window(start, length)                     # (H, len, d)
        pooled = l2_normalize(jnp.mean(seg.astype(jnp.float32), axis=1))
        return pooled.astype(keys.dtype)
    idx = jnp.asarray(start, jnp.int32) + jnp.arange(length, dtype=jnp.int32)
    idx = jnp.clip(idx, 0, keys.shape[1] - 1)
    seg = jnp.take_along_axis(
        keys, idx[None, :, None], axis=1)                    # (H, len, d)
    pooled = l2_normalize(jnp.mean(seg.astype(jnp.float32), axis=1))
    return pooled.astype(keys.dtype)


def lazy_update(index: LycheeIndex, new_key: jax.Array, start,
                length, cfg: LycheeConfig) -> LycheeIndex:
    """Graft one dynamic chunk into the index (all kv heads at once).

    new_key: (H, d); start/length: scalars for the chunk's token span.

    Drop-new at capacity: once ``chunk_count == M`` the graft is a no-op.
    The previous behaviour kept overwriting slot ``M - 1``'s
    ``chunk_start``/``chunk_len`` while older fine-cluster member lists
    still pointed at that slot, so retrieval silently returned spans from
    the *latest* dynamic chunk's positions wherever any stale member
    referenced it — wrong tokens in the active set, softmax over the wrong
    rows. Dropping the newest chunk loses a little recall at the capacity
    edge (the recent buffer still covers those tokens exactly) but never
    corrupts existing retrieval.
    """
    H, M, d = index.chunk_key.shape
    CC = index.fine_chunks.shape[-1]
    can = index.chunk_count < M
    slot = jnp.minimum(index.chunk_count, M - 1)
    start = jnp.asarray(start, jnp.int32)
    length = jnp.asarray(length, jnp.int32)

    # --- append chunk ------------------------------------------------------
    chunk_key = jax.lax.dynamic_update_slice(
        index.chunk_key, new_key[:, None, :], (0, slot, 0))
    chunk_start = index.chunk_start.at[slot].set(start)
    chunk_len = index.chunk_len.at[slot].set(length)
    chunk_valid = index.chunk_valid.at[slot].set(True)

    # --- nearest fine cluster per head (inner-product, App. A) -------------
    sim = jnp.einsum("hld,hd->hl", index.fine_centroid, new_key)
    sim = jnp.where(index.fine_valid, sim, -1e30)
    fid = jnp.argmax(sim, axis=-1).astype(jnp.int32)          # (H,)
    heads = jnp.arange(H)

    # moving-average centroid, re-normalised (spherical mean)
    n = index.fine_size[heads, fid].astype(index.fine_centroid.dtype)
    mu = index.fine_centroid[heads, fid]                      # (H, d)
    mu_new = l2_normalize((mu * n[:, None] + new_key) / (n[:, None] + 1.0))
    fine_centroid = index.fine_centroid.at[heads, fid].set(mu_new)
    fine_size = index.fine_size.at[heads, fid].add(1)

    # monotonic radius expansion: must keep covering old members after the
    # centroid moved, plus the new chunk.
    shift = jnp.linalg.norm(mu_new - mu, axis=-1)
    r_old = index.fine_radius[heads, fid]
    r_new = jnp.maximum(r_old + shift,
                        jnp.linalg.norm(new_key - mu_new, axis=-1))
    fine_radius = index.fine_radius.at[heads, fid].set(
        r_new.astype(index.fine_radius.dtype))

    # append to member list when capacity allows
    pos = jnp.minimum(index.fine_nchunks[heads, fid], CC - 1)
    ok = index.fine_nchunks[heads, fid] < CC
    fine_chunks = index.fine_chunks.at[
        heads, jnp.where(ok, fid, 0), jnp.where(ok, pos, 0)].set(
        jnp.where(ok, slot, index.fine_chunks[heads, 0, 0]))
    fine_nchunks = index.fine_nchunks.at[heads, fid].add(
        ok.astype(jnp.int32))

    # --- propagate to the coarse unit ---------------------------------------
    gid = index.fine2coarse[heads, fid]
    ng = index.coarse_size[heads, gid].astype(index.coarse_centroid.dtype)
    mug = index.coarse_centroid[heads, gid]
    mug_new = l2_normalize((mug * ng[:, None] + new_key) / (ng[:, None] + 1))
    shift_g = jnp.linalg.norm(mug_new - mug, axis=-1)
    rg_old = index.coarse_radius[heads, gid]
    rg_new = jnp.maximum(rg_old + shift_g,
                         jnp.linalg.norm(mu_new - mug_new, axis=-1))
    coarse_centroid = index.coarse_centroid.at[heads, gid].set(mug_new)
    coarse_radius = index.coarse_radius.at[heads, gid].set(
        rg_new.astype(index.coarse_radius.dtype))
    coarse_size = index.coarse_size.at[heads, gid].add(1)

    grafted = index._replace(
        chunk_key=chunk_key, chunk_start=chunk_start, chunk_len=chunk_len,
        chunk_valid=chunk_valid,
        chunk_count=jnp.minimum(index.chunk_count + 1, M),
        fine_centroid=fine_centroid, fine_radius=fine_radius,
        fine_size=fine_size, fine_chunks=fine_chunks,
        fine_nchunks=fine_nchunks,
        coarse_centroid=coarse_centroid, coarse_radius=coarse_radius,
        coarse_size=coarse_size)
    # drop-new at capacity: keep every leaf of the old index when full
    return jax.tree.map(lambda new, old: jnp.where(can, new, old),
                        grafted, index)


def maybe_lazy_update(index: LycheeIndex, keys: jax.Array, t,
                      cfg: LycheeConfig) -> LycheeIndex:
    """Conditionally graft a dynamic chunk once ``max_chunk`` new tokens have
    accumulated past the last indexed position. ``t`` = length AFTER the
    current token was appended. Jit-safe (lax.cond). Under the continuous-
    batching engine ``t`` is per-slot and this runs vmapped over the batch,
    where the cond lowers to a select — every slot computes the graft and
    keeps it only when its own cadence hits. A full index
    (``chunk_count == M``) is never due: the graft would be dropped anyway
    (see :func:`lazy_update`), so the cond skips its compute entirely."""
    t = jnp.asarray(t, jnp.int32)
    size = jnp.int32(cfg.max_chunk)
    M = index.chunk_start.shape[0]
    due = ((t % size) == 0) & (index.chunk_count < M)

    def do(idx):
        start = t - size
        new_key = pack_dynamic_chunk(keys, start, cfg.max_chunk)
        return lazy_update(idx, new_key, start, size, cfg)

    return jax.lax.cond(due, do, lambda idx: idx, index)
