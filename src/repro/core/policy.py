"""Pluggable KV cache-management policies (the §5.1 comparison surface).

A :class:`CachePolicy` owns the *selection state* of one policy-managed
attention layer — what the paper calls the index — as a per-(layer, slot)
pytree of STATIC shapes, so every policy composes with the continuous-
batching slot surgery (``models.model.write_slot`` / ``reset_slot``) exactly
like the Lychee index does. Five operations:

* ``empty(N, H, d)``          all-invalid state for an ``N``-token cache
                              (zero leaves ARE the empty state — the
                              recycled-slot contract);
* ``build(keys, layout, n_cache)``   prefill-time construction, padded to
                              the static capacities of ``n_cache`` so slots
                              admitted from different prompt lengths carry
                              identical leaf shapes;
* ``select(state, probe, t)`` decode-time selection → chunk SPANS
                              ``(starts, lens)`` per kv head, the TPU-native
                              active-set form every span executor (pure-jnp,
                              ctx-sharded shard_map, Pallas kernel) consumes;
* ``update(state, k_cache, t)``  streaming append: fold the token written at
                              position ``t - 1`` into the state;
* ``extend(state, k_cache, t0, n)``  streaming MULTI-token append (the
                              session-reuse path of ``model.extend_slot``):
                              fold rows ``[t0, t0+n)`` in without a rebuild,
                              following the same trajectory per-token decode
                              would have (lychee lazy-grafts, quest extends
                              tail pages, clusterkv assigns to centroids);
* ``pad(state, N_cap)`` / ``reset(state)``  slot-lifecycle hooks.

Registered policies (``register_policy`` / ``get_policy``):

* ``lychee``     the paper's three-tier hierarchical index — a thin wrapper
                 over :mod:`repro.core.index`/``retrieval``/``update``,
                 bit-identical to calling them directly;
* ``quest``      Quest (Tang et al., 2024): fixed pages with per-page
                 elementwise min/max key bounds, score = Σ_d max(q·min,
                 q·max); streaming update extends the tail page's bounds;
* ``clusterkv``  ClusterKV (Liu et al., 2025): token-granular spherical
                 k-means; streaming update assigns each new token to its
                 nearest centroid (moving-average, like the Lychee graft);
* ``streaming``  StreamingLLM (Xiao et al., 2024): selects nothing — the
                 active set is the shared sink + recent buffer only;
* ``dense``      no selection state; the model runs full cache attention
                 (``is_dense`` short-circuits dispatch).

Every policy flows through the same sink/recent-buffer span assembly
(:func:`repro.core.attention.assemble_spans`) and the same attention
executors, so an end-to-end tokens/s comparison isolates the selection
policy — the precondition for honest §5.1 tables.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple, Type

import jax
import jax.numpy as jnp

from repro.configs.base import LycheeConfig
from repro.core.index import build_index, build_member_lists
from repro.core.kmeans import spherical_kmeans
from repro.core.paging import kv_batch_axes, kv_len, kv_row
from repro.core.pooling import l2_normalize
from repro.core.retrieval import retrieve_spans
from repro.core.types import ChunkLayout, empty_index, pad_index
from repro.core.update import maybe_lazy_update

_NEG = -1e30


def spans_to_tokens(starts: jax.Array, lens: jax.Array, span_len: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Expand a span table into ``(token_idx, token_mask)`` — the flat form
    consumed by ``sparse_decode_attention`` and the recall metrics.

    starts/lens: (..., C). Returns (..., C * span_len) each.
    """
    offs = jnp.arange(span_len, dtype=jnp.int32)
    tok = starts[..., None] + offs
    mask = offs < jnp.clip(lens, 0, span_len)[..., None]
    flat = starts.shape[:-1] + (starts.shape[-1] * span_len,)
    return tok.reshape(flat), mask.reshape(flat)


class CachePolicy:
    """Base cache-management policy. Subclasses override the five ops.

    Class attributes describe the dispatch contract:

    * ``stateful``      the policy carries a pytree state in the decode
                        cache (key ``"policy_state"``);
    * ``has_update``    ``update`` does real work at decode time;
    * ``needs_layout``  ``build`` consumes the structure-aware ChunkLayout;
    * ``is_dense``      the model bypasses selection and runs full cache
                        attention (no ``select``/``update`` calls).
    """

    name: str = ""
    stateful: bool = True
    has_update: bool = True
    needs_layout: bool = False
    is_dense: bool = False

    def __init__(self, cfg: LycheeConfig):
        self.cfg = cfg

    @property
    def span_len(self) -> int:
        """Static max span length — the executors' per-span gather width."""
        return self.cfg.max_chunk

    # -- lifecycle ---------------------------------------------------------
    def empty(self, N: int, H: int, d: int, dtype=jnp.float32):
        """All-invalid state for an N-token cache (zero leaves)."""
        return None

    def build(self, keys: jax.Array, layout: Optional[ChunkLayout],
              n_cache: int, n_tokens=None):
        """Prefill-time state over ``keys`` (H, S, d), padded to the static
        capacities of an ``n_cache``-token cache (slot-splice uniformity)."""
        return None

    def build_batched(self, keys: jax.Array, layout, n_cache: int,
                      n_tokens=None):
        """vmap ``build`` over a leading batch dim of ``keys`` (B, H, S, d),
        threading the (batched) layout only for policies that consume it —
        the one call site cache builders need. ``n_tokens`` (scalar, shared
        by all rows; traced ok) marks right-padded prompts: positions >=
        n_tokens are ignored by the build (the prompt-length-bucketing
        contract)."""
        if self.needs_layout:
            return jax.vmap(lambda kb, lay: self.build(
                kb, lay, n_cache, n_tokens=n_tokens))(keys, layout)
        return jax.vmap(lambda kb: self.build(
            kb, None, n_cache, n_tokens=n_tokens))(keys)

    def empty_batched(self, B: int, N: int, H: int, d: int,
                      dtype=jnp.float32):
        """(B,)-batched :meth:`empty` — the placeholder state a chunked
        admission carries before its end-of-admission monolithic build
        (``serving.chunk_state == "rebuild"``)."""
        state = self.empty(N, H, d, dtype)
        if state is None:
            return None
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (B,) + l.shape), state)

    def select(self, state, probe: jax.Array, t) -> Tuple[jax.Array,
                                                          jax.Array]:
        """Decode-time selection. probe: (H, d) one query per kv head;
        t: scalar current length. Returns chunk spans (starts, lens),
        each (H, C) int32 — padding spans carry len 0."""
        raise NotImplementedError

    def update(self, state, keys: jax.Array, t):
        """Streaming append: fold the row written at position ``t - 1`` of
        ``keys`` (H, N, d) into the state. ``t`` = length AFTER the token
        was appended. Jit-safe; vmapped per slot by the model."""
        return state

    def update_batched(self, state, keys: jax.Array, t: jax.Array):
        """Fold each serving slot's freshly appended token into its state —
        the batched decode-time entry point (one call per managed layer per
        step, from ``core.attention.fused_policy_decode``). keys:
        (B, H, N, d); t: (B,) per-slot lengths AFTER the append. Default:
        ``vmap`` of :meth:`update`; policies with a sparser real-work
        cadence (lychee's ``max_chunk`` graft) override this to skip the
        whole vmapped computation when no slot is due.

        ``keys`` may be a batched contiguous cache OR a ``PagedKV`` view
        (shared pool + per-slot page-table rows): ``kv_batch_axes`` maps
        only the table row, never the pool."""
        if not self.has_update or state is None:
            return state
        return jax.vmap(self.update, in_axes=(0, kv_batch_axes(keys), 0))(
            state, keys, t)

    def extend(self, state, keys: jax.Array, t0, n_new: int):
        """Streaming multi-token append — the session-reuse primitive
        (``model.extend_slot``): fold the ``n_new`` cache rows written at
        positions ``[t0, t0 + n_new)`` of ``keys`` (H, N, d) into the state
        WITHOUT rebuilding it, exactly as if those tokens had been decoded
        one by one (lychee grafts dynamic chunks at its ``max_chunk``
        cadence via ``lazy_update``; quest extends tail-page min/max bounds;
        clusterkv assigns each token to its nearest centroid). ``t0`` is the
        slot's length BEFORE the delta (traced ok); ``n_new`` is static.

        The default replays :meth:`update` over the delta with a
        ``fori_loop`` — per-token updates are cheap and the loop keeps the
        HLO O(1) in the delta length — and is exactly the trajectory a
        decoded session would have followed, so a subsequent decode behaves
        identically to one that streamed those tokens. ``n_new`` may be a
        TRACED scalar (a right-padded chunk's valid length under prompt
        bucketing): the replay then folds only the valid rows.
        """
        if not self.has_update or state is None:
            return state
        if isinstance(n_new, int) and n_new == 0:
            return state
        t0 = jnp.asarray(t0, jnp.int32)
        return jax.lax.fori_loop(
            0, jnp.asarray(n_new, jnp.int32),
            lambda i, s: self.update(s, keys, t0 + 1 + i), state)

    def extend_batched(self, state, keys: jax.Array, t0: jax.Array,
                       n_new):
        """vmap :meth:`extend` over the slot axis. keys: (B, H, N, d);
        t0: (B,) per-slot lengths before the delta; n_new: scalar shared by
        every slot (traced ok)."""
        if not self.has_update or state is None:
            return state
        if isinstance(n_new, int) and n_new == 0:
            return state
        return jax.vmap(lambda s, k, t: self.extend(s, k, t, n_new))(
            state, keys, jnp.asarray(t0, jnp.int32))

    def pad(self, state, N_cap: int):
        """Grow a short-prompt state to the capacities of ``N_cap``."""
        return state

    def reset(self, state):
        """Empty state with the same static shapes (zero leaves ARE the
        empty state for every registered policy — the contract
        ``models.model.reset_slot`` relies on)."""
        return None if state is None else jax.tree.map(jnp.zeros_like, state)

    def splice_prefix(self, state, keep: int):
        """Truncate a donated prefix state to its first ``keep`` tokens —
        the prefix-cache partial-hit primitive: the reader slot inherits a
        snapshot built over a LONGER prefix and must behave as if only
        ``keep`` tokens exist. Sound means valid selections never address
        positions ``>= keep``; it need not equal a fresh ``keep``-token
        build bit-for-bit (clustering over a shorter prompt may differ).
        Exact full hits (``keep`` == snapshot length) bypass this entirely.
        Trailing-axis op: ``state`` may carry arbitrary leading stack dims
        (groups, slots). Identity for stateless policies."""
        return state


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Type[CachePolicy]] = {}


def register_policy(cls: Type[CachePolicy]) -> Type[CachePolicy]:
    assert cls.name, f"{cls.__name__} needs a name"
    _REGISTRY[cls.name] = cls
    return cls


def list_policies() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def make_policy(name: str, cfg: LycheeConfig) -> CachePolicy:
    if name not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](cfg)


def policy_for(cfg: LycheeConfig) -> CachePolicy:
    """Resolve the effective policy of a config (``enabled=False`` forces
    ``dense`` — the pre-policy ``--no-lychee`` behaviour)."""
    return make_policy(cfg.policy if cfg.enabled else "dense", cfg)


# ---------------------------------------------------------------------------
# LycheeCluster (paper §4) — wraps the existing index, bit-identical
# ---------------------------------------------------------------------------
@register_policy
class LycheePolicy(CachePolicy):
    name = "lychee"
    needs_layout = True

    def empty(self, N, H, d, dtype=jnp.float32):
        return empty_index(N, H, d, self.cfg, dtype)

    def build(self, keys, layout, n_cache, n_tokens=None):
        return pad_index(build_index(keys, layout, self.cfg,
                                     n_tokens=n_tokens), n_cache, self.cfg)

    def select(self, state, probe, t):
        starts, lens, _ = retrieve_spans(state, probe, self.cfg)
        return starts, lens

    def update(self, state, keys, t):
        return maybe_lazy_update(state, keys, t, self.cfg)

    def update_batched(self, state, keys, t):
        """Graft-cadence gate: a dynamic chunk is grafted only when some
        slot's ``t`` hits a ``max_chunk`` boundary (and that slot's index
        still has capacity), so on most decode steps the whole vmapped
        graft — pooling, nearest-cluster search, centroid/radius/member
        scatters — is skipped by one ``lax.cond``. When the cond IS taken
        the per-slot ``maybe_lazy_update`` selects exactly as before — same
        math as the ungated vmap (identical up to XLA fusion order)."""
        due = jnp.any(((jnp.asarray(t, jnp.int32) % self.cfg.max_chunk) == 0)
                      & (state.chunk_count < state.chunk_start.shape[-1]))
        return jax.lax.cond(
            due,
            lambda s: jax.vmap(
                lambda sb, kb, tb: maybe_lazy_update(sb, kb, tb, self.cfg),
                in_axes=(0, kv_batch_axes(keys), 0))(s, keys, t),
            lambda s: s, state)

    def pad(self, state, N_cap):
        return pad_index(state, N_cap, self.cfg)

    def splice_prefix(self, state, keep):
        """Invalidate every chunk extending past ``keep``. Retrieval does
        NOT consult ``chunk_valid`` (only fine-member lists), so soundness
        comes from zeroing ``chunk_len``: stale member references expand to
        zero-length spans and contribute exactly nothing. ``chunk_count``
        is deliberately NOT compacted — the truncated slots stay consumed,
        so later lazy grafts can never reuse a slot that old member lists
        still point at (the resurrection hazard ``lazy_update`` documents).
        Centroids/radii keep covering the dropped chunks: Eqn. 2 bounds
        stay valid, merely looser."""
        kept = state.chunk_valid & (
            state.chunk_start + state.chunk_len <= jnp.int32(keep))
        return state._replace(
            chunk_len=jnp.where(kept, state.chunk_len, 0),
            chunk_valid=kept)


# ---------------------------------------------------------------------------
# Quest (Tang et al., 2024)
# ---------------------------------------------------------------------------
class QuestState(NamedTuple):
    """Per-page min/max key bounds. Pg = ceil(n_cache / page)."""

    kmin: jax.Array     # (H, Pg, d)
    kmax: jax.Array     # (H, Pg, d)
    pvalid: jax.Array   # (H, Pg) bool


@register_policy
class QuestPolicy(CachePolicy):
    name = "quest"

    @property
    def span_len(self) -> int:
        return self.cfg.quest_page

    def empty(self, N, H, d, dtype=jnp.float32):
        Pg = max(1, -(-N // self.cfg.quest_page))
        return QuestState(kmin=jnp.zeros((H, Pg, d), dtype),
                          kmax=jnp.zeros((H, Pg, d), dtype),
                          pvalid=jnp.zeros((H, Pg), bool))

    def build(self, keys, layout, n_cache, n_tokens=None):
        H, S, d = keys.shape
        page = self.cfg.quest_page
        Pg = max(1, -(-max(n_cache, S) // page))
        t = jnp.int32(S) if n_tokens is None else jnp.asarray(n_tokens,
                                                              jnp.int32)
        kp = jnp.pad(keys, ((0, 0), (0, Pg * page - S), (0, 0)))
        tmask = (jnp.arange(Pg * page) < t).reshape(Pg, page)
        kp = kp.reshape(H, Pg, page, d)
        kmin = jnp.min(jnp.where(tmask[None, :, :, None], kp, jnp.inf), 2)
        kmax = jnp.max(jnp.where(tmask[None, :, :, None], kp, -jnp.inf), 2)
        pvalid = jnp.broadcast_to(jnp.any(tmask, 1)[None], (H, Pg))
        kmin = jnp.where(pvalid[..., None], kmin, 0.0).astype(keys.dtype)
        kmax = jnp.where(pvalid[..., None], kmax, 0.0).astype(keys.dtype)
        return QuestState(kmin=kmin, kmax=kmax, pvalid=pvalid)

    def select(self, state, probe, t):
        H, Pg, d = state.kmin.shape
        page = self.cfg.quest_page
        k_pages = max(1, min(self.cfg.budget // page, Pg))

        t = jnp.asarray(t, jnp.int32)

        def per_head(h):
            q = probe[h]
            # Quest Eq. 3 upper bound: per-dim max of q*min / q*max
            score = jnp.sum(jnp.maximum(q * state.kmin[h],
                                        q * state.kmax[h]), -1)
            score = jnp.where(state.pvalid[h], score, _NEG)
            top_s, top_p = jax.lax.top_k(score, k_pages)
            ok = top_s > _NEG / 2
            starts = (top_p * page).astype(jnp.int32)
            # clip the tail page at the valid length so direct span->token
            # consumers never see phantom positions >= t
            lens = jnp.where(ok, jnp.clip(t - starts, 0, page), 0)
            return starts, lens.astype(jnp.int32)

        return jax.vmap(per_head)(jnp.arange(H))

    def update(self, state, keys, t):
        """Extend the tail page's min/max with the freshly appended key."""
        H, Pg, d = state.kmin.shape
        page = self.cfg.quest_page
        tpos = jnp.clip(jnp.asarray(t, jnp.int32) - 1, 0, kv_len(keys) - 1)
        row = kv_row(keys, tpos).astype(state.kmin.dtype)     # (H, d)
        p = jnp.clip(tpos // page, 0, Pg - 1)
        was = state.pvalid[:, p]                              # (H,)
        nmin = jnp.where(was[:, None],
                         jnp.minimum(state.kmin[:, p], row), row)
        nmax = jnp.where(was[:, None],
                         jnp.maximum(state.kmax[:, p], row), row)
        return QuestState(
            kmin=jax.lax.dynamic_update_slice(state.kmin, nmin[:, None, :],
                                              (0, p, 0)),
            kmax=jax.lax.dynamic_update_slice(state.kmax, nmax[:, None, :],
                                              (0, p, 0)),
            pvalid=state.pvalid.at[:, p].set(True))

    def splice_prefix(self, state, keep):
        """Keep only pages FULLY inside ``keep``. Partial hits land on
        page-pool boundaries that are multiples of ``quest_page`` (the
        pool's span-base contract), so the cut never bisects a quest page
        and the kept bounds are exactly what a ``keep``-token build would
        produce; zeroed bounds on dropped pages mirror ``build``."""
        Pg = state.pvalid.shape[-1]
        full = (jnp.arange(Pg, dtype=jnp.int32) + 1) * self.cfg.quest_page \
            <= jnp.int32(keep)
        pvalid = state.pvalid & full
        z = jnp.zeros((), state.kmin.dtype)
        return QuestState(
            kmin=jnp.where(pvalid[..., None], state.kmin, z),
            kmax=jnp.where(pvalid[..., None], state.kmax, z),
            pvalid=pvalid)


# ---------------------------------------------------------------------------
# ClusterKV (Liu et al., 2025)
# ---------------------------------------------------------------------------
class ClusterKVState(NamedTuple):
    """Token-granular spherical clusters. C = n_cache // tokens_per_cluster;
    cap = tokens_per_cluster * cap_factor member slots per cluster."""

    centroid: jax.Array   # (H, C, d) unit-norm
    cvalid: jax.Array     # (H, C) bool
    members: jax.Array    # (H, C, cap) int32 token positions, -1 pad
    nmember: jax.Array    # (H, C) int32 (counts overflow beyond cap too)


@register_policy
class ClusterKVPolicy(CachePolicy):
    name = "clusterkv"

    @property
    def span_len(self) -> int:
        return 1                   # token-granular: every span is one token

    def _dims(self, N: int) -> Tuple[int, int]:
        tpc = self.cfg.ckv_tokens_per_cluster
        return max(1, N // tpc), tpc * self.cfg.ckv_cap_factor

    def empty(self, N, H, d, dtype=jnp.float32):
        C, cap = self._dims(N)
        return ClusterKVState(centroid=jnp.zeros((H, C, d), dtype),
                              cvalid=jnp.zeros((H, C), bool),
                              members=jnp.zeros((H, C, cap), jnp.int32),
                              nmember=jnp.zeros((H, C), jnp.int32))

    def build(self, keys, layout, n_cache, n_tokens=None):
        H, S, d = keys.shape
        C_cap, cap = self._dims(max(n_cache, S))
        C_s = min(max(1, S // self.cfg.ckv_tokens_per_cluster), C_cap)
        t = jnp.int32(S) if n_tokens is None else jnp.asarray(n_tokens,
                                                              jnp.int32)
        mask = jnp.arange(S) < t
        kn = l2_normalize(keys) * mask[None, :, None]

        def per_head(kh):
            km = spherical_kmeans(kh, mask, C_s, self.cfg.kmeans_iters)
            members, nm = build_member_lists(km.assign, mask, C_s, cap)
            return km.centroid, km.valid, members, nm

        cent, valid, members, nm = jax.vmap(per_head)(kn)
        padC = C_cap - C_s
        return ClusterKVState(
            centroid=jnp.pad(cent, ((0, 0), (0, padC), (0, 0))),
            cvalid=jnp.pad(valid, ((0, 0), (0, padC))),
            members=jnp.pad(members, ((0, 0), (0, padC), (0, 0)),
                            constant_values=-1),
            nmember=jnp.pad(nm, ((0, 0), (0, padC))))

    def select(self, state, probe, t):
        H, C, d = state.centroid.shape
        cap = state.members.shape[-1]
        k_cl = max(1, min(self.cfg.budget // self.cfg.ckv_tokens_per_cluster,
                          C))

        def per_head(h):
            score = jnp.einsum("cd,d->c", state.centroid[h], probe[h])
            score = jnp.where(state.cvalid[h], score, _NEG)
            top_s, top_c = jax.lax.top_k(score, k_cl)
            ok = top_s > _NEG / 2
            tok = state.members[h][top_c].reshape(-1)          # (k_cl*cap,)
            m = (tok >= 0) & jnp.repeat(ok, cap)
            return jnp.maximum(tok, 0), m.astype(jnp.int32)

        return jax.vmap(per_head)(jnp.arange(H))

    def update(self, state, keys, t):
        """Assign the appended token to its nearest valid centroid: moving-
        average (spherical) centroid shift + member-list append, mirroring
        the Lychee dynamic-chunk graft at token granularity."""
        H, C, d = state.centroid.shape
        cap = state.members.shape[-1]
        tpos = jnp.clip(jnp.asarray(t, jnp.int32) - 1, 0, kv_len(keys) - 1)
        row = l2_normalize(kv_row(keys, tpos).astype(state.centroid.dtype))
        sim = jnp.einsum("hcd,hd->hc", state.centroid, row)
        sim = jnp.where(state.cvalid, sim, _NEG)
        cid = jnp.argmax(sim, axis=-1).astype(jnp.int32)       # (H,)
        heads = jnp.arange(H)
        live = state.cvalid.any(axis=-1)                       # (H,) gate

        n = state.nmember[heads, cid].astype(state.centroid.dtype)
        mu = state.centroid[heads, cid]
        mu_new = l2_normalize((mu * n[:, None] + row) / (n[:, None] + 1.0))
        centroid = state.centroid.at[heads, cid].set(
            jnp.where(live[:, None], mu_new, mu))

        pos = jnp.minimum(state.nmember[heads, cid], cap - 1)
        ok = live & (state.nmember[heads, cid] < cap)
        members = state.members.at[
            heads, jnp.where(ok, cid, 0), jnp.where(ok, pos, 0)].set(
            jnp.where(ok, tpos, state.members[heads, 0, 0]))
        nmember = state.nmember.at[heads, cid].add(live.astype(jnp.int32))
        return ClusterKVState(centroid=centroid, cvalid=state.cvalid,
                              members=members, nmember=nmember)

    def splice_prefix(self, state, keep):
        """Drop member positions ``>= keep`` (-1-padded, exactly what the
        span expansion masks); clusters left empty go invalid. Centroids
        are left where the donor's longer prefix moved them — stale but
        still spherical means over a superset, so nearest-centroid
        assignment stays an approximation of the same quality class as the
        streaming updates themselves."""
        kept = (state.members >= 0) & (state.members < jnp.int32(keep))
        nmember = kept.sum(-1).astype(state.nmember.dtype)
        cvalid = state.cvalid & (nmember > 0)
        return ClusterKVState(
            centroid=state.centroid, cvalid=cvalid,
            members=jnp.where(kept, state.members, -1), nmember=nmember)


# ---------------------------------------------------------------------------
# StreamingLLM (Xiao et al., 2024) — sink + window only, no state
# ---------------------------------------------------------------------------
@register_policy
class StreamingPolicy(CachePolicy):
    name = "streaming"
    stateful = False
    has_update = False

    def select(self, state, probe, t):
        """Retrieves nothing: the active set degenerates to the shared
        sink + recent-buffer spans added by ``assemble_spans``."""
        H = probe.shape[0]
        return (jnp.zeros((H, 1), jnp.int32), jnp.zeros((H, 1), jnp.int32))


# ---------------------------------------------------------------------------
# Dense — full cache attention, no selection at all
# ---------------------------------------------------------------------------
@register_policy
class DensePolicy(CachePolicy):
    name = "dense"
    stateful = False
    has_update = False
    is_dense = True
