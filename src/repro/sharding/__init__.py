from repro.sharding.ctx import (axis_in_mesh, batch_axes, context_parallel,
                                current_mesh, is_context_parallel,
                                mesh_context, shard)
from repro.sharding.rules import decode_state_specs, param_specs

__all__ = ["axis_in_mesh", "batch_axes", "context_parallel", "current_mesh",
           "decode_state_specs", "is_context_parallel", "mesh_context", "param_specs", "shard",
           ]
