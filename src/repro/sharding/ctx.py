"""Ambient-mesh context so model code can annotate activation shardings
without threading a mesh through every call. On CPU tests (no mesh entered)
the annotations are no-ops, so a single code path serves smoke tests and the
multi-pod dry-run.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Union

import jax
from jax.sharding import Mesh, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    """Enter both our ambient context and jax's mesh context.

    jax.sharding.set_mesh is the modern entry point; older jax uses the
    Mesh object itself as the resource-environment context manager.
    """
    prev = current_mesh()
    _state.mesh = mesh
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    try:
        if set_mesh is not None:
            with set_mesh(mesh):
                yield mesh
        else:
            with mesh:
                yield mesh
    finally:
        _state.mesh = prev


def axis_in_mesh(name: str) -> bool:
    mesh = current_mesh()
    return mesh is not None and name in mesh.axis_names


@contextlib.contextmanager
def serving_mode(enabled: bool = True):
    """Inference param layout (§Perf iteration 3): no optimizer exists, so
    MoE expert weights shard over ('model','data') jointly (e.g. DeepSeek's
    256 experts over 256 chips, one expert each) instead of FSDP — kills
    the per-decode-step weight all-gathers."""
    prev = getattr(_state, "serving", False)
    _state.serving = enabled
    try:
        yield
    finally:
        _state.serving = prev


def is_serving() -> bool:
    return getattr(_state, "serving", False)


@contextlib.contextmanager
def context_parallel(enabled: bool = True):
    """When the batch is too small to occupy the data axis (long_500k decode
    has batch=1), shard the KV-cache *context* dim over ('pod','data')
    instead of the batch dim — sequence/context parallelism."""
    prev = getattr(_state, "ctx_parallel", False)
    _state.ctx_parallel = enabled
    try:
        yield
    finally:
        _state.ctx_parallel = prev


def is_context_parallel() -> bool:
    return getattr(_state, "ctx_parallel", False)


def batch_axes() -> Union[None, str, tuple]:
    """The axes the global batch is sharded over ('pod' first if present)."""
    mesh = current_mesh()
    if mesh is None:
        return None
    if "pod" in mesh.axis_names:
        return ("pod", "data")
    return "data"


def kv_axes():
    """Sharding tokens for a (B, H, N, d) decode KV cache under the current
    policy (must agree with rules.decode_state_specs):

    * context-parallel (batch too small, long_500k): context over every
      mesh axis, batch/heads replicated;
    * batched decode (decode_32k): batch over ('pod','data'), context over
      'model' — the cache is the dominant bytes term, so the long dim gets
      the remaining axis; heads stay unsharded.
    """
    mesh = current_mesh()
    if mesh is None:
        return (None, None, None, None)
    if is_context_parallel():
        ctx = tuple(a for a in ("pod", "data", "model")
                    if a in mesh.axis_names)
        return (None, None, ctx, None)
    return (batch_axes(), None, "model", None)


def _filter(spec_axes) -> P:
    """Drop axes not present in the current mesh (e.g. 'pod' on 1 pod)."""
    mesh = current_mesh()
    out = []
    for a in spec_axes:
        if a is None:
            out.append(None)
        elif isinstance(a, tuple):
            kept = tuple(x for x in a if x in mesh.axis_names)
            out.append(kept if kept else None)
        else:
            out.append(a if a in mesh.axis_names else None)
    return P(*out)


def shard(x, *spec_axes):
    """``with_sharding_constraint`` iff a mesh is ambient; else identity.

    Axis tokens: mesh axis names, ``"batch"`` (expands to ('pod','data')),
    tuples of axis names, or None.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    expanded = []
    for a in spec_axes:
        if a == "batch":
            expanded.append(None if is_context_parallel() else batch_axes())
        elif a == "ctx":
            expanded.append(batch_axes() if is_context_parallel() else None)
        else:
            expanded.append(a)
    return jax.lax.with_sharding_constraint(x, _filter(expanded))
