"""Path-based parameter partitioning rules.

Params are plain nested dicts; the leaf *name* (last path key) determines the
PartitionSpec, with the convention that scanned ("stacked") parameters carry
a leading ``groups`` dimension (detected from the path) that is never
sharded. ``fsdp`` adds 'data'-axis sharding of the non-model weight dim for
the very large architectures (intra-pod only — cross-pod param gathers over
DCN would dominate; see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _leaf_spec(name: str, ndim: int, cfg, fsdp: Optional[str],
               expert_parallel: bool) -> P:
    # 2D projections -------------------------------------------------------
    if name in ("wq", "wk", "wv", "wkv", "w_gate", "w_in", "w_uq", "w_uk",
                "w_uv", "w_inproj", "w_up"):
        spec = (fsdp, "model")
    elif name in ("wo", "w_out", "w_outproj", "w_down"):
        spec = ("model", fsdp)
    elif name in ("tok_embed",):
        spec = ("model", fsdp)
    elif name in ("out_head",):
        spec = (fsdp, "model")
    elif name in ("we_gate", "we_in"):      # (E, d, f)
        if expert_parallel == "ep2":        # serving: E over model×data
            spec = (("model", "data"), None, None)
        elif expert_parallel:
            spec = ("model", fsdp, None)
        else:
            spec = (None, fsdp, "model")
    elif name in ("we_out",):               # (E, f, d)
        if expert_parallel == "ep2":
            spec = (("model", "data"), None, None)
        elif expert_parallel:
            spec = ("model", None, fsdp)
        else:
            spec = (None, "model", fsdp)
    elif name in ("conv_w",):               # (width, channels)
        spec = (None, "model")
    elif name in ("A_log", "D", "dt_bias"):  # (ssm_heads,)
        spec = ("model",)
    else:
        # norms, biases, routers, pos embeddings, small vectors: replicated
        spec = ()
    spec = spec[:ndim]
    pad = ndim - len(spec)
    return P(*((None,) * pad + tuple(spec)))


def param_specs(params, cfg, mesh: Optional[Mesh] = None,
                serving: bool = False):
    """PartitionSpec tree matching ``params``. If ``mesh`` is given, leaves
    whose sharded dim is not divisible by the axis size fall back to
    replication on that axis (e.g. 8 mixtral experts on a 16-way model axis
    keep experts replicated and shard ff instead — handled by the EP flag).
    ``serving=True`` (no optimizer state): experts shard over
    ('model','data') jointly when divisible, and FSDP is dropped for the
    dense weights of EP2 archs — no per-decode-step weight gathers."""
    fsdp = "data" if cfg.fsdp else None
    model_size = mesh.shape.get("model", 1) if mesh is not None else 1
    data_size = mesh.shape.get("data", 1) if mesh is not None else 1
    ep = cfg.n_experts > 0 and model_size > 1 and \
        cfg.n_experts % model_size == 0
    if serving and cfg.n_experts and \
            cfg.n_experts % (model_size * data_size) == 0:
        ep = "ep2"
        fsdp = None         # dense weights fit once experts are 256-way

    def fix(path, leaf):
        name = None
        for k in reversed(path):
            if isinstance(k, jax.tree_util.DictKey):
                name = k.key
                break
        spec = _leaf_spec(name or "", leaf.ndim, cfg, fsdp, ep)
        if mesh is not None:
            parts = []
            for dim, ax in zip(leaf.shape, spec):
                ok = ax is not None and all(
                    a in mesh.axis_names for a in
                    (ax if isinstance(ax, tuple) else (ax,)))
                if ok:
                    size = 1
                    for a in (ax if isinstance(ax, tuple) else (ax,)):
                        size *= mesh.shape[a]
                    parts.append(ax if dim % size == 0 else None)
                else:
                    parts.append(None)
            spec = P(*parts)
        return spec

    return jax.tree_util.tree_map_with_path(fix, params)


def named(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Decode-state sharding
# ---------------------------------------------------------------------------
# Per-leaf layout AFTER the (groups?, batch) prefix. Tokens: "H" = kv-head
# dim (model axis when divisible), "ctx" = context/chunk/cluster dim (the
# long axis — sharded over ctx_axes), None = replicated.
_STATE_LAYOUTS = {
    "k": ("H", "ctx", None), "v": ("H", "ctx", None),
    "latent": ("ctx", None),
    # paged KV pool leaves are BATCHLESS (groups lead directly): full-rank
    # layouts so the (groups?, batch) prefix heuristic never puts the batch
    # axes on the groups dim. The pool-row dim is the context memory.
    "pool_k": (None, "H", "ctx", None), "pool_v": (None, "H", "ctx", None),
    "pool_latent": (None, "ctx", None),
    "page_tbl": (None,),     # (B, max_pages): tiny, rows follow their slot
    "enc_k": ("H", None, None), "enc_v": ("H", None, None),
    "ssm": ("H", None, None),
    "conv": (None, "H"),
    "C": ("H", None, None),
    "c": ("H", None), "h": ("H", None), "m": ("H",),
    # LycheeIndex fields
    "chunk_key": (None, "ctx", None),
    "chunk_start": ("ctx",), "chunk_len": ("ctx",), "chunk_valid": ("ctx",),
    "chunk_count": (),
    "fine_centroid": (None, "ctx", None),
    "fine_radius": (None, "ctx"), "fine_size": (None, "ctx"),
    "fine_valid": (None, "ctx"), "fine_nchunks": (None, "ctx"),
    "fine2coarse": (None, "ctx"),
    "fine_chunks": (None, "ctx", None),
    "coarse_centroid": (None, None, None), "coarse_radius": (None, None),
    "coarse_size": (None, None), "coarse_valid": (None, None),
    "coarse_children": (None, None, None), "coarse_nchild": (None, None),
    # QuestState fields (page dim = ctx)
    "kmin": (None, "ctx", None), "kmax": (None, "ctx", None),
    "pvalid": (None, "ctx"),
    # ClusterKVState fields (cluster dim = ctx)
    "centroid": (None, "ctx", None), "cvalid": (None, "ctx"),
    "members": (None, "ctx", None), "nmember": (None, "ctx"),
    "t": (),
}


def _path_name(path) -> str:
    for k in reversed(path):
        if isinstance(k, jax.tree_util.DictKey):
            return k.key
        if isinstance(k, jax.tree_util.GetAttrKey):
            return k.name
    return ""


def decode_state_specs(state_shapes, mesh: Mesh, batch_axes, ctx_axes):
    """PartitionSpec tree for a decode/prefill state pytree (of
    ShapeDtypeStructs or arrays).

    batch_axes: axes for the batch dim (e.g. ("pod","data")) or None.
    ctx_axes: axes for the long context/chunk/cluster dims (e.g. ("model",)
    for decode_32k — batch occupies data — or ("data","model") for the
    batch-1 long_500k context-parallel decode).
    """
    def ax_size(ax):
        if ax is None:
            return 1
        axs = ax if isinstance(ax, tuple) else (ax,)
        s = 1
        for a in axs:
            if a not in mesh.axis_names:
                return 0          # axis missing -> unusable
            s *= mesh.shape[a]
        return s

    def fix(path, leaf):
        if not hasattr(leaf, "ndim"):
            return P()
        name = _path_name(path)
        # the "n" field is ambiguous: mlstm normaliser (…, H, d, 1) ends in
        # a singleton; slstm's is (…, H, dh)
        layout = _STATE_LAYOUTS.get(name)
        if name == "n":
            layout = ("H", None, None) if leaf.shape[-1] == 1 else ("H", None)
        if layout is None:
            return P(*([None] * leaf.ndim))
        nd = leaf.ndim
        ntrail = len(layout)
        if ntrail > nd:
            return P(*([None] * nd))
        # prefix = (groups?, batch) — batch sits right before the layout dims
        nprefix = nd - ntrail
        parts = [None] * nd
        used = set()
        if nprefix >= 1 and batch_axes is not None:
            bsz = ax_size(batch_axes)
            if bsz and leaf.shape[nprefix - 1] % bsz == 0 and \
                    leaf.shape[nprefix - 1] > 0:
                parts[nprefix - 1] = batch_axes
                used |= set(batch_axes if isinstance(batch_axes, tuple)
                            else (batch_axes,))
        # ctx first (the big dim), then H if its axis is still free
        for i, tok in enumerate(layout):
            if tok != "ctx":
                continue
            dim = leaf.shape[nprefix + i]
            csz = ax_size(ctx_axes) if ctx_axes else 0
            caxs = set(ctx_axes if isinstance(ctx_axes, tuple)
                       else (ctx_axes,)) if ctx_axes else set()
            if csz and dim % csz == 0 and not (caxs & used):
                parts[nprefix + i] = ctx_axes
                used |= caxs
        for i, tok in enumerate(layout):
            if tok != "H":
                continue
            dim = leaf.shape[nprefix + i]
            if "model" in mesh.axis_names and "model" not in used and \
                    dim % mesh.shape["model"] == 0:
                parts[nprefix + i] = "model"
                used.add("model")
        return P(*parts)

    return jax.tree_util.tree_map_with_path(fix, state_shapes)
