"""Mamba2 block (SSD — state-space duality form) [Zamba2, arXiv:2411.15242].

The selective-SSM recurrence  h_t = a_t·h_{t-1} + dt_t·(B_t ⊗ x_t),
y_t = C_t·h_t + D·x_t  (scalar decay per head) is computed with the chunked
SSD algorithm: quadratic attention-like form inside chunks of Q tokens +
a tiny inter-chunk state scan — O(S·Q) work, no S×S tensor, TPU-friendly
einsums. ``chunked_ssd`` is shared with the xLSTM mLSTM cell (identical
algebra with (k, v, q, log f, i) in place of (B, x, C, log a, dt)).

Decode is the O(1) single-step recurrence on the (heads, headdim, state)
state — the reason hybrid/SSM archs run long_500k natively.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_rmsnorm, rmsnorm, trunc_normal
from repro.sharding.ctx import shard


def chunked_ssd(x: jax.Array, B: jax.Array, C: jax.Array, loga: jax.Array,
                gate: jax.Array, h0: jax.Array | None = None,
                chunk: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Chunked scan for  h_t = exp(loga_t)·h_{t-1} + gate_t·(B_t ⊗ x_t),
    y_t = C_t · h_t.

    x: (b, S, H, P) values; B/C: (b, S, H, N); loga/gate: (b, S, H).
    Returns (y (b, S, H, P), h_last (b, H, P, N)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        x, B, C, loga, gate = map(zf, (x, B, C, loga, gate))
    nc = (S + pad) // Q
    xc = x.reshape(b, nc, Q, H, P)
    Bc = B.reshape(b, nc, Q, H, N)
    Cc = C.reshape(b, nc, Q, H, N)
    lc = loga.reshape(b, nc, Q, H)
    gc = gate.reshape(b, nc, Q, H)

    s = jnp.cumsum(lc, axis=2)                        # (b,nc,Q,H) cum log-decay
    s_tot = s[:, :, -1]                               # (b,nc,H)

    # ---- intra-chunk (quadratic, causal) -----------------------------------
    # G[i,j] = (C_i·B_j) · exp(s_i - s_j) · gate_j,  j <= i
    # NB: mask INSIDE the exp — for j > i, s_i - s_j is positive and grows
    # with Q·|log f|, overflowing exp at seq >= ~128; masking after the exp
    # hits the classic jnp.where-gradient NaN (inf in the dead branch).
    dot = jnp.einsum("bnihd,bnjhd->bnhij", Cc, Bc)    # (b,nc,H,Q,Q)
    si = s.transpose(0, 1, 3, 2)                      # (b,nc,H,Q)
    dmat = si[..., :, None] - si[..., None, :]        # (b,nc,H,Q,Q)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    w = jnp.exp(jnp.where(causal, dmat, -1e30)) * dot
    w = w * gc.transpose(0, 1, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bnhij,bnjhp->bnihp", w, xc)

    # ---- chunk summary states ----------------------------------------------
    # S_n = Σ_j exp(s_tot - s_j)·gate_j·(B_j ⊗ x_j)   (b,nc,H,P,N)
    wj = jnp.exp(s_tot[:, :, None] - s) * gc          # (b,nc,Q,H)
    Sn = jnp.einsum("bnjh,bnjhp,bnjhd->bnhpd", wj, xc, Bc)

    # ---- inter-chunk scan ---------------------------------------------------
    def step(h, inp):
        st, dec = inp                                 # (b,H,P,N), (b,H)
        h_new = h * jnp.exp(dec)[..., None, None] + st
        return h_new, h                               # emit PREVIOUS state

    h_init = (jnp.zeros((b, H, P, N), x.dtype) if h0 is None else h0)
    h_last, h_prev = jax.lax.scan(
        step, h_init,
        (Sn.transpose(1, 0, 2, 3, 4), s_tot.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)          # (b,nc,H,P,N)

    # ---- inter-chunk contribution -------------------------------------------
    y_inter = jnp.einsum("bnihd,bnhpd,bnih->bnihp", Cc, h_prev,
                         jnp.exp(s))
    y = (y_intra + y_inter).reshape(b, nc * Q, H, P)[:, :S + 0]
    if pad:
        y = y[:, :S]
    return y, h_last


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def _dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads
    P = di // H
    N = cfg.ssm_state
    return di, H, P, N


def init_mamba2(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, H, P, N = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * N
    return {
        "w_inproj": trunc_normal(ks[0], (d, 2 * di + 2 * N + H), dt),
        "conv_w": trunc_normal(ks[1], (cfg.conv_width, conv_ch), dt,
                               scale=0.2),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": init_rmsnorm(di, dt),
        "w_outproj": trunc_normal(ks[2], (di, d), dt, scale=0.02 / 2),
    }


def _split_proj(p, x, cfg):
    di, H, P, N = _dims(cfg)
    zxbcdt = x @ p["w_inproj"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, xin, Bc, Cc, dt


def _causal_conv(p, u, cfg):
    """u: (b, S, ch) depthwise causal conv, width cw."""
    cw = cfg.conv_width
    upad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(upad[:, i:i + u.shape[1]] * p["conv_w"][i]
              for i in range(cw))
    return jax.nn.silu(out)


def mamba2_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). Training/prefill (full sequence)."""
    b, S, d = x.shape
    di, H, P, N = _dims(cfg)
    z, xin, Bc, Cc, dtp = _split_proj(p, x, cfg)
    conv_in = jnp.concatenate([xin, Bc, Cc], -1)
    conv_out = _causal_conv(p, conv_in, cfg)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dtp.astype(jnp.float32)
                         + p["dt_bias"])               # (b,S,H)
    A = -jnp.exp(p["A_log"])                           # (H,)
    loga = dt * A                                      # (b,S,H)
    xh = xin.reshape(b, S, H, P)
    Bh = jnp.broadcast_to(Bc[:, :, None], (b, S, H, N))
    Ch = jnp.broadcast_to(Cc[:, :, None], (b, S, H, N))
    y, _ = chunked_ssd(xh.astype(jnp.float32), Bh.astype(jnp.float32),
                       Ch.astype(jnp.float32), loga, dt)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, S, di).astype(x.dtype) * jax.nn.silu(z)
    out = rmsnorm(p["norm"], y) @ p["w_outproj"]
    return shard(out, "batch", None, None)


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    di, H, P, N = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * N), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def mamba2_prefill_state(p: dict, x: jax.Array, cfg: ModelConfig) -> dict:
    """Run the forward and return the final recurrent state for decode."""
    b, S, d = x.shape
    di, H, P, N = _dims(cfg)
    z, xin, Bc, Cc, dtp = _split_proj(p, x, cfg)
    conv_in = jnp.concatenate([xin, Bc, Cc], -1)
    conv_state = conv_in[:, -(cfg.conv_width - 1):]
    conv_out = _causal_conv(p, conv_in, cfg)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(b, S, H, P)
    Bh = jnp.broadcast_to(Bc[:, :, None], (b, S, H, N))
    Ch = jnp.broadcast_to(Cc[:, :, None], (b, S, H, N))
    _, h_last = chunked_ssd(xh.astype(jnp.float32), Bh.astype(jnp.float32),
                            Ch.astype(jnp.float32), dt * A, dt)
    return {"conv": conv_state.astype(x.dtype), "ssm": h_last}


def mamba2_decode(p: dict, x: jax.Array, state: dict,
                  cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x: (B, 1, d). O(1) recurrent step."""
    b = x.shape[0]
    di, H, P, N = _dims(cfg)
    z, xin, Bc, Cc, dtp = _split_proj(p, x, cfg)        # (b,1,·)
    u = jnp.concatenate([xin, Bc, Cc], -1)              # (b,1,ch)
    conv_hist = jnp.concatenate([state["conv"], u], 1)  # (b,cw,ch)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", conv_hist, p["conv_w"]))[:, None]
    xin, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                  # (b,H)
    xh = xin[:, 0].reshape(b, H, P).astype(jnp.float32)
    Bh = Bc[:, 0].astype(jnp.float32)                    # (b,N)
    Ch = Cc[:, 0].astype(jnp.float32)
    h = state["ssm"] * a[..., None, None] + \
        dt[..., None, None] * jnp.einsum("bhp,bn->bhpn", xh, Bh)
    y = jnp.einsum("bhpn,bn->bhp", h, Ch) + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype) * jax.nn.silu(z)
    out = rmsnorm(p["norm"], y) @ p["w_outproj"]
    new_state = {"conv": conv_hist[:, 1:], "ssm": h}
    return shard(out, "batch", None, None), new_state
