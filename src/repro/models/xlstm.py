"""xLSTM blocks (mLSTM + sLSTM) [arXiv:2405.04517] — xLSTM[1:1] layout.

mLSTM: matrix memory  C_t = f_t·C_{t-1} + i_t·(v_t k_tᵀ),  read h = C_t q_t
with a dot-product normaliser. Training uses the same chunked-SSD algebra as
Mamba2 (k→B, v→x, q→C, log f→loga, i→gate); the normaliser n_t runs through
the identical recurrence with v ≡ 1. Exponential gating is tamed with a
per-chunk stabilised form (global running-max stabilisation is decode-only,
where it is exact) — documented deviation, DESIGN.md §2.

sLSTM: scalar memory with TRUE hidden-state recurrence (recurrent weights R
act on h_{t-1}), so training scans over time — inherently sequential, kept
faithful to the paper.

Both are attention-free: LycheeCluster does not apply (no KV cache).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_rmsnorm, rmsnorm, trunc_normal
from repro.models.mamba2 import chunked_ssd
from repro.sharding.ctx import shard


def _hdims(cfg: ModelConfig):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return H, dh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, dh = _hdims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "wq": trunc_normal(ks[0], (d, d), dt),
        "wk": trunc_normal(ks[1], (d, d), dt),
        "wv": trunc_normal(ks[2], (d, d), dt),
        "w_gates": trunc_normal(ks[3], (d, 2 * H), dt),   # i, f pre-acts
        "w_ogate": trunc_normal(ks[4], (d, d), dt),
        "norm": init_rmsnorm(dh, dt),
        "w_out": trunc_normal(ks[5], (d, d), dt, scale=0.02 / 2),
    }


def _mlstm_qkvg(p, x, cfg):
    b, S, d = x.shape
    H, dh = _hdims(cfg)
    q = (x @ p["wq"]).reshape(b, S, H, dh)
    k = (x @ p["wk"]).reshape(b, S, H, dh) / dh ** 0.5
    v = (x @ p["wv"]).reshape(b, S, H, dh)
    gates = (x @ p["w_gates"]).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, -1)              # (b,S,H)
    logf = -jax.nn.softplus(-f_pre)                     # log sigmoid(f)
    i_g = jnp.exp(i_pre - 4.0)                          # tamed exp input gate
    o = jax.nn.sigmoid(x @ p["w_ogate"])
    return q, k, v, logf, i_g, o


def mlstm_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, S, d = x.shape
    H, dh = _hdims(cfg)
    q, k, v, logf, i_g, o = _mlstm_qkvg(p, x, cfg)
    y, _ = chunked_ssd(v.astype(jnp.float32), k.astype(jnp.float32),
                       q.astype(jnp.float32), logf, i_g)
    ones = jnp.ones_like(v[..., :1])
    n, _ = chunked_ssd(ones.astype(jnp.float32), k.astype(jnp.float32),
                       q.astype(jnp.float32), logf, i_g)
    h = y / jnp.maximum(jnp.abs(n), 1.0)                # (b,S,H,dh)
    h = rmsnorm(p["norm"], h.astype(x.dtype)).reshape(b, S, d)
    out = (h * o) @ p["w_out"]
    return shard(out, "batch", None, None)


def mlstm_init_state(cfg: ModelConfig, batch: int) -> dict:
    H, dh = _hdims(cfg)
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh, 1), jnp.float32)}


def mlstm_prefill_state(p: dict, x: jax.Array, cfg: ModelConfig) -> dict:
    b, S, d = x.shape
    q, k, v, logf, i_g, o = _mlstm_qkvg(p, x, cfg)
    _, C = chunked_ssd(v.astype(jnp.float32), k.astype(jnp.float32),
                       q.astype(jnp.float32), logf, i_g)
    ones = jnp.ones_like(v[..., :1])
    _, n = chunked_ssd(ones.astype(jnp.float32), k.astype(jnp.float32),
                       q.astype(jnp.float32), logf, i_g)
    return {"C": C, "n": n}


def mlstm_decode(p: dict, x: jax.Array, state: dict,
                 cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    b = x.shape[0]
    H, dh = _hdims(cfg)
    q, k, v, logf, i_g, o = _mlstm_qkvg(p, x, cfg)      # S=1
    f = jnp.exp(logf[:, 0])                             # (b,H)
    C = state["C"] * f[..., None, None] + i_g[:, 0][..., None, None] * \
        jnp.einsum("bhp,bhd->bhpd", v[:, 0].astype(jnp.float32),
                   k[:, 0].astype(jnp.float32))
    n = state["n"] * f[..., None, None] + i_g[:, 0][..., None, None] * \
        k[:, 0].astype(jnp.float32)[..., None]
    qf = q[:, 0].astype(jnp.float32)
    y = jnp.einsum("bhpd,bhd->bhp", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhdo,bhd->bho", n, qf))[..., 0],
                      1.0)
    h = y / den[..., None]
    h = rmsnorm(p["norm"], h.astype(x.dtype)).reshape(b, 1, -1)
    out = (h * o) @ p["w_out"]
    return shard(out, "batch", None, None), {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H, dh = _hdims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        # input weights for (z, i, f, o) gates
        "w_in": trunc_normal(ks[0], (d, 4 * d), dt),
        # block-diagonal recurrent weights per head
        "r_w": trunc_normal(ks[1], (H, dh, 4 * dh), dt),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "norm": init_rmsnorm(d, dt),
        "w_out": trunc_normal(ks[2], (d, d), dt, scale=0.02 / 2),
    }


def _slstm_step(p, cfg, carry, wx_t):
    """carry: (c, n, h, m) each (b, H, dh)."""
    H, dh = _hdims(cfg)
    c, n, h, m = carry
    b = h.shape[0]
    rh = jnp.einsum("bhd,hde->bhe", h, p["r_w"].astype(jnp.float32))
    pre = wx_t + rh.reshape(b, -1) + p["bias"]
    z, i_pre, f_pre, o_pre = jnp.split(pre.reshape(b, H, 4 * dh), 4, -1)
    # stabilised exponential gating (per-head scalar gates from mean pre-act)
    i_s = jnp.mean(i_pre, -1)
    f_s = jnp.mean(f_pre, -1)
    logf = -jax.nn.softplus(-f_s)
    m_new = jnp.maximum(logf + m, i_s)
    i_g = jnp.exp(i_s - m_new)[..., None]
    f_g = jnp.exp(logf + m - m_new)[..., None]
    c_new = f_g * c + i_g * jnp.tanh(z)
    n_new = f_g * n + i_g
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_init_state(cfg: ModelConfig, batch: int) -> Tuple:
    H, dh = _hdims(cfg)
    z = jnp.zeros((batch, H, dh), jnp.float32)
    m = jnp.full((batch, H), -1e9, jnp.float32)
    return {"c": z, "n": z, "h": z, "m": m}


def slstm_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                  state: dict | None = None,
                  return_state: bool = False):
    b, S, d = x.shape
    wx = (x @ p["w_in"]).astype(jnp.float32)            # (b,S,4d)
    st = state or slstm_init_state(cfg, b)
    carry = (st["c"], st["n"], st["h"], st["m"])
    (c, n, h, m), hs = jax.lax.scan(
        lambda cr, w: _slstm_step(p, cfg, cr, w), carry,
        wx.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2, 3).reshape(b, S, d).astype(x.dtype)
    out = rmsnorm(p["norm"], hs) @ p["w_out"]
    out = shard(out, "batch", None, None)
    if return_state:
        return out, {"c": c, "n": n, "h": h, "m": m}
    return out


def slstm_decode(p: dict, x: jax.Array, state: dict,
                 cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    out, st = slstm_forward(p, x, cfg, state, return_state=True)
    return out, st
