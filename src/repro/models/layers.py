"""Shared building blocks: norms, RoPE, MLPs, embeddings.

Pure-pytree modules: ``init_*`` returns a nested dict of arrays, ``*_apply``
consumes it. Compute norms/softmax in f32, matmuls in the param dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.ctx import shard


def trunc_normal(key, shape, dtype, scale: float = 0.02):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    # gemma-style (1 + scale); zero-init scale == identity for all archs
    return (xf * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * (1.0 + p["scale"].astype(jnp.float32))
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               heads: bool | None = None) -> jax.Array:
    """x: (..., S, H, dh) or (..., S, dh); positions: (..., S) — broadcasts
    over any leading batch dims of x not present in positions.

    ``heads`` marks whether x carries a head dim between S and dh. The
    default (None) infers it from the rank difference, which is ambiguous
    once positions themselves are batched (continuous batching decodes each
    slot at its own position) — those callers pass it explicitly."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                     # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, dh/2)
    if heads is None:
        heads = x.ndim - positions.ndim == 3
    if heads:                                         # head dim present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": trunc_normal(k1, (d, ff), dtype),
        "w_in": trunc_normal(k2, (d, ff), dtype),
        "w_out": trunc_normal(k3, (ff, d), dtype, scale=0.02 / 2),
    }


def mlp_apply(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    g = x @ p["w_gate"]
    h = x @ p["w_in"]
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    out = (g * h) @ p["w_out"]
    return shard(out, "batch", None, None)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------
def init_embed(key, vocab: int, d: int, dtype, tie: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok_embed": trunc_normal(k1, (vocab, d), dtype)}
    if not tie:
        p["out_head"] = trunc_normal(k2, (d, vocab), dtype)
    return p


def embed(p: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["tok_embed"], tokens, axis=0)


def unembed(p: dict, x: jax.Array, softcap: float = 0.0) -> jax.Array:
    if "out_head" in p:
        logits = x @ p["out_head"]
    else:
        logits = x @ p["tok_embed"].T
    logits = logits.astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return shard(logits, "batch", None, "model")
