"""DeepSeek-V3 Multi-head Latent Attention [arXiv:2412.19437 §2.1].

Low-rank joint KV compression (kv_lora=512) + decoupled RoPE keys (64).
The decode path uses the *absorbed* formulation: W_uk is folded into the
query (q̃ = W_ukᵀ q_nope) and W_uv into the output projection, so attention
runs directly over the cached 576-dim latents — the cache is never
decompressed. LycheeCluster indexes that latent cache as a single logical
kv head (the UB bound in latent space equals the bound on true logits,
because q_effᵀ·latent == the exact attention logit).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import full_decode_attention
from repro.core.attention import full_decode_attention_ctxsharded
from repro.core.policy import CachePolicy, policy_for
from repro.core.types import ChunkLayout
from repro.models.attention import _policy_attend, flash_attention
from repro.models.layers import (apply_rope, init_rmsnorm, rmsnorm,
                                 trunc_normal)
from repro.sharding.ctx import kv_axes, shard


def init_mla(key, cfg: ModelConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    qh = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "w_dq": trunc_normal(ks[0], (d, cfg.q_lora_rank), dt),
        "q_norm": init_rmsnorm(cfg.q_lora_rank, dt),
        "w_uq": trunc_normal(ks[1], (cfg.q_lora_rank, H * qh), dt),
        "w_dkv": trunc_normal(ks[2], (d, cfg.kv_lora_rank), dt),
        "kv_norm": init_rmsnorm(cfg.kv_lora_rank, dt),
        "w_kr": trunc_normal(ks[3], (d, cfg.qk_rope_dim), dt),
        "w_uk": trunc_normal(ks[4], (cfg.kv_lora_rank,
                                     H * cfg.qk_nope_dim), dt),
        "w_uv": trunc_normal(ks[5], (cfg.kv_lora_rank,
                                     H * cfg.v_head_dim), dt),
        "wo": trunc_normal(ks[6], (H * cfg.v_head_dim, d), dt,
                           scale=0.02 / 2),
    }


def _queries(p, x, positions, cfg):
    """Returns q_nope (B,S,H,nd), q_rope (B,S,H,rd). positions: (S,) or
    (B, S) per-slot."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(p["q_norm"], x @ p["w_dq"])
    q = (cq @ p["w_uq"]).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta, heads=True)
    return q_nope, q_rope


def _latents(p, x, positions, cfg):
    """Returns c_kv (B,S,kvl) normed, k_rope (B,S,rd) roped (shared heads)."""
    c_kv = rmsnorm(p["kv_norm"], x @ p["w_dkv"])
    k_rope = apply_rope(x @ p["w_kr"], positions, cfg.rope_theta,
                        heads=False)
    return c_kv, k_rope


def mla_forward(p: dict, x: jax.Array, positions: jax.Array,
                cfg: ModelConfig, n_tokens=None
                ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train/prefill). Returns (out, latent (B,S,576))
    where latent = concat(c_kv, k_rope) — the decode cache row. ``n_tokens``
    (scalar, traced ok) masks right-padded prompt rows out of the attention
    (prompt-length bucketing; pad outputs/latents are garbage the caller
    ignores or overwrites)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _queries(p, x, positions, cfg)
    c_kv, k_rope = _latents(p, x, positions, cfg)

    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, nd)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, vd)
    q = jnp.concatenate([q_nope, q_rope], -1).transpose(0, 2, 1, 3)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, rd))],
        -1).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    q = shard(q, "batch", "model", None, None)
    k = shard(k, "batch", "model", None, None)
    k_pos = positions
    if n_tokens is not None:
        n = jnp.asarray(n_tokens, jnp.int32)
        k_pos = jnp.where(jnp.arange(positions.shape[-1]) < n, positions, -1)
    out = flash_attention(q, k, v, q_pos=positions, k_pos=k_pos,
                          causal=True, scale=1.0 / (nd + rd) ** 0.5)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * vd) @ p["wo"]
    latent = jnp.concatenate([c_kv, k_rope], -1)
    return shard(out, "batch", None, None), latent


def _absorbed_queries(p, x, pos, cfg):
    """Decode queries in latent space: (B, H, kvl + rd). pos: (B, 1)."""
    H = cfg.n_heads
    nd = cfg.qk_nope_dim
    q_nope, q_rope = _queries(p, x, pos, cfg)               # (B,1,H,·)
    w_uk = p["w_uk"].reshape(cfg.kv_lora_rank, H, nd)
    q_lat = jnp.einsum("bhn,khn->bhk", q_nope[:, 0], w_uk)  # (B,H,kvl)
    return jnp.concatenate([q_lat, q_rope[:, 0]], -1)


def mla_decode(p: dict, x: jax.Array, t, cache: dict, cfg: ModelConfig,
               managed: bool, pol: Optional[CachePolicy] = None,
               paged=None, budget=None) -> Tuple[jax.Array, dict]:
    """x: (B,1,d); t: scalar or (B,) per-slot positions;
    cache: {"latent": (B, N, kvl+rd)[, "policy_state"]} — or
    {"pool_latent": (R, kvl+rd)} (batchless shared page pool) with
    ``paged`` = the (page_tbl, spec) pair under the paged layout."""
    B = x.shape[0]
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    tt = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    pos = tt[:, None]                                       # (B, 1)

    c_kv, k_rope = _latents(p, x, pos, cfg)
    lat_t = jnp.concatenate([c_kv, k_rope], -1)             # (B,1,576)
    paged_kv = "pool_latent" in cache
    if paged_kv:
        from repro.core.paging import PagedKV, append_rows
        tbl, spec = paged
        direct, halo = append_rows(tbl, tt, spec)
        rows = jnp.concatenate([direct, halo])              # (2B,)
        vals = jnp.concatenate([lat_t[:, 0]] * 2)           # (2B, 576)
        pool = cache["pool_latent"].at[rows, :].set(
            vals.astype(cache["pool_latent"].dtype))
        cache = dict(cache, pool_latent=pool)
        # one logical kv head over the pool; the value view is the LAZY
        # ``dlim`` feature limit — slicing the pool here would materialize
        # a pool-sized copy every decode step (the contiguous layout's
        # ``latent[..., :kvl]`` fuses away; a pool-wide slice does not)
        k_c = PagedKV(pool[None], tbl, spec)
        v_c = PagedKV(pool[None], tbl, spec, dlim=kvl)
    else:
        latent = jax.vmap(
            lambda c, r, a: jax.lax.dynamic_update_slice_in_dim(c, r, a, 0))(
            cache["latent"], lat_t, tt)
        _, _, lat_ctx, _ = kv_axes()
        latent = shard(latent, kv_axes()[0], lat_ctx, None)
        cache = dict(cache, latent=latent)
        k_c = latent[:, None]                               # (B,1,N,576)
        v_c = latent[:, None, :, :kvl]                      # values = c_kv

    q_eff = _absorbed_queries(p, x, pos, cfg)               # (B,H,576)
    scale = 1.0 / (nd + rd) ** 0.5

    ly = cfg.lychee
    if managed and pol is None:
        pol = policy_for(ly)
    if managed and pol is not None and not pol.is_dense and \
            (not pol.stateful or "policy_state" in cache):
        # the latent cache is one logical kv head, so the shared policy
        # dispatch applies directly: its GQA-group-mean probe degenerates
        # to the head-mean q_eff, and the MLA scale comes from cfg.
        ctx, pstate = _policy_attend(q_eff, k_c, v_c,
                                     cache.get("policy_state"), tt, cfg,
                                     pol, budget=budget)
        if pstate is not None:
            cache = dict(cache, policy_state=pstate)
    elif paged_kv:
        raise ValueError(
            "paged MLA decode requires a policy-managed layer (dense "
            "full-cache attention over the pool would be a pool-sized "
            "gather per step); MD.can_page should have forced the "
            "contiguous layout")
    elif kv_axes()[2] is not None:
        ctx = full_decode_attention_ctxsharded(
            q_eff, k_c, v_c, tt + 1, kv_axes()[2], scale=scale)
    else:
        ctx = jax.vmap(lambda qq, kk, vv, tb: full_decode_attention(
            qq, kk, vv, tb + 1, scale))(q_eff, k_c[:, 0][:, None],
                                        v_c[:, 0][:, None], tt)

    # un-absorb values: per-head v = ctx_latent @ w_uv_h
    w_uv = p["w_uv"].reshape(kvl, H, vd)
    out = jnp.einsum("bhk,khv->bhv", ctx, w_uv).reshape(B, 1, H * vd)
    out = out @ p["wo"]
    return shard(out, "batch", None, None), cache


def mla_extend(p: dict, x: jax.Array, t, cache: dict, cfg: ModelConfig,
               managed: bool, pol: Optional[CachePolicy] = None,
               n_tokens=None, update_policy: bool = True
               ) -> Tuple[jax.Array, dict]:
    """Multi-token EXTEND of one occupied MLA slot (session reuse).

    x: (1, S, d) delta tokens; t: (1,) current length. The delta's latents
    are appended at rows ``[t, t + S)`` and the delta queries attend over
    the whole latent cache in the NON-absorbed prefill formulation —
    per-head keys/values are reconstructed from the cached latents
    (``k_nope = c_kv @ w_uk``, ``v = c_kv @ w_uv``, both position-free, so
    the reconstruction is the exact prefill math and greedy continuations
    match the re-prefill oracle). Decompression is acceptable here because
    extend is a prefill-class operation (once per turn, not per token); the
    per-token decode path stays absorbed. The policy state extends through
    ``CachePolicy.extend`` over the latent rows (one logical kv head).

    ``n_tokens`` (scalar, traced ok) marks a right-padded delta: garbage
    rows land at positions >= t + n_tokens (causally masked, overwritten
    by the next chunk) and the policy folds only the valid rows.
    ``update_policy=False`` skips the policy extension (chunked-admission
    "rebuild" mode).
    """
    B, S, _ = x.shape
    assert B == 1, "extend_slot extends one slot at a time"
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    tt = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))
    t0 = tt[0]
    d_pos = t0 + jnp.arange(S, dtype=jnp.int32)             # (S,) absolute

    q_nope, q_rope = _queries(p, x, d_pos[None], cfg)       # (1,S,H,·)
    c_kv, k_rope = _latents(p, x, d_pos[None], cfg)
    lat_t = jnp.concatenate([c_kv, k_rope], -1)             # (1,S,kvl+rd)
    latent = jax.vmap(
        lambda c, r, a: jax.lax.dynamic_update_slice_in_dim(c, r, a, 0))(
        cache["latent"], lat_t, tt)
    _, _, lat_ctx, _ = kv_axes()
    latent = shard(latent, kv_axes()[0], lat_ctx, None)
    cache = dict(cache, latent=latent)
    N = latent.shape[1]

    ckv_all = latent[..., :kvl]                             # (1, N, kvl)
    kr_all = latent[..., kvl:]                              # (1, N, rd)
    k_nope = (ckv_all @ p["w_uk"]).reshape(B, N, H, nd)
    v_all = (ckv_all @ p["w_uv"]).reshape(B, N, H, vd)
    q = jnp.concatenate([q_nope, q_rope], -1).transpose(0, 2, 1, 3)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None], (B, N, H, rd))],
        -1).transpose(0, 2, 1, 3)
    v = v_all.transpose(0, 2, 1, 3)
    # rows >= t + S are zero latents at k_pos > every q_pos: causally masked
    out = flash_attention(q, k, v, q_pos=d_pos,
                          k_pos=jnp.arange(N, dtype=jnp.int32),
                          causal=True, scale=1.0 / (nd + rd) ** 0.5)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * vd) @ p["wo"]

    if managed and pol is None:
        pol = policy_for(cfg.lychee)
    if update_policy and managed and pol is not None and pol.stateful and \
            "policy_state" in cache:
        cache = dict(cache, policy_state=pol.extend_batched(
            cache["policy_state"], latent[:, None], tt,
            S if n_tokens is None else jnp.asarray(n_tokens, jnp.int32)))
    return shard(out, "batch", None, None), cache


def mla_prefill_cache(latent: jax.Array, cfg: ModelConfig,
                      layout: Optional[ChunkLayout], n_cache: int,
                      managed: bool, pol: Optional[CachePolicy] = None,
                      n_tokens=None, build_policy: bool = True) -> dict:
    """latent: (B, S, kvl+rd). The cache policy treats the latent cache as a
    single logical kv head of width 576. The tail ``core.types.cache_slack``
    rows are the kernel's reserved DMA-overrun region (never written —
    ``usable_rows``). ``n_tokens``/``build_policy`` follow
    :func:`repro.models.attention.gqa_prefill_cache`."""
    B, S, D = latent.shape
    pad = n_cache - S
    lat = jnp.pad(latent, ((0, 0), (0, pad), (0, 0)))
    lat = shard(lat, kv_axes()[0], kv_axes()[2], None)
    cache = {"latent": lat}
    if managed and pol is None:
        pol = policy_for(cfg.lychee)
    if managed and pol is not None and pol.stateful:
        # layout is batched (leading B dim); latent cache = 1 logical kv
        # head. Padded to cache capacity for uniform serving-slot shapes.
        if not build_policy:
            cache["policy_state"] = pol.empty_batched(B, n_cache, 1, D,
                                                      latent.dtype)
        elif not (pol.needs_layout and layout is None):
            cache["policy_state"] = pol.build_batched(
                latent[:, None], layout, n_cache, n_tokens=n_tokens)
    return cache
