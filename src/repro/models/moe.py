"""Mixture-of-Experts FFN with per-row sorted dispatch (GShard grouping).

Dispatch is computed INDEPENDENTLY per batch row (vmapped sorted ranking,
capacity C = S·k/E·capacity_factor per row): since rows are data-sharded,
the token gather ``x[b][table[b]]`` never crosses the data axis — the only
communication in the MoE layer is the expert-dim math itself. Two weight
layouts (picked by ``rules.py`` + the constraints here):

* expert-parallel (E % model == 0, e.g. DeepSeek 256e on a 16-way model
  axis): expert dim on 'model'. Dispatched activations are laid out
  (batch=data, expert=model, cap, d) — token routing to expert shards is
  GSPMD resharding of that tensor (an all-to-all over 'model'), exactly the
  paper-standard EP schedule.
* TP-inside-expert (E < model, e.g. Mixtral 8e): expert ff dim on 'model';
  experts replicated.

Aux loss: Switch-style load balancing (fraction·probability), coefficient
``router_aux_coef``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_mlp, mlp_apply, trunc_normal
from repro.sharding.ctx import current_mesh, is_serving, shard


def init_moe(key, cfg: ModelConfig) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": trunc_normal(ks[0], (d, E), jnp.float32),
        "we_gate": trunc_normal(ks[1], (E, d, f), dt),
        "we_in": trunc_normal(ks[2], (E, d, f), dt),
        "we_out": trunc_normal(ks[3], (E, f, d), dt, scale=0.02 / 2),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared_experts, dt)
    return p


def _expert_sharding(cfg: ModelConfig):
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    if is_serving() and "data" in mesh.axis_names and \
            cfg.n_experts % (mesh.shape["model"] * mesh.shape["data"]) == 0:
        return "ep2"          # serving: experts over model x data jointly
    return "ep" if cfg.n_experts % mesh.shape["model"] == 0 else "tp"


def _dispatch_row(top_e: jax.Array, top_p: jax.Array, E: int, C: int, S: int
                  ) -> Tuple[jax.Array, jax.Array]:
    """One row's (S, k) routing -> (E, C) token table + combine weights.

    Sentinel S marks empty capacity slots (points at a zero pad row)."""
    k = top_e.shape[-1]
    flat_e = top_e.reshape(-1)                              # (S*k,)
    flat_t = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    counts = jax.ops.segment_sum(jnp.ones_like(flat_e), flat_e,
                                 num_segments=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(S * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = rank < C
    table_t = jnp.full((E, C), S, jnp.int32)
    table_t = table_t.at[jnp.where(keep, se, 0),
                         jnp.where(keep, rank, 0)].set(
        jnp.where(keep, st, S), mode="drop")
    table_p = jnp.zeros((E, C), jnp.float32)
    table_p = table_p.at[jnp.where(keep, se, 0),
                         jnp.where(keep, rank, 0)].set(
        jnp.where(keep, sp, 0.0), mode="drop")
    return table_t, table_p


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (out, aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k

    # ---- routing (f32) ----------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (B, S, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # Switch aux loss: E * Σ_e fraction_e · mean_prob_e
    frac = jnp.mean(jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32),
                    axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_coef * E * jnp.sum(frac * mean_prob)

    # ---- per-row sorted dispatch (data-local) ------------------------------
    C = max(1, int(S * k / E * cfg.capacity_factor))
    table_t, table_p = jax.vmap(
        lambda te, tp: _dispatch_row(te, tp, E, C, S))(top_e, top_p)

    ep = _expert_sharding(cfg)
    e_ax = ("model", "data") if ep == "ep2" else (
        "model" if ep == "ep" else None)
    table_t = shard(table_t, None if ep == "ep2" else "batch", e_ax, None)

    # gather: row-local (sentinel row S is the zero pad)
    xp = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xp[:, :, None, :],                                   # (B, S+1, 1, d)
        table_t.reshape(B, E * C)[:, :, None, None], axis=1
    ).reshape(B, E, C, d)

    # ---- expert compute -----------------------------------------------------
    if ep == "ep2":
        xe = shard(xe, None, ("model", "data"), None, None)
    elif ep == "ep":
        xe = shard(xe, "batch", "model", None, None)
    g = jnp.einsum("becd,edf->becf", xe, p["we_gate"])
    h = jnp.einsum("becd,edf->becf", xe, p["we_in"])
    if ep == "tp":
        g = shard(g, "batch", None, None, "model")
        h = shard(h, "batch", None, None, "model")
    act = jax.nn.silu(g) * h
    out_e = jnp.einsum("becf,efd->becd", act, p["we_out"])   # (B, E, C, d)

    # ---- combine (row-local segment sum) ------------------------------------
    weighted = out_e * table_p[..., None].astype(out_e.dtype)
    out = jax.vmap(lambda w, t: jax.ops.segment_sum(
        w.reshape(E * C, d), t.reshape(E * C), num_segments=S + 1)[:S])(
        weighted, table_t)
    out = shard(out, "batch", None, None)

    if cfg.n_shared_experts:
        out = out + mlp_apply(p["shared"], x)
    return out, aux.astype(jnp.float32)
