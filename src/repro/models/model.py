"""Composable decoder assembly for every assigned architecture.

A model is ``prelude`` blocks (unrolled — these keep full attention at
decode, matching the paper's "retain full KV for the first layers") followed
by ``pattern`` blocks repeated ``groups`` times and executed with
``lax.scan`` over *stacked* parameters, so HLO size and compile time are
O(|pattern|), not O(depth) — a requirement for lowering the 61-layer
deepseek or 56-layer mixtral dry-runs.

Three entry points per model, all pure functions of (params, cfg):

* ``train_forward``  — full-sequence teacher forcing; returns (loss, metrics).
* ``prefill``        — full-sequence forward that also builds the decode
                       state: KV caches/ring buffers/SSM states and, for
                       policy-managed layers, the selection state of the
                       configured :class:`~repro.core.policy.CachePolicy`
                       (lychee default: Algorithm 1 phase 1).
* ``decode_step``    — one token in, one token's logits out, state updated
                       (lychee: Algorithm 1 phase 2 — retrieval, sparse
                       attention, lazy update; other policies plug their
                       own select/update through the same path).

Block kinds and their decode-time cache management:

  attn / mla / mla_moe      prelude -> dense cache; scanned -> CachePolicy
  attn_local / swa_moe      sliding-window ring buffer (exact, O(window))
  shared_attn (zamba2)      shared *weights*, per-group caches; CachePolicy
  mamba / mlstm / slstm     O(1) recurrent state (attention-free)
  dec_cross (whisper)       self-attn as "attn" + cross-attn over cached
                            encoder KV
  enc_attn                  encoder-only (no decode)

VLM / audio frontends are STUBS per the assignment carve-out: callers pass
precomputed patch/frame embeddings through ``extras``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import chunk_sequence, synthetic_delimiter_table
from repro.core.policy import policy_for
from repro.core.types import ChunkLayout
from repro.models import attention as A
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import xlstm as XL
from repro.models.layers import (embed, init_embed, init_mlp, init_rmsnorm,
                                 mlp_apply, rmsnorm, unembed)
from repro.sharding.ctx import shard

ATTN_KINDS = ("attn", "attn_local", "swa_moe", "shared_attn", "enc_attn",
              "dec_cross")
MLA_KINDS = ("mla", "mla_moe")
SSM_KINDS = ("mamba", "mlstm", "slstm")
LOCAL_KINDS = ("attn_local", "swa_moe")


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------
def init_block(key, kind: str, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    if kind == "shared_attn":
        return {}                       # weights live in params["shared"]
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("attn", "attn_local", "enc_attn"):
        return {"norm1": init_rmsnorm(d, dt), "attn": A.init_gqa(k1, cfg),
                "norm2": init_rmsnorm(d, dt),
                "mlp": init_mlp(k2, d, cfg.d_ff, dt)}
    if kind == "swa_moe":
        return {"norm1": init_rmsnorm(d, dt), "attn": A.init_gqa(k1, cfg),
                "norm2": init_rmsnorm(d, dt), "moe": MOE.init_moe(k2, cfg)}
    if kind == "mla":
        from repro.models.mla import init_mla
        return {"norm1": init_rmsnorm(d, dt), "attn": init_mla(k1, cfg),
                "norm2": init_rmsnorm(d, dt),
                "mlp": init_mlp(k2, d, cfg.d_ff, dt)}
    if kind == "mla_moe":
        from repro.models.mla import init_mla
        return {"norm1": init_rmsnorm(d, dt), "attn": init_mla(k1, cfg),
                "norm2": init_rmsnorm(d, dt), "moe": MOE.init_moe(k2, cfg)}
    if kind == "mamba":
        return {"norm1": init_rmsnorm(d, dt), "mixer": M2.init_mamba2(k1, cfg)}
    if kind == "mlstm":
        return {"norm1": init_rmsnorm(d, dt), "cell": XL.init_mlstm(k1, cfg)}
    if kind == "slstm":
        return {"norm1": init_rmsnorm(d, dt), "cell": XL.init_slstm(k1, cfg)}
    if kind == "dec_cross":
        return {"norm1": init_rmsnorm(d, dt), "attn": A.init_gqa(k1, cfg),
                "norm_x": init_rmsnorm(d, dt), "cross": A.init_cross(k2, cfg),
                "norm2": init_rmsnorm(d, dt),
                "mlp": init_mlp(k3, d, cfg.d_ff, dt)}
    raise ValueError(f"unknown block kind {kind!r}")


def _shared_params(params, kind, bp):
    """zamba2 shared block: weights are a closure constant."""
    return params["shared"] if kind == "shared_attn" else bp


# --- full-sequence (train / prefill) ----------------------------------------
def block_forward(bp: dict, kind: str, x: jax.Array, positions: jax.Array,
                  cfg: ModelConfig, enc_out: Optional[jax.Array] = None,
                  n_tokens=None) -> Tuple[jax.Array, jax.Array, Any]:
    """Returns (x_out, aux_loss, cache_material).

    cache_material feeds ``make_cache``: (k, v) post-RoPE for attention
    kinds, latent for MLA, recurrent state for SSM kinds, plus (enc_k,
    enc_v) for cross blocks. During pure training callers drop it.

    ``n_tokens`` (scalar, traced ok) marks a right-padded prompt for the
    attention kinds that support exact masking (prompt-length bucketing —
    see :func:`prefill`); training callers never pass it.
    """
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "attn_local", "enc_attn", "shared_attn"):
        akind = "attn" if kind == "shared_attn" else kind
        h, k, v = A.gqa_forward(bp["attn"], rmsnorm(bp["norm1"], x),
                                positions, cfg, akind, n_tokens=n_tokens)
        x = x + h
        x = x + mlp_apply(bp["mlp"], rmsnorm(bp["norm2"], x))
        return x, aux, {"k": k, "v": v}
    if kind == "swa_moe":
        h, k, v = A.gqa_forward(bp["attn"], rmsnorm(bp["norm1"], x),
                                positions, cfg, kind)
        x = x + h
        h, aux = MOE.moe_apply(bp["moe"], rmsnorm(bp["norm2"], x), cfg)
        return x + h, aux, {"k": k, "v": v}
    if kind in MLA_KINDS:
        from repro.models.mla import mla_forward
        h, latent = mla_forward(bp["attn"], rmsnorm(bp["norm1"], x),
                                positions, cfg,
                                n_tokens=n_tokens if kind == "mla" else None)
        x = x + h
        if kind == "mla":
            x = x + mlp_apply(bp["mlp"], rmsnorm(bp["norm2"], x))
        else:
            h, aux = MOE.moe_apply(bp["moe"], rmsnorm(bp["norm2"], x), cfg)
            x = x + h
        return x, aux, {"latent": latent}
    if kind == "mamba":
        x = x + M2.mamba2_forward(bp["mixer"], rmsnorm(bp["norm1"], x), cfg)
        return x, aux, None
    if kind == "mlstm":
        x = x + XL.mlstm_forward(bp["cell"], rmsnorm(bp["norm1"], x), cfg)
        return x, aux, None
    if kind == "slstm":
        x = x + XL.slstm_forward(bp["cell"], rmsnorm(bp["norm1"], x), cfg)
        return x, aux, None
    if kind == "dec_cross":
        h, k, v = A.gqa_forward(bp["attn"], rmsnorm(bp["norm1"], x),
                                positions, cfg, "attn")
        x = x + h
        x = x + A.cross_forward(bp["cross"], rmsnorm(bp["norm_x"], x),
                                *A.cross_kv(bp["cross"], enc_out, cfg), cfg)
        x = x + mlp_apply(bp["mlp"], rmsnorm(bp["norm2"], x))
        return x, aux, {"k": k, "v": v}
    raise ValueError(kind)


def block_make_cache(bp: dict, kind: str, material, x: jax.Array,
                     cfg: ModelConfig, layout: Optional[ChunkLayout],
                     n_cache: int, managed: bool,
                     enc_out: Optional[jax.Array] = None,
                     pol=None, n_tokens=None,
                     build_policy: bool = True) -> Any:
    """Turn forward material into the decode cache for this block.
    ``managed`` marks layers whose cache is run through the configured
    :class:`~repro.core.policy.CachePolicy` (``pol``, resolved once by the
    caller). KV/latent caches keep exactly ``n_cache`` rows; the LAST
    ``core.types.cache_slack`` of them are the Pallas kernel's reserved
    DMA-overrun region and must never be written (``usable_rows`` — the
    engine enforces this at admission). ``n_tokens`` marks a right-padded
    prompt; ``build_policy=False`` installs the policy's empty state (the
    chunked-admission rebuild mode builds it once at the end)."""
    if kind in ("attn", "attn_local", "enc_attn", "shared_attn", "swa_moe",
                "dec_cross"):
        akind = "attn" if kind in ("shared_attn", "dec_cross") else kind
        cache = A.gqa_prefill_cache(material["k"], material["v"], cfg, akind,
                                    layout, n_cache, managed, pol=pol,
                                    n_tokens=n_tokens,
                                    build_policy=build_policy)
        if kind == "dec_cross":
            ek, ev = A.cross_kv(bp["cross"], enc_out, cfg)
            cache["enc_k"], cache["enc_v"] = ek, ev
        return cache
    if kind in MLA_KINDS:
        from repro.models.mla import mla_prefill_cache
        return mla_prefill_cache(material["latent"], cfg, layout, n_cache,
                                 managed, pol=pol, n_tokens=n_tokens,
                                 build_policy=build_policy)
    if kind == "mamba":
        return M2.mamba2_prefill_state(bp["mixer"], rmsnorm(bp["norm1"], x),
                                       cfg)
    if kind == "mlstm":
        return XL.mlstm_prefill_state(bp["cell"], rmsnorm(bp["norm1"], x),
                                      cfg)
    if kind == "slstm":
        _, st = XL.slstm_forward(bp["cell"], rmsnorm(bp["norm1"], x), cfg,
                                 return_state=True)
        return st
    raise ValueError(kind)


# --- single-token decode ------------------------------------------------------
def block_decode(bp: dict, kind: str, x: jax.Array, t, cache: Any,
                 cfg: ModelConfig, managed: bool,
                 pol=None, paged=None, budget=None) -> Tuple[jax.Array, Any]:
    if kind in ("attn", "attn_local", "swa_moe", "shared_attn"):
        akind = "attn" if kind == "shared_attn" else kind
        h, cache = A.gqa_decode(bp["attn"], rmsnorm(bp["norm1"], x), t,
                                cache, cfg, akind, managed, pol=pol,
                                paged=paged, budget=budget)
        x = x + h
        if kind == "swa_moe":
            h, _ = MOE.moe_apply(bp["moe"], rmsnorm(bp["norm2"], x), cfg)
            x = x + h
        else:
            x = x + mlp_apply(bp["mlp"], rmsnorm(bp["norm2"], x))
        return x, cache
    if kind in MLA_KINDS:
        from repro.models.mla import mla_decode
        h, cache = mla_decode(bp["attn"], rmsnorm(bp["norm1"], x), t, cache,
                              cfg, managed, pol=pol, paged=paged,
                              budget=budget)
        x = x + h
        if kind == "mla":
            x = x + mlp_apply(bp["mlp"], rmsnorm(bp["norm2"], x))
        else:
            h, _ = MOE.moe_apply(bp["moe"], rmsnorm(bp["norm2"], x), cfg)
            x = x + h
        return x, cache
    if kind == "mamba":
        h, st = M2.mamba2_decode(bp["mixer"], rmsnorm(bp["norm1"], x),
                                 cache, cfg)
        return x + h, st
    if kind == "mlstm":
        h, st = XL.mlstm_decode(bp["cell"], rmsnorm(bp["norm1"], x),
                                cache, cfg)
        return x + h, st
    if kind == "slstm":
        h, st = XL.slstm_decode(bp["cell"], rmsnorm(bp["norm1"], x),
                                cache, cfg)
        return x + h, st
    if kind == "dec_cross":
        h, cache = A.gqa_decode(bp["attn"], rmsnorm(bp["norm1"], x), t,
                                cache, cfg, "attn", managed, pol=pol)
        x = x + h
        x = x + A.cross_decode(bp["cross"], rmsnorm(bp["norm_x"], x),
                               cache["enc_k"], cache["enc_v"], cfg)
        x = x + mlp_apply(bp["mlp"], rmsnorm(bp["norm2"], x))
        return x, cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------
def init_model(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embed(keys[0], cfg.vocab, cfg.d_model, dt,
                            cfg.tie_embeddings),
        "final_norm": init_rmsnorm(cfg.d_model, dt),
    }
    # prelude (unrolled)
    pk = jax.random.split(keys[1], max(1, len(cfg.prelude)))
    params["prelude"] = [init_block(pk[i], kind, cfg)
                         for i, kind in enumerate(cfg.prelude)]
    # pattern (stacked over groups)
    G = cfg.groups
    stacked = []
    for pos, kind in enumerate(cfg.pattern):
        gk = jax.random.split(jax.random.fold_in(keys[2], pos), G)
        per_group = [init_block(gk[g], kind, cfg) for g in range(G)]
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_group))
    params["pattern"] = tuple(stacked)
    # zamba2 shared transformer block
    if "shared_attn" in cfg.prelude + cfg.pattern:
        params["shared"] = init_block(keys[3], "attn", cfg)
    # whisper encoder
    if cfg.is_encdec:
        ek = jax.random.split(keys[4], cfg.n_enc_layers + 1)
        enc_blocks = [init_block(ek[i], "enc_attn", cfg)
                      for i in range(cfg.n_enc_layers)]
        params["encoder"] = {
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
            "norm": init_rmsnorm(cfg.d_model, dt),
        }
    # deepseek multi-token prediction head (one extra block + fuse proj)
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": jax.random.normal(keys[5], (2 * cfg.d_model, cfg.d_model),
                                      dt) * 0.02,
            "norm_h": init_rmsnorm(cfg.d_model, dt),
            "norm_e": init_rmsnorm(cfg.d_model, dt),
            "block": init_block(keys[6], "attn" if cfg.d_ff else "attn", cfg)
            if cfg.d_ff else None,
        }
        if params["mtp"]["block"] is None:
            del params["mtp"]["block"]
    return params


# ---------------------------------------------------------------------------
# Embedding of the (stub-frontend-aware) input
# ---------------------------------------------------------------------------
def embed_inputs(params: dict, tokens: jax.Array, cfg: ModelConfig,
                 extras: Optional[dict] = None) -> jax.Array:
    """tokens: (B, S_text). VLM: extras["patches"] (B, Pch, d) is prepended
    (stub vision frontend). Returns (B, S, d)."""
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if cfg.n_patches and extras and "patches" in extras:
        x = jnp.concatenate(
            [extras["patches"].astype(x.dtype), x], axis=1)
    return shard(x, "batch", None, None)


def run_encoder(params: dict, frames: jax.Array, cfg: ModelConfig):
    """Whisper encoder over stub frame embeddings (B, F, d)."""
    pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
    x = frames.astype(jnp.dtype(cfg.dtype))

    def step(x, bp):
        x, _, _ = block_forward(bp, "enc_attn", x, pos, cfg)
        return x, None

    x, _ = jax.lax.scan(step, x, params["encoder"]["blocks"])
    return rmsnorm(params["encoder"]["norm"], x)


# ---------------------------------------------------------------------------
# Full-sequence forward (training)
# ---------------------------------------------------------------------------
def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            extras: Optional[dict] = None) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forcing forward. Returns (hidden (B,S,d), aux_loss)."""
    x = embed_inputs(params, tokens, cfg, extras)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_out = None
    if cfg.is_encdec:
        enc_out = run_encoder(params, extras["frames"], cfg)
    aux = jnp.zeros((), jnp.float32)

    for bp, kind in zip(params["prelude"], cfg.prelude):
        bp = _shared_params(params, kind, bp)
        x, a, _ = block_forward(bp, kind, x, positions, cfg, enc_out)
        aux = aux + a

    def group_step(carry, gp):
        x, aux = carry
        for pos_i, kind in enumerate(cfg.pattern):
            bp = _shared_params(params, kind, gp[pos_i])
            x, a, _ = block_forward(bp, kind, x, positions, cfg, enc_out)
            aux = aux + a
        # §Perf iteration 2 (sequence parallelism): the scan carry is the
        # residual saved for backward — shard its sequence dim over 'model'
        # so remat keeps (B/data, S/model, d) per group instead of
        # (B/data, S, d). Blocks re-gather internally; the saved-residual
        # footprint drops by the model-axis size.
        x = shard(x, "batch", "model", None)
        return (x, aux), None

    step = group_step
    if cfg.remat:
        step = jax.checkpoint(group_step, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(step, (x, aux), params["pattern"])
    return rmsnorm(params["final_norm"], x), aux


def chunked_ce(x: jax.Array, embed_params: dict, labels: jax.Array,
               mask: jax.Array, softcap: float, chunk: int = 512
               ) -> jax.Array:
    """Cross-entropy without materialising the full (B,S,V) logits tensor:
    the unembed + softmax runs over sequence chunks (required for the 256k
    vocab archs at 4k train lengths)."""
    B, S, d = x.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nb = (S + pad) // C
    xb = x.reshape(B, nb, C, d).transpose(1, 0, 2, 3)
    lb = labels.reshape(B, nb, C).transpose(1, 0, 2)
    mb = mask.reshape(B, nb, C).transpose(1, 0, 2)

    def per_chunk(args):
        xc, lc, mc = args
        logits = unembed(embed_params, xc, softcap)       # (B, C, V) f32
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mc
        return jnp.sum(nll), jnp.sum(mc)

    tot, cnt = jax.lax.map(per_chunk, (xb, lb, mb))
    return jnp.sum(tot) / jnp.maximum(jnp.sum(cnt), 1.0)


def train_forward(params: dict, batch: dict, cfg: ModelConfig
                  ) -> Tuple[jax.Array, dict]:
    """batch: {"tokens": (B,S) int32 [, "patches", "frames"]}. Next-token CE
    over the text positions (+ router aux + MTP loss where configured)."""
    tokens = batch["tokens"]
    x, aux = forward(params, tokens, cfg, batch)
    # VLM: hidden includes patch positions; only text positions predict
    off = cfg.n_patches if (cfg.n_patches and "patches" in batch) else 0
    xt = x[:, off:]
    labels = tokens[:, 1:]
    mask = jnp.ones_like(labels, jnp.float32)
    ce = chunked_ce(xt[:, :-1], params["embed"], labels, mask,
                    cfg.final_softcap)
    loss = ce + aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth and "mtp" in params:
        mtp_loss = _mtp_loss(params, xt, tokens, cfg)
        loss = loss + 0.3 * mtp_loss
        metrics["mtp"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(params: dict, x: jax.Array, tokens: jax.Array,
              cfg: ModelConfig) -> jax.Array:
    """DeepSeek-V3 multi-token prediction (depth 1): fuse the trunk hidden
    at t with the embedding of token t+1, run one extra block, predict
    token t+2 with the shared head. [arXiv:2412.19437 §2.2]"""
    mp = params["mtp"]
    B, S, d = x.shape
    e_next = embed(params["embed"], tokens[:, 1:]).astype(x.dtype)  # (B,S-1,d)
    h = jnp.concatenate([rmsnorm(mp["norm_h"], x[:, :-1]),
                         rmsnorm(mp["norm_e"], e_next)], -1) @ mp["proj"]
    if "block" in mp:
        pos = jnp.arange(S - 1, dtype=jnp.int32)
        h, _, _ = block_forward(mp["block"], "attn", h, pos, cfg)
    h = rmsnorm(params["final_norm"], h)
    labels = tokens[:, 2:]                                     # predict t+2
    mask = jnp.ones_like(labels, jnp.float32)
    return chunked_ce(h[:, :-1], params["embed"], labels, mask,
                      cfg.final_softcap)


# ---------------------------------------------------------------------------
# Prefill: forward + decode-state construction
# ---------------------------------------------------------------------------
def _policy_managed(cfg: ModelConfig, kind: str, scanned: bool) -> bool:
    """Prelude layers keep full attention (paper App. A); scanned global-
    attention layers are managed by the configured CachePolicy (the
    ``dense`` policy recovers full attention there); local/SWA layers use
    exact ring buffers; SSM kinds have no cache to manage."""
    if not scanned:
        return False
    return kind in ("attn", "shared_attn", "dec_cross") + MLA_KINDS and \
        kind not in LOCAL_KINDS


def make_layout(tokens: jax.Array, cfg: ModelConfig, table=None,
                extras: Optional[dict] = None, n_tokens=None) -> ChunkLayout:
    """Structure-aware chunk layout for one batch of prompts. The delimiter
    table is tokenizer-specific; the synthetic table is the default for
    in-repo data. VLM patch positions are treated as a leading structural
    span (they precede text). ``n_tokens`` (scalar, shared by all rows)
    marks right-padded prompts — chunking stops at the valid length."""
    if table is None:
        table = jnp.asarray(synthetic_delimiter_table(cfg.vocab))
    ly = cfg.lychee
    if cfg.n_patches and extras is not None and "patches" in extras:
        # prepend pseudo-tokens for the patch span (delimiter-free)
        pad = jnp.zeros((tokens.shape[0], cfg.n_patches), tokens.dtype)
        tokens = jnp.concatenate([pad, tokens], axis=1)
    return jax.vmap(
        lambda tk: chunk_sequence(tk, table, ly, n_tokens=n_tokens))(tokens)


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig,
            n_cache: int, extras: Optional[dict] = None,
            layout: Optional[ChunkLayout] = None, n_tokens=None,
            build_policy: bool = True) -> Tuple[jax.Array, dict]:
    """Process the prompt; return (last-position logits (B,V), state).

    ``n_tokens`` (scalar, traced ok — one jit shape serves every prompt
    length in a pad bucket) marks right-padded prompts: every attention
    masks rows >= n_tokens, the policy build/chunk layout stop at the
    valid length, the returned logits come from position ``n_tokens - 1``
    and ``state["t"] = n_tokens``. Pad rows leave garbage K/V at positions
    >= n_tokens, which every decode-time consumer masks by ``t`` (and
    decode/extend appends overwrite) — valid-row numerics are identical to
    the unpadded prefill. Only architectures whose every block is exactly
    maskable support this (``can_extend``: no SSM recurrence over pad
    rows, no sequence-length-dependent MoE capacity, no enc-dec/VLM
    frontends). ``build_policy=False`` installs empty policy states (the
    chunked-admission rebuild mode).

    state = {"prelude": [cache...], "groups": stacked caches, "t": (B,)}.

    Every leaf's shape depends only on ``n_cache`` (KV caches pad to it,
    policy states pad to its static capacities, ``t`` is per-slot), so
    states from prefills of DIFFERENT prompt lengths are pytree-compatible:
    the per-slot surgery below (``prefill_into_slot`` / ``write_slot``)
    splices one request's state into any slot of a live batched state.

    Tail-slack contract: the LAST ``core.types.cache_slack`` rows of every
    KV/latent cache are the Pallas sparse-attention kernel's DMA-overrun
    region. Callers must stop decoding at ``core.types.usable_rows`` (the
    serving engine enforces this at admission) so those rows stay zero and
    any ``span_len``-row span DMA starting below ``t`` is in bounds by
    construction — no per-step cache copy, and row counts (hence context-
    dim shard splits and index capacities) unchanged.
    """
    if n_tokens is not None:
        assert can_extend(cfg), \
            f"{cfg.name}: masked (bucketed) prefill needs every block to " \
            f"be exactly maskable (see model.EXTEND_KINDS)"
    x = embed_inputs(params, tokens, cfg, extras)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_out = run_encoder(params, extras["frames"], cfg) if cfg.is_encdec \
        else None
    pol = policy_for(cfg.lychee)          # resolved once, threaded down
    needs_layout = pol.needs_layout
    if layout is None and needs_layout and cfg.uses_attention and \
            build_policy:
        layout = make_layout(tokens, cfg, extras=extras, n_tokens=n_tokens)

    prelude_caches = []
    for bp, kind in zip(params["prelude"], cfg.prelude):
        bp = _shared_params(params, kind, bp)
        x_in = x
        x, _, mat = block_forward(bp, kind, x, positions, cfg, enc_out,
                                  n_tokens=n_tokens)
        prelude_caches.append(block_make_cache(
            bp, kind, mat, x_in, cfg, None, n_cache, False, enc_out,
            n_tokens=n_tokens))

    def group_step(x, gp):
        caches = []
        for pos_i, kind in enumerate(cfg.pattern):
            bp = _shared_params(params, kind, gp[pos_i])
            x_in = x
            x, _, mat = block_forward(bp, kind, x, positions, cfg, enc_out,
                                      n_tokens=n_tokens)
            managed = _policy_managed(cfg, kind, scanned=True)
            caches.append(block_make_cache(
                bp, kind, mat, x_in, cfg,
                layout if managed and needs_layout else None,
                n_cache, managed, enc_out, pol=pol if managed else None,
                n_tokens=n_tokens, build_policy=build_policy))
        return x, tuple(caches)

    x, group_caches = jax.lax.scan(group_step, x, params["pattern"])
    x = rmsnorm(params["final_norm"], x)
    if n_tokens is None:
        x_last = x[:, -1:]
        t_fill = jnp.full((B,), S, jnp.int32)
    else:
        n = jnp.asarray(n_tokens, jnp.int32)
        x_last = jax.lax.dynamic_slice_in_dim(x, n - 1, 1, axis=1)
        t_fill = jnp.full((B,), 0, jnp.int32) + n
    logits = unembed(params["embed"], x_last, cfg.final_softcap)[:, 0]
    state = {"prelude": prelude_caches, "groups": group_caches,
             "t": t_fill}
    return logits, state


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------
def decode_step(params: dict, token: jax.Array, state: dict,
                cfg: ModelConfig, budget=None) -> Tuple[jax.Array, dict]:
    """token: (B,) int32. Returns (logits (B, V), new state).

    ``state["t"]`` is the per-slot position vector (B,) — each serving slot
    decodes at its own sequence length (a scalar broadcasts for legacy
    states). All attention/cache ops thread it per-batch-element.

    ``budget`` (optional, (B,) int32, 0 = uncapped) is the serving
    engine's overload-degradation valve: it caps each slot's RETRIEVED
    token budget inside ``fused_policy_decode`` (sink/recent never
    shrink). Per-slot and traced — capping one slot is bitwise invisible
    to the others, and ``None`` (the default) traces the exact
    pre-existing step.
    """
    t = jnp.broadcast_to(jnp.asarray(state["t"], jnp.int32),
                         (token.shape[0],))
    x = embed(params["embed"], token[:, None]).astype(jnp.dtype(cfg.dtype))
    x = shard(x, "batch", None, None)
    pol = policy_for(cfg.lychee)          # resolved once, threaded down
    # Paged serving state: the shared page table rides along as a state
    # part and every scanned block resolves its pool rows through it.
    # Prelude caches stay contiguous per-slot (they are never managed).
    paged = None
    if "page_tbl" in state:
        paged = (state["page_tbl"], paged_spec(state, cfg))

    new_prelude = []
    for bp, kind, cache in zip(params["prelude"], cfg.prelude,
                               state["prelude"]):
        bp = _shared_params(params, kind, bp)
        x, cache = block_decode(bp, kind, x, t, cache, cfg, False)
        new_prelude.append(cache)

    def group_step(x, xs):
        gp, caches = xs
        new = []
        for pos_i, kind in enumerate(cfg.pattern):
            bp = _shared_params(params, kind, gp[pos_i])
            managed = _policy_managed(cfg, kind, scanned=True)
            x, c = block_decode(bp, kind, x, t, caches[pos_i], cfg, managed,
                                pol=pol if managed else None, paged=paged,
                                budget=budget if managed else None)
            new.append(c)
        return x, tuple(new)

    x, new_groups = jax.lax.scan(group_step, x,
                                 (params["pattern"], state["groups"]))
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg.final_softcap)[:, 0]
    new_state = {"prelude": new_prelude, "groups": new_groups, "t": t + 1}
    if paged is not None:
        new_state["page_tbl"] = state["page_tbl"]
    return logits, new_state


# ---------------------------------------------------------------------------
# Per-slot state surgery (continuous batching)
# ---------------------------------------------------------------------------
# Where the batch axis sits in each state part. Prelude caches and ``t`` are
# plain (B, ...) leaves; scanned group caches carry a leading ``groups`` dim,
# so their batch axis is 1. Every leaf inside a part shares its part's axis —
# the invariant that makes the whole state uniformly sliceable by slot.
STATE_BATCH_AXIS = {"prelude": 0, "groups": 1, "t": 0}


def _per_part(state: dict, fn) -> dict:
    return {part: jax.tree.map(fn(axis), state[part])
            for part, axis in STATE_BATCH_AXIS.items()}


def slice_slot(state: dict, slot) -> dict:
    """Extract ONE slot's decode state (batch dims kept, size 1)."""
    slot = jnp.asarray(slot, jnp.int32)

    def sl(axis):
        return lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis)

    return _per_part(state, sl)


def write_slot(state: dict, sub: dict, slot) -> dict:
    """Splice a single-request state (every batch dim of size 1 — e.g. from
    a B=1 ``prefill``) into slot ``slot`` of a live batched state.

    This is the continuous-batching admission primitive: the KV caches,
    policy selection state, recent-buffer bookkeeping, and position counter
    of the slot are all overwritten in one pass; other slots' leaves are
    untouched, so their retrieval stays bit-identical.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def upd(axis):
        def f(dst, src):
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, axis)
        return f

    return {part: jax.tree.map(upd(axis), state[part], sub[part])
            for part, axis in STATE_BATCH_AXIS.items()}


def reset_slot(state: dict, slot) -> dict:
    """Clear a drained slot: caches zeroed, position counter 0, and the
    slot's policy state emptied (zero leaves ARE the empty state for every
    registered CachePolicy — see ``core.policy.CachePolicy.reset`` and
    ``core.update.reset_index``), so a recycled slot's cursors and validity
    masks restart cleanly and leak nothing into the next request.
    """
    slot = jnp.asarray(slot, jnp.int32)

    def z(axis):
        def f(leaf):
            cur = jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis)
            return jax.lax.dynamic_update_slice_in_dim(
                leaf, jnp.zeros_like(cur), slot, axis)
        return f

    return _per_part(state, z)


# ---------------------------------------------------------------------------
# Session reuse: multi-token extend of an occupied slot
# ---------------------------------------------------------------------------
# Block kinds whose decode state supports in-place multi-token extension.
# SSM kinds would need a sequential recurrence over the delta (their prefill
# has no prefix-state entry point) and enc/dec frontends are excluded from
# streaming admission anyway. MoE FFN kinds (swa_moe / mla_moe) are ALSO
# excluded: ``moe_apply``'s expert capacity is sequence-length dependent
# (C = S*k/E*capacity_factor), so a delta-length extend forward can drop /
# route tokens differently than the full-history prefill would — greedy
# extend output would silently diverge from the re-prefill oracle.
# Sessions on all excluded architectures fall back to re-prefilling the
# concatenated history (the engine checks ``can_extend``).
EXTEND_KINDS = ("attn", "attn_local", "shared_attn", "mla")


def can_extend(cfg: ModelConfig) -> bool:
    """True when every decode block of ``cfg`` supports ``extend_slot``."""
    if cfg.is_encdec or cfg.n_patches:
        return False
    return all(k in EXTEND_KINDS for k in cfg.prelude + cfg.pattern)


def block_extend(bp: dict, kind: str, x: jax.Array, t, cache: Any,
                 cfg: ModelConfig, managed: bool,
                 pol=None, n_tokens=None,
                 update_policy: bool = True) -> Tuple[jax.Array, Any]:
    """Multi-token analogue of ``block_decode``: x (1, S, d) delta hidden
    states against an occupied slot's cache at length ``t``. The MoE kinds
    are implemented for completeness but gated out of ``EXTEND_KINDS``
    (capacity drops are sequence-length dependent — see above).
    ``n_tokens`` marks a right-padded delta (chunked admission / prompt
    bucketing); ``update_policy=False`` skips the policy-state extension
    (the rebuild mode's deferred build)."""
    if kind in ("attn", "attn_local", "swa_moe", "shared_attn"):
        akind = "attn" if kind == "shared_attn" else kind
        h, cache = A.gqa_extend(bp["attn"], rmsnorm(bp["norm1"], x), t,
                                cache, cfg, akind, managed, pol=pol,
                                n_tokens=n_tokens,
                                update_policy=update_policy)
        x = x + h
        if kind == "swa_moe":
            h, _ = MOE.moe_apply(bp["moe"], rmsnorm(bp["norm2"], x), cfg)
            x = x + h
        else:
            x = x + mlp_apply(bp["mlp"], rmsnorm(bp["norm2"], x))
        return x, cache
    if kind in MLA_KINDS:
        from repro.models.mla import mla_extend
        h, cache = mla_extend(bp["attn"], rmsnorm(bp["norm1"], x), t, cache,
                              cfg, managed, pol=pol, n_tokens=n_tokens,
                              update_policy=update_policy)
        x = x + h
        if kind == "mla":
            x = x + mlp_apply(bp["mlp"], rmsnorm(bp["norm2"], x))
        else:
            h, _ = MOE.moe_apply(bp["moe"], rmsnorm(bp["norm2"], x), cfg)
            x = x + h
        return x, cache
    raise ValueError(f"block kind {kind!r} does not support extend "
                     f"(see model.EXTEND_KINDS)")


def extend(params: dict, tokens: jax.Array, cfg: ModelConfig, state: dict,
           n_tokens=None, update_policy: bool = True
           ) -> Tuple[jax.Array, dict]:
    """Append a turn's delta tokens to ONE session's decode state.

    tokens: (1, S) — the delta (the previous turn's final sampled token,
    whose KV was never appended, plus the new user prompt); state: a
    single-slot (B=1) decode state, e.g. from ``slice_slot``. The delta
    runs a prefill-exact forward against the existing caches (every block's
    K/V rows for ``[0, t)`` are REUSED — this is the lazy-update streaming
    story of the paper applied across turns) and each managed layer's
    policy state is extended through ``CachePolicy.extend`` instead of
    rebuilt. Returns (last-position logits (1, V), updated state with
    ``t + S``).

    ``n_tokens`` (scalar, traced ok) marks a right-padded delta — the
    prompt-bucketing / chunked-admission form: only the first ``n_tokens``
    rows are real, the logits come from row ``n_tokens - 1`` and ``t``
    advances by ``n_tokens``. ``update_policy=False`` skips the policy
    extension (rebuild mode).
    """
    assert tokens.shape[0] == 1, "extend is a per-slot primitive"
    S = tokens.shape[1]
    n = None if n_tokens is None else jnp.asarray(n_tokens, jnp.int32)
    t0 = jnp.broadcast_to(jnp.asarray(state["t"], jnp.int32), (1,))
    x = embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x = shard(x, "batch", None, None)
    pol = policy_for(cfg.lychee)          # resolved once, threaded down

    new_prelude = []
    for bp, kind, cache in zip(params["prelude"], cfg.prelude,
                               state["prelude"]):
        bp = _shared_params(params, kind, bp)
        x, cache = block_extend(bp, kind, x, t0, cache, cfg, False,
                                n_tokens=n, update_policy=update_policy)
        new_prelude.append(cache)

    def group_step(x, xs):
        gp, caches = xs
        new = []
        for pos_i, kind in enumerate(cfg.pattern):
            bp = _shared_params(params, kind, gp[pos_i])
            managed = _policy_managed(cfg, kind, scanned=True)
            x, c = block_extend(bp, kind, x, t0, caches[pos_i], cfg, managed,
                                pol=pol if managed else None, n_tokens=n,
                                update_policy=update_policy)
            new.append(c)
        return x, tuple(new)

    x, new_groups = jax.lax.scan(group_step, x,
                                 (params["pattern"], state["groups"]))
    x = rmsnorm(params["final_norm"], x)
    if n is None:
        x_last = x[:, -1:]
        t_new = t0 + S
    else:
        x_last = jax.lax.dynamic_slice_in_dim(x, n - 1, 1, axis=1)
        t_new = t0 + n
    logits = unembed(params["embed"], x_last, cfg.final_softcap)[:, 0]
    new_state = {"prelude": new_prelude, "groups": new_groups,
                 "t": t_new}
    return logits, new_state


def extend_slot(params: dict, tokens: jax.Array, cfg: ModelConfig,
                state: dict, slot, n_tokens=None,
                update_policy: bool = True) -> Tuple[jax.Array, dict]:
    """Append a turn's delta into an OCCUPIED slot of a live batched state
    — the multi-turn admission primitive, sibling of ``prefill_into_slot``.

    Where ``prefill_into_slot`` builds a fresh state from the full prompt
    (O(T^2) attention + index rebuild), ``extend_slot`` reuses the slot's
    existing KV rows and index: it slices the slot (B=1), runs
    :func:`extend` over the delta at the slot's current ``t``, and splices
    the result back. tokens: (1, S). Returns (last-position logits (1, V),
    updated batched state). ``slot`` may be a traced scalar — one jit
    specialisation per delta length (per delta BUCKET with ``n_tokens``),
    not per slot.
    """
    assert tokens.shape[0] == 1, "extend_slot extends one slot at a time"
    sub = slice_slot(state, slot)
    logits, sub = extend(params, tokens, cfg, sub, n_tokens=n_tokens,
                         update_policy=update_policy)
    return logits, write_slot(state, sub, slot)


def prefill_into_slot(params: dict, tokens: jax.Array, cfg: ModelConfig,
                      n_cache: int, state: dict, slot,
                      extras: Optional[dict] = None, n_tokens=None,
                      build_policy: bool = True) -> Tuple[jax.Array, dict]:
    """Admit one request into a freed slot of a live batched decode state.

    tokens: (1, S) — a single-sequence prefill at the request's natural
    length (no cross-request padding, so its logits match the request served
    alone); the resulting caches/index/position are spliced into ``slot``.
    Returns (last-position logits (1, V), updated state). ``slot`` may be a
    traced scalar — one jit specialisation per prompt length, not per slot
    (per prompt BUCKET with ``n_tokens`` — the pow2 bucketing the engine
    applies on pad-safe architectures).
    """
    assert tokens.shape[0] == 1, "prefill_into_slot admits one request"
    logits, sub = prefill(params, tokens, cfg, n_cache, extras=extras,
                          n_tokens=n_tokens, build_policy=build_policy)
    return logits, write_slot(state, sub, slot)


def rebuild_slot_policy(params: dict, tokens: jax.Array, cfg: ModelConfig,
                        n_cache: int, state: dict, slot, n_tokens=None
                        ) -> dict:
    """Monolithic policy-state build for ONE chunk-admitted slot — the
    end-of-admission pass of ``serving.chunk_state == "rebuild"``.

    tokens: (1, Sp) — the admitted prompt, right-padded to the SAME bucket
    a monolithic (bucketed) admission would use; ``n_tokens`` its valid
    length. The slot's first ``Sp`` cached key/latent rows — written chunk
    by chunk, numerically the prefill rows — are fed through the exact
    ``CachePolicy.build`` path a monolithic prefill runs (same keys, same
    chunk layout, same padding to ``n_cache``), so the resulting selection
    state is the monolithic-build oracle's state and chunked admission
    stays token-identical to monolithic admission for EVERY policy at any
    retrieval budget. Only the managed layers' ``policy_state`` leaves are
    touched. ``slot`` may be a traced scalar.
    """
    assert tokens.shape[0] == 1, "rebuild_slot_policy rebuilds one slot"
    pol = policy_for(cfg.lychee)
    if not pol.stateful:
        return state
    Sp = tokens.shape[1]
    slot = jnp.asarray(slot, jnp.int32)
    layout = None
    if pol.needs_layout:
        layout = make_layout(tokens, cfg, n_tokens=n_tokens)   # B=1 batched
    new_groups = []
    for pos_i, kind in enumerate(cfg.pattern):
        cache = state["groups"][pos_i]
        if not _policy_managed(cfg, kind, scanned=True) or \
                not isinstance(cache, dict) or "policy_state" not in cache:
            new_groups.append(cache)
            continue
        if kind in MLA_KINDS:
            rows = jax.lax.dynamic_slice_in_dim(
                cache["latent"], slot, 1, 1)[:, :, :Sp]       # (G,1,Sp,D)
            keys = rows[:, :, None]                           # 1 logical head
        else:
            keys = jax.lax.dynamic_slice_in_dim(
                cache["k"], slot, 1, 1)[:, :, :, :Sp]         # (G,1,H,Sp,d)
        built = jax.vmap(lambda kg: pol.build_batched(
            kg, layout, n_cache, n_tokens=n_tokens))(keys)    # (G,1,...)
        merged = jax.tree.map(
            lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, 1),
            cache["policy_state"], built)
        new_groups.append(dict(cache, policy_state=merged))
    return dict(state, groups=tuple(new_groups))


def mask_step_slots(old_state: dict, new_state: dict, keep: jax.Array
                    ) -> dict:
    """Discard a decode step's POLICY/POSITION side effects on masked slots.

    ``keep``: (B,) bool — True slots keep the step's full effects; False
    slots (mid-admission "prefilling" slots and empty slots, during the
    chunk-interleaved decode steps) revert ``t`` and every managed layer's
    ``policy_state`` to their pre-step values. Their K/V caches are NOT
    reverted: the step's single garbage row at the slot's ``t`` is
    overwritten by the admission's next chunk append (which starts exactly
    there), so reverting the cheap leaves suffices — no O(cache) copy in
    the interleaved hot path.
    """
    keep = jnp.asarray(keep, bool)
    groups = []
    for oc, nc in zip(old_state["groups"], new_state["groups"]):
        if isinstance(nc, dict) and "policy_state" in nc:
            sel = jax.tree.map(
                lambda o, n_: jnp.where(
                    keep.reshape((1, -1) + (1,) * (n_.ndim - 2)), n_, o),
                oc["policy_state"], nc["policy_state"])
            nc = dict(nc, policy_state=sel)
        groups.append(nc)
    t = jnp.where(keep, new_state["t"], old_state["t"])
    return dict(new_state, groups=tuple(groups), t=t)


# ---------------------------------------------------------------------------
# Paged decode state (global KV pool + per-slot page tables)
# ---------------------------------------------------------------------------
# In paged mode the scanned group caches do not carry per-slot K/V rows.
# Instead each pattern position owns batchless pool leaves
#
#   "pool_k" / "pool_v"   (G, Hkv, pool_rows, dh)     (GQA kinds)
#   "pool_latent"         (G, pool_rows, D)           (MLA kinds)
#
# and the state gains one top-level part ``"page_tbl"`` — (B, max_pages)
# int32, shared by every layer — mapping each slot's logical pages to
# physical pool pages (``core.paging`` documents the halo layout that keeps
# paged attention bit-identical to the contiguous caches). Everything else
# (prelude caches, policy_state, t) stays per-slot exactly as before; the
# surgery below splits those RESIDUAL leaves from the shared pools.
_POOL_KEYS = ("pool_k", "pool_v", "pool_latent")
# contiguous cache leaves that the pools replace
_ROW_KEYS = ("k", "v", "latent")


def can_page(cfg: ModelConfig) -> bool:
    """True when the serving engine may run ``cfg`` on the paged KV pool.

    Paged admission streams a slot in through the extend path (gather the
    slot's contiguous view, run :func:`extend`, scatter the delta rows
    back), so ``can_extend`` is required; every scanned block must be
    policy-managed global attention (local ring buffers and SSM states are
    per-slot by construction and are not paged); and the ``dense`` policy
    reads the whole cache each step — paging it would gather pool_rows
    per token — so dense falls back to the contiguous layout.
    """
    if not can_extend(cfg):
        return False
    if not cfg.pattern or not all(
            k in ("attn", "shared_attn") + MLA_KINDS for k in cfg.pattern):
        return False
    return not policy_for(cfg.lychee).is_dense


def paged_spec(state: dict, cfg: ModelConfig):
    """Reconstruct the static :class:`~repro.core.paging.PageSpec` of a
    paged state. ``cfg.serving.page_tokens`` must hold the RESOLVED page
    size (the engine pins it before jitting) — the remaining geometry is
    read off the state shapes."""
    from repro.core.paging import PageSpec
    from repro.core.types import cache_slack
    P = int(cfg.serving.page_tokens)
    slack = cache_slack(cfg.lychee)
    pool_rows = 0
    for c in state["groups"]:
        if isinstance(c, dict):
            for key in _POOL_KEYS:
                if key in c:
                    pool_rows = c[key].shape[-2]
                    break
        if pool_rows:
            break
    assert pool_rows, "paged_spec: state has no pool leaves"
    return PageSpec(page_tokens=P, slack=slack,
                    n_pages=pool_rows // (P + slack) - 1,
                    max_pages=state["page_tbl"].shape[1])


def paged_state_struct(state: dict, spec) -> dict:
    """Map a CONTIGUOUS batched decode state (arrays or ShapeDtypeStructs,
    e.g. from ``jax.eval_shape`` of :func:`prefill`) to the paged layout's
    shape structs. The engine zero-fills these and then sets ``page_tbl``
    to the dump page (zero-init would alias physical page 0)."""
    def struct(leaf):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)

    B = state["t"].shape[0]
    groups = []
    for c in state["groups"]:
        if isinstance(c, dict) and any(k in c for k in _ROW_KEYS):
            nc = {k: jax.tree.map(struct, v) for k, v in c.items()
                  if k not in _ROW_KEYS}
            if "latent" in c:
                lat = c["latent"]                       # (G, B, N, D)
                nc["pool_latent"] = jax.ShapeDtypeStruct(
                    (lat.shape[0], spec.pool_rows, lat.shape[-1]), lat.dtype)
            else:
                k, v = c["k"], c["v"]                   # (G, B, Hkv, N, dh)
                nc["pool_k"] = jax.ShapeDtypeStruct(
                    (k.shape[0], k.shape[2], spec.pool_rows, k.shape[-1]),
                    k.dtype)
                nc["pool_v"] = jax.ShapeDtypeStruct(
                    (v.shape[0], v.shape[2], spec.pool_rows, v.shape[-1]),
                    v.dtype)
            groups.append(nc)
        else:
            groups.append(jax.tree.map(struct, c))
    return {"prelude": jax.tree.map(struct, state["prelude"]),
            "groups": tuple(groups),
            "t": jax.ShapeDtypeStruct(state["t"].shape, state["t"].dtype),
            "page_tbl": jax.ShapeDtypeStruct((B, spec.max_pages),
                                             jnp.int32)}


def _upd_axis(slot, axis):
    def f(dst, src):
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis)
    return f


def slice_slot_paged(state: dict, slot) -> dict:
    """One slot's RESIDUAL decode state (batch dims kept, size 1): prelude
    caches, ``t``, the slot's page-table row, and the non-pool leaves of
    every group cache. The shared pools are deliberately absent — a slot
    has no private K/V rows, only table entries."""
    slot = jnp.asarray(slot, jnp.int32)

    def sl(axis):
        return lambda leaf: jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis)

    groups = []
    for c in state["groups"]:
        if isinstance(c, dict):
            groups.append({k: jax.tree.map(sl(1), v) for k, v in c.items()
                           if k not in _POOL_KEYS})
        else:
            groups.append(jax.tree.map(sl(1), c))
    return {"prelude": jax.tree.map(sl(0), state["prelude"]),
            "groups": tuple(groups), "t": sl(0)(state["t"]),
            "page_tbl": sl(0)(state["page_tbl"])}


def write_slot_paged(state: dict, sub: dict, slot) -> dict:
    """Splice a residual sub (``slice_slot_paged`` layout; ``page_tbl``
    optional) into slot ``slot``. Pool leaves pass through untouched."""
    slot = jnp.asarray(slot, jnp.int32)
    groups = []
    for c, sc in zip(state["groups"], sub["groups"]):
        if isinstance(c, dict):
            nc = dict(c)
            for k, v in sc.items():
                nc[k] = jax.tree.map(_upd_axis(slot, 1), c[k], v)
            groups.append(nc)
        else:
            groups.append(jax.tree.map(_upd_axis(slot, 0), c, sc))
    out = dict(state,
               prelude=jax.tree.map(_upd_axis(slot, 0), state["prelude"],
                                    sub["prelude"]),
               groups=tuple(groups),
               t=_upd_axis(slot, 0)(state["t"], sub["t"]))
    if "page_tbl" in sub:
        out["page_tbl"] = _upd_axis(slot, 0)(state["page_tbl"],
                                             sub["page_tbl"])
    return out


def _scatter_groups(groups, sub_groups, direct, halo, rsel, slot):
    """Write a contiguous sub-state's K/V/latent rows into the pools and
    its residual leaves into ``slot``. ``direct``/``halo``: (R,) physical
    scatter targets for the logical rows ``rsel`` selects from the sub
    leaves (``None`` = all rows, in order). Two scatters of the same
    delta keep the value operand at R rows — never 2R — and dump-page
    collisions between the halves are write-only garbage."""
    def pick(vals, axis):
        if rsel is None:
            return vals
        return jnp.take(vals, rsel, axis=axis)

    new = []
    for c, sc in zip(groups, sub_groups):
        if not isinstance(c, dict):
            new.append(jax.tree.map(_upd_axis(slot, 0), c, sc))
            continue
        nc = dict(c)
        for k, v in sc.items():
            if k == "latent":
                delta = pick(v[:, 0], 1)               # (G, S, D)
                delta = delta.astype(c["pool_latent"].dtype)
                nc["pool_latent"] = (c["pool_latent"]
                                     .at[:, direct, :].set(delta)
                                     .at[:, halo, :].set(delta))
            elif k in ("k", "v"):
                pool_key = "pool_" + k
                delta = pick(v[:, 0], 2)               # (G, Hkv, S, dh)
                delta = delta.astype(c[pool_key].dtype)
                nc[pool_key] = (c[pool_key]
                                .at[:, :, direct, :].set(delta)
                                .at[:, :, halo, :].set(delta))
            else:
                nc[k] = jax.tree.map(_upd_axis(slot, 1), c[k], v)
        new.append(nc)
    return tuple(new)


def prefill_into_slot_paged(params: dict, tokens: jax.Array,
                            cfg: ModelConfig, n_cache: int, state: dict,
                            slot, tbl_row, spec, extras=None, n_tokens=None,
                            build_policy: bool = True
                            ) -> Tuple[jax.Array, dict]:
    """Paged sibling of :func:`prefill_into_slot`: run the one-request B=1
    prefill CONTIGUOUSLY (bit-identical logits by construction), then
    scatter its K/V/latent rows into the pools through ``tbl_row`` — the
    slot's freshly reserved (max_pages,) page-table row — and splice the
    residual leaves. Pad rows land on the dump page (unreserved table
    entries point there), so over-reservation is never required."""
    assert tokens.shape[0] == 1, "prefill_into_slot_paged admits one request"
    from repro.core.paging import slot_write_rows
    logits, sub = prefill(params, tokens, cfg, n_cache, extras=extras,
                          n_tokens=n_tokens, build_policy=build_policy)
    slot = jnp.asarray(slot, jnp.int32)
    tbl_row = jnp.asarray(tbl_row, jnp.int32)
    direct, halo = slot_write_rows(tbl_row, spec)
    groups = _scatter_groups(state["groups"], sub["groups"], direct, halo,
                             None, slot)
    return logits, dict(
        state,
        prelude=jax.tree.map(_upd_axis(slot, 0), state["prelude"],
                             sub["prelude"]),
        groups=groups,
        t=_upd_axis(slot, 0)(state["t"], sub["t"]),
        page_tbl=_upd_axis(slot, 0)(state["page_tbl"], tbl_row[None]))


def _paged_contiguous_sub(state: dict, sub: dict, grows) -> dict:
    """Assemble the contiguous (B=1) view of a paged slot: the residual
    sub from ``slice_slot_paged`` plus K/V/latent gathered from the pools
    at physical rows ``grows`` (admission-class gather — never the decode
    hot path). Rows past the slot's ``t`` read dump-page garbage, which
    the extend/build consumers mask to exact zero contribution."""
    groups = []
    for c, sc in zip(state["groups"], sub["groups"]):
        if isinstance(c, dict):
            nc = dict(sc)
            if "pool_latent" in c:
                nc["latent"] = c["pool_latent"][:, grows, :][:, None]
            elif "pool_k" in c:
                nc["k"] = c["pool_k"][:, :, grows, :][:, None]
                nc["v"] = c["pool_v"][:, :, grows, :][:, None]
            groups.append(nc)
        else:
            groups.append(sc)
    return {"prelude": sub["prelude"], "groups": tuple(groups),
            "t": sub["t"]}


def extend_slot_paged(params: dict, tokens: jax.Array, cfg: ModelConfig,
                      state: dict, slot, spec, n_tokens=None,
                      update_policy: bool = True) -> Tuple[jax.Array, dict]:
    """Paged sibling of :func:`extend_slot`: gather the slot's contiguous
    view, run the UNCHANGED :func:`extend` over the delta (so the math is
    the contiguous path's, row for row), then scatter only the delta rows
    ``[t0, t0 + S)`` (plus their halo duplicates) back into the pools."""
    assert tokens.shape[0] == 1, "extend_slot_paged extends one slot"
    from repro.core.paging import slot_gather_rows
    S = tokens.shape[1]
    slot = jnp.asarray(slot, jnp.int32)
    sub = slice_slot_paged(state, slot)
    tbl_row = sub["page_tbl"][0]
    grows = slot_gather_rows(tbl_row, spec)
    cont = _paged_contiguous_sub(state, sub, grows)
    logits, cont = extend(params, tokens, cfg, cont, n_tokens=n_tokens,
                          update_policy=update_policy)

    t0 = jnp.asarray(sub["t"], jnp.int32)[0]
    P, pr = spec.page_tokens, spec.page_rows
    r = t0 + jnp.arange(S, dtype=jnp.int32)
    page = jnp.clip(r // P, 0, spec.max_pages - 1)
    off = r % P
    direct = tbl_row[page] * pr + off
    halo = jnp.where((off < spec.slack) & (page >= 1),
                     tbl_row[jnp.maximum(page - 1, 0)] * pr + P + off,
                     spec.dump_row)
    groups = _scatter_groups(state["groups"], cont["groups"], direct, halo,
                             r, slot)
    return logits, dict(
        state,
        prelude=jax.tree.map(_upd_axis(slot, 0), state["prelude"],
                             cont["prelude"]),
        groups=groups,
        t=_upd_axis(slot, 0)(state["t"], cont["t"]))


def rebuild_slot_policy_paged(params: dict, tokens: jax.Array,
                              cfg: ModelConfig, n_cache: int, state: dict,
                              slot, spec, n_tokens=None) -> dict:
    """Paged sibling of :func:`rebuild_slot_policy`: the slot's first
    ``Sp`` key/latent rows are gathered from the pools (they are the
    chunk-streamed prefill rows, bit-identical to contiguous admission)
    and fed through the same monolithic ``CachePolicy.build`` path."""
    assert tokens.shape[0] == 1, "rebuild_slot_policy_paged rebuilds one"
    pol = policy_for(cfg.lychee)
    if not pol.stateful:
        return state
    from repro.core.paging import slot_gather_rows
    Sp = tokens.shape[1]
    slot = jnp.asarray(slot, jnp.int32)
    tbl_row = jax.lax.dynamic_slice_in_dim(state["page_tbl"], slot, 1, 0)[0]
    grows = slot_gather_rows(tbl_row, spec)[:Sp]
    layout = None
    if pol.needs_layout:
        layout = make_layout(tokens, cfg, n_tokens=n_tokens)
    new_groups = []
    for pos_i, kind in enumerate(cfg.pattern):
        cache = state["groups"][pos_i]
        if not _policy_managed(cfg, kind, scanned=True) or \
                not isinstance(cache, dict) or "policy_state" not in cache:
            new_groups.append(cache)
            continue
        if "pool_latent" in cache:
            rows_v = cache["pool_latent"][:, grows, :]     # (G, Sp, D)
            keys = rows_v[:, None, None]                   # 1 logical head
        else:
            keys = cache["pool_k"][:, :, grows, :][:, None]  # (G,1,H,Sp,d)
        built = jax.vmap(lambda kg: pol.build_batched(
            kg, layout, n_cache, n_tokens=n_tokens))(keys)   # (G,1,...)
        merged = jax.tree.map(
            lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), slot, 1),
            cache["policy_state"], built)
        new_groups.append(dict(cache, policy_state=merged))
    return dict(state, groups=tuple(new_groups))


def copy_pool_pages(state: dict, src_rows, dst_rows) -> dict:
    """Copy whole physical pages (incl. halo rows) inside every pool leaf
    — the copy-on-write primitive behind prefix-cache registration and
    splicing (``core.paging.copy_page_rows`` builds the row vectors). A
    few pages per admission; never the decode hot path."""
    src_rows = jnp.asarray(src_rows, jnp.int32)
    dst_rows = jnp.asarray(dst_rows, jnp.int32)
    groups = []
    for c in state["groups"]:
        if isinstance(c, dict) and any(k in c for k in _POOL_KEYS):
            nc = dict(c)
            if "pool_latent" in c:
                nc["pool_latent"] = c["pool_latent"].at[:, dst_rows, :].set(
                    c["pool_latent"][:, src_rows, :])
            else:
                nc["pool_k"] = c["pool_k"].at[:, :, dst_rows, :].set(
                    c["pool_k"][:, :, src_rows, :])
                nc["pool_v"] = c["pool_v"].at[:, :, dst_rows, :].set(
                    c["pool_v"][:, :, src_rows, :])
            groups.append(nc)
        else:
            groups.append(c)
    return dict(state, groups=tuple(groups))


def reset_tbl_row(state: dict, slot, spec) -> dict:
    """Point a finished slot's page-table row back at the dump page. Must
    be enqueued BEFORE the slot's pages are recycled: inactive slots keep
    lock-step decoding and their garbage appends must not land in pages a
    new owner holds."""
    slot = jnp.asarray(slot, jnp.int32)
    row = jnp.full((1, spec.max_pages), spec.dump_page, jnp.int32)
    return dict(state, page_tbl=jax.lax.dynamic_update_slice_in_dim(
        state["page_tbl"], row, slot, 0))


def splice_sub_prefix(sub: dict, cfg: ModelConfig, keep) -> dict:
    """Truncate a residual sub (``slice_slot_paged`` layout) to its first
    ``keep`` tokens — the partial prefix-cache hit path. Every managed
    layer's policy state goes through ``CachePolicy.splice_prefix`` (drop
    selection units that reach past ``keep``) and ``t`` is reset; prelude
    caches keep their stale rows >= ``keep``, which the length masks hide
    and the suffix extend overwrites."""
    pol = policy_for(cfg.lychee)
    keep = jnp.asarray(keep, jnp.int32)
    groups = []
    for c in sub["groups"]:
        if isinstance(c, dict) and "policy_state" in c:
            c = dict(c, policy_state=pol.splice_prefix(c["policy_state"],
                                                       keep))
        groups.append(c)
    t = jnp.zeros_like(sub["t"]) + keep
    return dict(sub, groups=tuple(groups), t=t)
