"""Attention variants: GQA (global / sliding-window / bidirectional / cross)
and DeepSeek MLA, each with a full-sequence forward (train / prefill) and a
single-token decode step that plugs into LycheeCluster.

Prefill/train uses a blocked flash-style attention (lax.scan over KV blocks,
online softmax) so no S×S logits tensor is ever materialised — required for
prefill_32k / train_4k to fit. Decode uses either dense cache attention
(prelude layers — the paper keeps the first layers full), windowed ring-
buffer attention (local layers), or the configured :class:`~repro.core.
policy.CachePolicy` (global layers): policy selection + budgeted sparse
span attention, with LycheeCluster's hierarchical retrieval as the default
policy and Quest/ClusterKV/StreamingLLM/dense as registered alternatives.

MLA decode runs in *absorbed latent space*: q̃ = W_ukᵀ q_nope scores the
576-dim latent cache directly, so retrieval, the index, and the sparse
attention all operate on the compressed cache — LycheeCluster composes with
MLA without decompressing unselected tokens (a TPU-friendly synergy the
paper doesn't exploit; see DESIGN.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import full_decode_attention
from repro.core.attention import (full_decode_attention_ctxsharded,
                                  fused_policy_decode)
from repro.core.policy import CachePolicy, policy_for
from repro.core.types import ChunkLayout
from repro.models.layers import apply_rope, init_rmsnorm, rmsnorm, trunc_normal
from repro.sharding.ctx import kv_axes, shard

_NEG = -1e30


# ---------------------------------------------------------------------------
# Blocked flash attention (forward; differentiable)
# ---------------------------------------------------------------------------
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                    window: int = 0, scale: float, softcap: float = 0.0,
                    block_k: int = 512) -> jax.Array:
    """q: (B, Hq, Sq, dk); k/v: (B, Hkv, Sk, d*); positions: (Sq,)/(Sk,).

    GQA broadcast is handled internally. Never materialises Sq×Sk.
    """
    B, Hq, Sq, dk = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, Hkv, G, Sq, dk).astype(jnp.float32)

    BK = min(block_k, Sk)
    pad = (-Sk) % BK
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kpos = jnp.pad(k_pos, (0, pad), constant_values=-1)
    nblk = (Sk + pad) // BK
    kb = kp.reshape(B, Hkv, nblk, BK, -1).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(B, Hkv, nblk, BK, -1).transpose(2, 0, 1, 3, 4)
    pb = kpos.reshape(nblk, BK)

    def step(carry, xs):
        m, l, acc = carry
        kblk, vblk, pblk = xs                       # (B,Hkv,BK,dk) etc.
        logits = jnp.einsum("bhgsd,bhtd->bhgst", qf,
                            kblk.astype(jnp.float32)) * scale
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        valid = pblk >= 0                            # (BK,)
        mask = jnp.broadcast_to(valid[None, :], (Sq, BK))
        if causal:
            mask = mask & (pblk[None, :] <= q_pos[:, None])
        if window:
            mask = mask & (q_pos[:, None] - pblk[None, :] < window)
        logits = jnp.where(mask[None, None, None], logits, _NEG)
        m_new = jnp.maximum(m, jnp.max(logits, -1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, -1)
        acc = alpha[..., None] * acc + jnp.einsum(
            "bhgst,bhtd->bhgsd", p, vblk.astype(jnp.float32))
        return (m_new, l, acc), None

    dv = v.shape[-1]
    init = (jnp.full((B, Hkv, G, Sq), _NEG, jnp.float32),
            jnp.zeros((B, Hkv, G, Sq), jnp.float32),
            jnp.zeros((B, Hkv, G, Sq, dv), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Hq, Sq, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def init_gqa(key, cfg: ModelConfig, d_in: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    dh = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": trunc_normal(k1, (d, cfg.n_heads * dh), dt),
        "wk": trunc_normal(k2, (d, cfg.n_kv_heads * dh), dt),
        "wv": trunc_normal(k3, (d, cfg.n_kv_heads * dh), dt),
        "wo": trunc_normal(k4, (cfg.n_heads * dh, d), dt, scale=0.02 / 2),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, dt)
        p["k_norm"] = init_rmsnorm(dh, dt)
    return p


def _project_qkv(p, x, positions, cfg, rope: bool = True):
    """positions: (S,) shared, or (B, S) per-slot (continuous batching)."""
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, dh)
    k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, dh)
    v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, heads=True)
        k = apply_rope(k, positions, cfg.rope_theta, heads=True)
    # (B, H, S, dh)
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


def gqa_forward(p: dict, x: jax.Array, positions: jax.Array,
                cfg: ModelConfig, kind: str, rope: bool = True,
                n_tokens=None) -> Tuple:
    """Full-sequence forward. Returns (out (B,S,d), k, v) — k/v (B,Hkv,S,dh)
    post-RoPE, ready for caching/indexing.

    ``n_tokens`` (scalar, traced ok) marks a right-padded prompt: key rows
    at positions >= n_tokens are masked out of the attention (their K/V and
    output rows are garbage the caller must ignore — under causal masking
    they cannot contaminate the valid rows, so the valid-row outputs are
    bit-identical to the unpadded forward)."""
    dh = cfg.resolved_head_dim
    q, k, v = _project_qkv(p, x, positions, cfg, rope)
    q = shard(q, "batch", "model", None, None)
    k = shard(k, "batch", "model", None, None)
    v = shard(v, "batch", "model", None, None)
    causal = kind != "enc_attn"
    window = cfg.window if kind in ("attn_local", "swa_moe") else 0
    k_pos = positions
    if n_tokens is not None:
        n = jnp.asarray(n_tokens, jnp.int32)
        k_pos = jnp.where(jnp.arange(positions.shape[-1]) < n, positions, -1)
    out = flash_attention(q, k, v, q_pos=positions, k_pos=k_pos,
                          causal=causal, window=window,
                          scale=1.0 / dh ** 0.5, softcap=cfg.attn_softcap)
    B, Hq, S, _ = out.shape
    out = out.transpose(0, 2, 1, 3).reshape(B, S, Hq * dh) @ p["wo"]
    return shard(out, "batch", None, None), k, v


# -- decode ------------------------------------------------------------------
def _slot_t(t, B: int) -> jax.Array:
    """Per-slot position counters: scalar t broadcasts to (B,).

    Continuous batching serves every slot at its own sequence length, so all
    decode-time position arithmetic (RoPE, cache append, validity masks,
    lazy-update cadence) is per-batch-element."""
    return jnp.broadcast_to(jnp.asarray(t, jnp.int32), (B,))


def _policy_attend(q, k_cache, v_cache, pstate, t, cfg: ModelConfig,
                   pol: CachePolicy, budget=None):
    """Policy-managed decode attention — a thin config adapter over
    :func:`repro.core.attention.fused_policy_decode`, the fused
    select -> assemble_spans -> span executor -> update_batched hot path
    every registered policy shares (GQA and MLA both land here).

    q: (B, Hq, dk); t: (B,); ``budget``: optional (B,) int32 per-slot
    retrieval cap in tokens (0 = uncapped — the serving engine's overload
    valve). Returns (out (B, Hq, dv), updated policy state
    — ``None`` for stateless policies)."""
    dk = q.shape[-1]
    scale = 1.0 / dk ** 0.5 if cfg.qk_nope_dim == 0 else \
        1.0 / (cfg.qk_nope_dim + cfg.qk_rope_dim) ** 0.5
    return fused_policy_decode(q, k_cache, v_cache, pstate, t, pol,
                               cfg.lychee, scale=scale,
                               softcap=cfg.attn_softcap, budget=budget)


def _append_kv(cache_kv: jax.Array, row: jax.Array, at: jax.Array
               ) -> jax.Array:
    """Write each slot's new row at its OWN position: cache (B, H, N, d*),
    row (B, H, 1, d*), at (B,) int32."""
    return jax.vmap(
        lambda c, r, a: jax.lax.dynamic_update_slice_in_dim(c, r, a, 1))(
        cache_kv, row, at)


def gqa_decode(p: dict, x: jax.Array, t, cache: dict, cfg: ModelConfig,
               kind: str, managed: bool, rope: bool = True,
               pol: Optional[CachePolicy] = None, paged=None,
               budget=None) -> Tuple:
    """x: (B, 1, d); t: scalar or (B,) per-slot positions;
    cache: {"k","v"[, "policy_state"]}. ``managed`` marks layers whose cache
    is run through the configured CachePolicy (``pol`` may be passed by the
    caller — ``model.decode_step`` resolves it once per step — or is
    resolved here). Under the paged layout the cache carries
    ``{"pool_k","pool_v"}`` (batchless shared page pool) instead of
    ``{"k","v"}`` and ``paged`` is the ``(page_tbl (B, max_pages), spec)``
    pair ``model.decode_step`` threads in. Returns (out, cache)."""
    B = x.shape[0]
    dh = cfg.resolved_head_dim
    tt = _slot_t(t, B)
    pos = tt[:, None]                                       # (B, 1)
    q, k_t, v_t = _project_qkv(p, x, pos, cfg, rope)        # (B,H,1,dh)
    q = q[:, :, 0]                                          # (B, Hq, dh)

    if "pool_k" in cache:
        from repro.core.paging import PagedKV, append_rows
        tbl, spec = paged
        # two (2B,)-row scatters per pool leaf: each slot's direct row in
        # page t//P plus the halo duplicate in page t//P - 1 (dump-routed
        # when t%P >= slack or for page 0) — never a pool-sized op
        direct, halo = append_rows(tbl, tt, spec)
        rows = jnp.concatenate([direct, halo])
        kv2 = jnp.concatenate([k_t[:, :, 0]] * 2).transpose(1, 0, 2)
        vv2 = jnp.concatenate([v_t[:, :, 0]] * 2).transpose(1, 0, 2)
        pool_k = cache["pool_k"].at[:, rows, :].set(
            kv2.astype(cache["pool_k"].dtype))
        pool_v = cache["pool_v"].at[:, rows, :].set(
            vv2.astype(cache["pool_v"].dtype))
        cache = dict(cache, pool_k=pool_k, pool_v=pool_v)
        if managed and pol is None:
            pol = policy_for(cfg.lychee)
        pk = PagedKV(pool_k, tbl, spec)
        pv = PagedKV(pool_v, tbl, spec)
        out, pstate = _policy_attend(q, pk, pv, cache.get("policy_state"),
                                     tt, cfg, pol, budget=budget)
        if pstate is not None:
            cache = dict(cache, policy_state=pstate)
        out = out.reshape(B, 1, -1) @ p["wo"]
        return shard(out, "batch", None, None), cache

    local = kind in ("attn_local", "swa_moe") and cfg.window
    if local:
        W = cache["k"].shape[2]
        slot = jnp.mod(tt, W)
        k_c = _append_kv(cache["k"], k_t, slot)
        v_c = _append_kv(cache["v"], v_t, slot)
        n_valid = jnp.minimum(tt + 1, W)
        out = jax.vmap(lambda qq, kk, vv, nv: full_decode_attention(
            qq, kk, vv, nv, 1.0 / dh ** 0.5, cfg.attn_softcap))(
            q, k_c, v_c, n_valid)
        cache = dict(cache, k=k_c, v=v_c)
    else:
        k_c = _append_kv(cache["k"], k_t, tt)
        v_c = _append_kv(cache["v"], v_t, tt)
        k_c = shard(k_c, *kv_axes())
        v_c = shard(v_c, *kv_axes())
        cache = dict(cache, k=k_c, v=v_c)
        if managed and pol is None:
            pol = policy_for(cfg.lychee)
        if managed and pol is not None and not pol.is_dense and \
                (not pol.stateful or "policy_state" in cache):
            out, pstate = _policy_attend(q, k_c, v_c,
                                         cache.get("policy_state"), tt,
                                         cfg, pol, budget=budget)
            if pstate is not None:
                cache = dict(cache, policy_state=pstate)
        elif kv_axes()[2] is not None:
            # §Perf iteration 4: dense prelude attention, shard-local flash
            out = full_decode_attention_ctxsharded(
                q, k_c, v_c, tt + 1, kv_axes()[2], scale=1.0 / dh ** 0.5,
                softcap=cfg.attn_softcap)
        else:
            out = jax.vmap(lambda qq, kk, vv, tb: full_decode_attention(
                qq, kk, vv, tb + 1, 1.0 / dh ** 0.5, cfg.attn_softcap))(
                q, k_c, v_c, tt)

    out = out.reshape(B, 1, -1) @ p["wo"]
    return shard(out, "batch", None, None), cache


def gqa_extend(p: dict, x: jax.Array, t, cache: dict, cfg: ModelConfig,
               kind: str, managed: bool, rope: bool = True,
               pol: Optional[CachePolicy] = None, n_tokens=None,
               update_policy: bool = True) -> Tuple:
    """Multi-token EXTEND of one occupied slot — the session-reuse
    primitive between ``gqa_forward`` (prefill from scratch) and
    ``gqa_decode`` (one token).

    x: (1, S, d) — the next turn's delta tokens, embedded; t: (1,) the
    slot's current length (rows ``[0, t)`` of the cache hold the session
    history, INCLUDING previously generated tokens). The delta's K/V rows
    are appended at ``[t, t + S)`` and the delta queries run exact blocked
    flash attention over the whole cache (history + delta) with causal
    masking by absolute position — numerically the prefill math, so greedy
    continuations match the re-prefill-from-scratch oracle — while the
    policy state is EXTENDED through the streaming-update path
    (``CachePolicy.extend``: lychee lazy-grafts dynamic chunks, quest
    extends tail pages, clusterkv assigns to nearest centroids) instead of
    being rebuilt.

    Single-slot contract: extend operates on a ``slice_slot`` view (B=1) so
    per-slot positions reduce to one traced scalar and flash attention's
    shared position vectors apply. Returns (out (1, S, d_model), cache).

    ``n_tokens`` (scalar, traced ok) marks a right-padded delta (prompt
    bucketing / chunked admission): rows >= n_tokens are garbage — their
    cache rows land at positions >= t + n_tokens where causal masking (and
    the next chunk's overwrite) neutralises them, the ring scatter drops
    them, and the policy extension folds only the valid rows.
    ``update_policy=False`` skips the policy-state extension entirely (the
    chunked-admission "rebuild" mode builds the state once at the end).
    """
    B, S, _ = x.shape
    assert B == 1, "extend_slot extends one slot at a time"
    dh = cfg.resolved_head_dim
    tt = _slot_t(t, B)
    t0 = tt[0]                                              # traced scalar
    n_valid = None if n_tokens is None else jnp.asarray(n_tokens, jnp.int32)
    d_pos = t0 + jnp.arange(S, dtype=jnp.int32)             # (S,) absolute
    q, k_t, v_t = _project_qkv(p, x, d_pos[None], cfg, rope)  # (1,H,S,dh)
    scale = 1.0 / dh ** 0.5

    local = kind in ("attn_local", "swa_moe") and cfg.window
    if local:
        W = cache["k"].shape[2]
        # ring slot j currently holds the LARGEST position < t congruent to
        # j (mod W); never-written slots resolve to a negative position and
        # are masked as invalid (k_pos = -1)
        j = jnp.arange(W, dtype=jnp.int32)
        ring_pos = t0 - 1 - jnp.mod(t0 - 1 - j, W)
        ring_pos = jnp.where(ring_pos >= 0, ring_pos, -1)
        k_comb = jnp.concatenate([cache["k"], k_t], axis=2)
        v_comb = jnp.concatenate([cache["v"], v_t], axis=2)
        d_kpos = d_pos if n_valid is None else \
            jnp.where(jnp.arange(S) < n_valid, d_pos, -1)
        out = flash_attention(q, k_comb, v_comb, q_pos=d_pos,
                              k_pos=jnp.concatenate([ring_pos, d_kpos]),
                              causal=True, window=cfg.window, scale=scale,
                              softcap=cfg.attn_softcap)
        if n_valid is None:
            # fold the delta into the ring: only the last min(S, W) rows
            # can survive, so slot indices are distinct, one scatter does
            lo = max(0, S - W)
            slots = jnp.mod(d_pos[lo:], W)
            cache = dict(cache,
                         k=cache["k"].at[:, :, slots].set(k_t[:, :, lo:]),
                         v=cache["v"].at[:, :, slots].set(v_t[:, :, lo:]))
        else:
            # padded delta: only rows [max(0, n - W), n) survive in the
            # ring; everything else scatters out of range and is dropped
            i = jnp.arange(S, dtype=jnp.int32)
            keep = (i < n_valid) & (i >= n_valid - W)
            slots = jnp.where(keep, jnp.mod(d_pos, W), W)
            cache = dict(cache,
                         k=cache["k"].at[:, :, slots].set(k_t, mode="drop"),
                         v=cache["v"].at[:, :, slots].set(v_t, mode="drop"))
    else:
        k_c = jax.vmap(
            lambda c, r, a: jax.lax.dynamic_update_slice_in_dim(c, r, a, 1))(
            cache["k"], k_t, tt)
        v_c = jax.vmap(
            lambda c, r, a: jax.lax.dynamic_update_slice_in_dim(c, r, a, 1))(
            cache["v"], v_t, tt)
        k_c = shard(k_c, *kv_axes())
        v_c = shard(v_c, *kv_axes())
        cache = dict(cache, k=k_c, v=v_c)
        N = k_c.shape[2]
        # rows >= t + S (zero / slack rows) carry k_pos > every q_pos, so
        # causal masking excludes them — exact, no per-step copy
        out = flash_attention(q, k_c, v_c, q_pos=d_pos,
                              k_pos=jnp.arange(N, dtype=jnp.int32),
                              causal=True, scale=scale,
                              softcap=cfg.attn_softcap)
        if managed and pol is None:
            pol = policy_for(cfg.lychee)
        if update_policy and managed and pol is not None and \
                pol.stateful and "policy_state" in cache:
            cache = dict(cache, policy_state=pol.extend_batched(
                cache["policy_state"], k_c, tt,
                S if n_valid is None else n_valid))

    Hq = out.shape[1]
    out = out.transpose(0, 2, 1, 3).reshape(B, S, Hq * out.shape[-1])
    out = out @ p["wo"]
    return shard(out, "batch", None, None), cache


def gqa_prefill_cache(k: jax.Array, v: jax.Array, cfg: ModelConfig,
                      kind: str, layout: Optional[ChunkLayout],
                      n_cache: int, managed: bool,
                      pol: Optional[CachePolicy] = None, n_tokens=None,
                      build_policy: bool = True) -> dict:
    """Build the decode cache (and the policy's selection state) after a
    prefill forward.

    k/v: (B, Hkv, S, dh) post-RoPE. The cache's last ``core.types.
    cache_slack`` rows are the Pallas kernel's reserved DMA-overrun region:
    the engine never writes them (``usable_rows``), so any span DMA of up
    to ``span_len`` rows starting below ``t`` stays in bounds with no
    per-step cache copy.

    ``n_tokens`` (scalar, traced ok) marks a right-padded prompt: the ring
    buffer keeps only the valid window and the policy build masks the pad
    rows. ``build_policy=False`` installs the policy's EMPTY state instead
    of building it — the chunked-admission "rebuild" mode defers the build
    to one end-of-admission pass over the cached keys."""
    B, Hkv, S, dh = k.shape
    local = kind in ("attn_local", "swa_moe") and cfg.window
    if local:
        W = min(cfg.window, n_cache)
        ring_k = jnp.zeros((B, Hkv, W, dh), k.dtype)
        ring_v = jnp.zeros((B, Hkv, W, dh), v.dtype)
        if n_tokens is None:
            lo = max(0, S - W)
            slots = jnp.arange(lo, S, dtype=jnp.int32) % W
            ring_k = ring_k.at[:, :, slots].set(k[:, :, lo:])
            ring_v = ring_v.at[:, :, slots].set(v[:, :, lo:])
        else:
            n = jnp.asarray(n_tokens, jnp.int32)
            pos = jnp.arange(S, dtype=jnp.int32)
            keep = (pos < n) & (pos >= n - W)
            slots = jnp.where(keep, pos % W, W)      # W -> dropped scatter
            ring_k = ring_k.at[:, :, slots].set(k, mode="drop")
            ring_v = ring_v.at[:, :, slots].set(v, mode="drop")
        return {"k": ring_k, "v": ring_v}
    pad = n_cache - S
    k_c = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    v_c = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    k_c = shard(k_c, *kv_axes())
    v_c = shard(v_c, *kv_axes())
    cache = {"k": k_c, "v": v_c}
    if managed and pol is None:
        pol = policy_for(cfg.lychee)
    if managed and pol is not None and pol.stateful:
        # layout is batched (leading B dim) — vmap over (keys, layout) pairs.
        # The state is padded to the CACHE capacity (not the prompt length)
        # so every serving slot carries identical leaf shapes and a freed
        # slot can be respliced with any request's state.
        if not build_policy:
            cache["policy_state"] = pol.empty_batched(B, n_cache, Hkv, dh,
                                                      k.dtype)
        elif not (pol.needs_layout and layout is None):
            cache["policy_state"] = pol.build_batched(k, layout, n_cache,
                                                      n_tokens=n_tokens)
    return cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------
def cross_forward(p: dict, x: jax.Array, enc_k: jax.Array, enc_v: jax.Array,
                  cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d); enc_k/enc_v: (B, H, F, dh) precomputed from encoder."""
    B, S, _ = x.shape
    dh = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, dh).transpose(0, 2, 1, 3)
    F = enc_k.shape[2]
    out = flash_attention(
        q, enc_k, enc_v,
        q_pos=jnp.arange(S, dtype=jnp.int32),
        k_pos=jnp.arange(F, dtype=jnp.int32), causal=False,
        scale=1.0 / dh ** 0.5)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, -1) @ p["wo"]
    return out


def init_cross(key, cfg: ModelConfig) -> dict:
    dh = cfg.resolved_head_dim
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": trunc_normal(k1, (d, cfg.n_heads * dh), dt),
        "wk": trunc_normal(k2, (d, cfg.n_heads * dh), dt),
        "wv": trunc_normal(k3, (d, cfg.n_heads * dh), dt),
        "wo": trunc_normal(k4, (cfg.n_heads * dh, d), dt, scale=0.02 / 2),
    }


def cross_kv(p: dict, enc_out: jax.Array, cfg: ModelConfig):
    B, F, _ = enc_out.shape
    dh = cfg.resolved_head_dim
    k = (enc_out @ p["wk"]).reshape(B, F, cfg.n_heads, dh)
    v = (enc_out @ p["wv"]).reshape(B, F, cfg.n_heads, dh)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def cross_decode(p: dict, x: jax.Array, enc_k, enc_v, cfg: ModelConfig):
    B = x.shape[0]
    dh = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(B, cfg.n_heads, dh)
    F = enc_k.shape[2]
    out = jax.vmap(lambda qq, kk, vv: full_decode_attention(
        qq, kk, vv, F, 1.0 / dh ** 0.5))(q, enc_k, enc_v)
    return out.reshape(B, 1, -1) @ p["wo"]
