"""Multi-pod dry-run: prove the distribution config is coherent without
real hardware.

``.lower().compile()`` every (architecture × input shape × mesh)
combination against 512 placeholder host devices; print/record
``memory_analysis()`` (fits-per-device proof) and ``cost_analysis()``
(FLOPs/bytes for §Roofline), plus collective bytes parsed from the
optimized HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape decode_32k [--multipod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
# The VERY FIRST statements — before any other import (jax locks the device
# count on first init):
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P   # noqa: E402

from repro.configs.base import ARCH_IDS, get_config           # noqa: E402
from repro.launch.mesh import make_production_mesh            # noqa: E402
from repro.launch import specs as SP                          # noqa: E402
from repro.models import model as MD                          # noqa: E402
from repro.serving.engine import serve_step                   # noqa: E402
from repro.sharding.ctx import (context_parallel, mesh_context,  # noqa: E402
                                serving_mode)  # noqa: E402
from repro.sharding.rules import decode_state_specs, param_specs  # noqa: E402
from repro.training.optimizer import adamw_init               # noqa: E402

# ---------------------------------------------------------------------------
# Collective-bytes accounting (roofline's third term)
# ---------------------------------------------------------------------------
_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")
# opcode position: `<name> = <type(s)> <opcode>(...` — match the opcode
# token (plain or async "-start"); "-done" ops reference the start's bytes
# and must not be double counted.
_OP_RE = re.compile(
    r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64|c64)"
                       r"\[([\d,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output sizes of every collective op in the optimized HLO.

    Linear scan: a cheap substring test gates the (non-backtracking) regex,
    and shapes are read from the type prefix of the matched line only.
    """
    out = {k: 0 for k in _KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "all-" not in line and "reduce-scatter" not in line and \
                "collective-permute" not in line:
            continue
        if " = " not in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # type prefix sits between "= " and the opcode
        eq = line.index(" = ")
        prefix = line[eq + 3:m.start()]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(prefix):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dt]
        out[kind] += nbytes
        out["count"] += 1
    return out


# ---------------------------------------------------------------------------
# Lowering per input-shape kind
# ---------------------------------------------------------------------------
def lower_train(cfg, mesh, microbatch: int = 0):
    params_s = SP.params_specs_shapes(cfg)
    opt_s = jax.eval_shape(
        lambda p: adamw_init(p, cfg.opt_state_dtype), params_s)
    batch = SP.batch_specs(cfg, "train_4k")
    with mesh_context(mesh):
        pspecs = param_specs(params_s, cfg, mesh)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    # moments shard like params; step replicated
    from repro.training.optimizer import AdamWState
    opt_sh = AdamWState(step=NamedSharding(mesh, P()), mu=p_sh, nu=p_sh)
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    b_sh = {k: NamedSharding(mesh, P(baxes) + P(*([None] * (v.ndim - 1))))
            for k, v in batch.items()}

    from repro.training.train_step import make_train_step
    step_fn, _ = make_train_step(cfg, microbatch=microbatch)

    def raw(params, opt, b):
        params, opt, metrics = step_fn.__wrapped__(params, opt, b) \
            if hasattr(step_fn, "__wrapped__") else step_fn(params, opt, b)
        return params, opt, metrics["loss"]

    with mesh_context(mesh):
        # donate params+opt: output buffers alias inputs (§Perf iter. 2)
        return jax.jit(raw, in_shardings=(p_sh, opt_sh, b_sh),
                       out_shardings=(p_sh, opt_sh, None),
                       donate_argnums=(0, 1)
                       ).lower(params_s, opt_s, batch)


def lower_prefill(cfg, mesh):
    params_s = SP.params_specs_shapes(cfg)
    batch = SP.batch_specs(cfg, "prefill_32k")
    n_cache = SP.n_cache_for(cfg, SP.SHAPES["prefill_32k"]["seq"])
    with mesh_context(mesh):
        pspecs = param_specs(params_s, cfg, mesh)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    baxes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    b_sh = {k: NamedSharding(mesh, P(baxes) + P(*([None] * (v.ndim - 1))))
            for k, v in batch.items()}

    def raw(params, b):
        extras = {k: v for k, v in b.items() if k != "tokens"}
        return MD.prefill(params, b["tokens"], cfg, n_cache, extras=extras)

    with mesh_context(mesh):
        return jax.jit(raw, in_shardings=(p_sh, b_sh)).lower(params_s, batch)


def lower_decode(cfg, mesh, shape_name):
    params_s = SP.params_specs_shapes(cfg)
    state_s = SP.state_specs(cfg, shape_name)
    tok = SP.batch_specs(cfg, shape_name)["token"]
    baxes, caxes = SP.mesh_axes_for(shape_name, mesh)
    with mesh_context(mesh):
        pspecs = param_specs(params_s, cfg, mesh, serving=True)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    st_specs = decode_state_specs(state_s, mesh, baxes, caxes)
    st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), st_specs,
                         is_leaf=lambda x: isinstance(x, P))
    tok_sh = NamedSharding(mesh, P(baxes) if baxes else P())
    ctx_par = SP.SHAPES[shape_name]["batch"] == 1

    def raw(params, token, state):
        return serve_step(params, token, state, cfg)

    with mesh_context(mesh), context_parallel(ctx_par), serving_mode():
        # donate the state: the serving engine reuses the buffers in place
        # every step (§Perf iteration 1b) — without it the step double-
        # buffers the entire KV cache + index
        return jax.jit(raw, in_shardings=(p_sh, tok_sh, st_sh),
                       out_shardings=(None, st_sh),
                       donate_argnums=(2,)
                       ).lower(params_s, tok, state_s)


def run_one(arch: str, shape: str, multi_pod: bool, outdir: str,
            verbose: bool = True) -> dict:
    t0 = time.time()
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = SP.SHAPES[shape]["kind"]
    if kind == "train":
        lowered = lower_train(cfg, mesh,
                              microbatch=int(os.environ.get("MICROBATCH",
                                                            "0")))
    elif kind == "prefill":
        lowered = lower_prefill(cfg, mesh)
    else:
        lowered = lower_decode(cfg, mesh, shape)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):      # older jax: one dict per device
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "argument_bytes_per_device": getattr(
            mem, "argument_size_in_bytes", 0),
        "output_bytes_per_device": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes_per_device": getattr(
            mem, "peak_memory_in_bytes",
            getattr(mem, "temp_size_in_bytes", 0)),
        "collective_bytes": coll,
    }
    if verbose:
        print(f"[{arch} × {shape} × {rec['mesh']}] "
              f"lower {rec['lower_s']}s compile {rec['compile_s']}s")
        print(f"  flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e}")
        print(f"  per-device: args={rec['argument_bytes_per_device']/2**30:.2f}GiB "
              f"temp={rec['temp_bytes_per_device']/2**30:.2f}GiB")
        print(f"  collectives: {coll}")
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        tag = f"{arch}_{shape}_{rec['mesh'].replace('x', '-')}"
        with open(os.path.join(outdir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SP.SHAPES) + [None])
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"],
                    help="--all filter: which production mesh(es)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SP.SHAPES:
                if args.mesh in ("single", "both"):
                    combos.append((a, s, False))
                if args.mesh in ("multi", "both"):
                    combos.append((a, s, True))
    else:
        assert args.arch and args.shape
        combos = [(args.arch, args.shape, args.multipod)]

    failures = []
    for a, s, mp in combos:
        try:
            run_one(a, s, mp, args.out)
        except Exception as e:  # noqa: BLE001
            failures.append((a, s, mp, repr(e)))
            print(f"FAILED [{a} × {s} × {'2x16x16' if mp else '16x16'}]: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
