"""Production mesh builders (functions, not constants — importing this
module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is
    the DCN dimension — only batch/data-parallel collectives cross it."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (CPU smoke tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
