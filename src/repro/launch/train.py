"""Training launcher.

On real hardware this runs the pjit'd train step on the production mesh;
on this CPU container it runs a host-mesh (or unsharded) training loop —
the mesh plumbing is identical, only the device count differs. The
production-mesh *lowering* is exercised by ``repro.launch.dryrun``.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
      --reduced --steps 50 [--mesh-data 1 --mesh-model 1]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as MD
from repro.training import synthetic_lm_batches
from repro.training.checkpoint import save
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh-data", type=int, default=0,
                    help=">0: run under a host mesh of this data size")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = cfg.replace(dtype="float32")
    mesh = (make_host_mesh(args.mesh_data, args.mesh_model)
            if args.mesh_data else None)
    params = MD.init_model(jax.random.key(0), cfg)
    step_fn, init_state = make_train_step(
        cfg, base_lr=args.lr, total_steps=args.steps, mesh=mesh)
    opt = init_state(params)
    data = synthetic_lm_batches(cfg.vocab, args.batch, args.seq)
    rng = np.random.default_rng(0)

    t0 = time.time()
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(next(data))}
        if cfg.n_patches:
            batch["patches"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.n_patches, cfg.d_model)) * 0.02, jnp.float32)
        if cfg.is_encdec:
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.n_audio_frames, cfg.d_model)) * 0.02,
                jnp.float32)
        params, opt, metrics = step_fn(params, opt, batch)
        if i % 10 == 0:
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.ckpt:
        save(args.ckpt, params, step=args.steps)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
