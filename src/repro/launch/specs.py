"""ShapeDtypeStruct input stand-ins for every (architecture × input shape)
— weak-type-correct, shardable, no device allocation.

The four assigned input shapes:

  train_4k       seq=4096    global_batch=256   lowers train_step
  prefill_32k    seq=32768   global_batch=32    lowers prefill (index build)
  decode_32k     seq=32768   global_batch=128   lowers serve_step
  long_500k      seq=524288  global_batch=1     lowers serve_step (ctx-par)

Decode shapes lower ONE new token against a seq-length KV cache; the cache
slack (+8192) keeps every context/chunk/cluster dim divisible by the 512-way
multi-pod mesh (N, M=N/8, L=M/2 all divisible by 1024).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4_096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k": dict(kind="decode", seq=32_768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1),
}

CACHE_SLACK = 8_192          # decode headroom; keeps dims 1024-divisible


def n_cache_for(cfg: ModelConfig, seq: int) -> int:
    return seq + (cfg.n_patches or 0) + CACHE_SLACK


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Model-input ShapeDtypeStructs for the given input shape.

    For train: {"tokens", ...extras}. For prefill: same at prompt length.
    For decode: {"token": (B,)} (the state comes from ``state_specs``).
    """
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    dt = jnp.dtype(cfg.dtype)
    if sh["kind"] in ("train", "prefill"):
        out = {"tokens": _sds((B, S), jnp.int32)}
        if cfg.n_patches:
            out["patches"] = _sds((B, cfg.n_patches, cfg.d_model), dt)
        if cfg.is_encdec:
            out["frames"] = _sds((B, cfg.n_audio_frames, cfg.d_model), dt)
        return out
    return {"token": _sds((B,), jnp.int32)}


def decode_prompt_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """The prompt whose prefill *shapes* define the decode state."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    dt = jnp.dtype(cfg.dtype)
    out = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.n_patches:
        out["patches"] = _sds((B, cfg.n_patches, cfg.d_model), dt)
    if cfg.is_encdec:
        out["frames"] = _sds((B, cfg.n_audio_frames, cfg.d_model), dt)
    return out


def state_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStructs of the decode state — via ``jax.eval_shape`` over
    prefill, so dry-runs never allocate the multi-hundred-GB caches."""
    from repro.models import model as MD
    sh = SHAPES[shape_name]
    n_cache = n_cache_for(cfg, sh["seq"])
    prompt = decode_prompt_specs(cfg, shape_name)

    def full(params, tokens, extras):
        _, state = MD.prefill(params, tokens, cfg, n_cache, extras=extras)
        return state

    params_s = params_specs_shapes(cfg)
    extras = {k: v for k, v in prompt.items() if k != "tokens"}
    return jax.eval_shape(full, params_s, prompt["tokens"], extras)


def params_specs_shapes(cfg: ModelConfig):
    from repro.models import model as MD
    return jax.eval_shape(
        lambda: MD.init_model(jax.random.key(0), cfg))


def mesh_axes_for(shape_name: str, mesh) -> Tuple[Optional[tuple],
                                                  Optional[tuple]]:
    """(batch_axes, ctx_axes) policy per input shape (DESIGN.md §5).

    * train/prefill/decode batches shard over ('pod','data');
    * decode_32k additionally shards the context/chunk/cluster dims over
      'model' (the batch already occupies 'data');
    * long_500k (batch=1) shards the context over EVERYTHING — sequence/
      context parallelism over ('pod','data','model').
    """
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    sh = SHAPES[shape_name]
    if sh["kind"] in ("train", "prefill"):
        return batch, None
    if sh["batch"] == 1:
        ctx = ("pod", "data", "model") if has_pod else ("data", "model")
        return None, ctx
    return batch, ("model",)
