"""Serving launcher: batched requests against any --arch (reduced scale on
CPU; the production-mesh decode lowering is exercised by dryrun.py).

``--policy`` selects the KV cache-management policy for the managed layers
(lychee | quest | clusterkv | streaming | dense — the ``core.policy``
registry); every policy runs through the same engine. ``--no-lychee`` is a
legacy alias for ``--policy dense``.

Fixed-batch mode (default):

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --reduced --ctx 1024 --gen 32 --batch 2 [--policy quest]

Streaming mode (--stream): feeds a mixed-length request trace through the
continuous-batching scheduler — Poisson arrivals at --rate req/s (0 =
offline, everything queued at t=0), admission into freed slots via the
per-slot prefill splice:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --reduced --stream --requests 12 --slots 4 --rate 2.0
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, LycheeConfig, get_config
from repro.core.policy import list_policies
from repro.models import model as MD
from repro.serving import Engine, SamplerConfig, make_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ctx", type=int, default=1024)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--policy", default="lychee",
                    choices=list(list_policies()),
                    help="KV cache-management policy for managed layers")
    ap.add_argument("--no-lychee", action="store_true",
                    help="legacy alias for --policy dense")
    ap.add_argument("--temperature", type=float, default=0.8)
    # --- streaming admission ------------------------------------------
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching over a request trace")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = offline")
    ap.add_argument("--prompt-lens", type=int, nargs="+",
                    default=[64, 256, 1024])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    policy = "dense" if args.no_lychee else args.policy
    lychee = LycheeConfig(policy=policy, enabled=policy != "dense",
                          budget=args.budget, sink=16, buffer_size=64,
                          max_coarse=32, top_kg=8, full_attn_layers=0)
    cfg = get_config(args.arch, reduced=args.reduced).replace(
        dtype="float32", lychee=lychee)
    rng = np.random.default_rng(args.seed)
    params = MD.init_model(jax.random.key(0), cfg)
    mode = "full" if policy == "dense" else \
        f"{policy}(budget={args.budget})"

    if args.stream:
        trace = make_trace(rng, args.requests, cfg.vocab,
                           prompt_lens=args.prompt_lens,
                           gen_lens=(args.gen // 2, args.gen),
                           rate_rps=args.rate)
        n_cache = max(args.prompt_lens) + args.gen + 32
        engine = Engine(cfg, params, n_cache=n_cache)
        res = engine.serve(trace, n_slots=args.slots, mode="continuous",
                           sampler=SamplerConfig(
                               temperature=args.temperature, top_k=50),
                           verbose=True)
        print(f"[{cfg.name} | {mode} | stream] "
              f"{res.total_new_tokens} tokens / {res.wall_s:.2f}s = "
              f"{res.tokens_per_s:.1f} tok/s over {res.n_steps} steps")
        print(f"  latency p50 {res.p50_latency_s:.2f}s  "
              f"p99 {res.p99_latency_s:.2f}s  "
              f"mean TTFT {res.mean_ttft_s:.2f}s")
        for uid in sorted(res.requests)[:4]:
            r = res.requests[uid]
            print(f"  req{uid}: S={r.prompt_len} "
                  f"-> {r.tokens[:8]} ... ({len(r.tokens)} tok)")
        return

    prompts = rng.integers(0, cfg.vocab,
                           size=(args.batch, args.ctx)).astype(np.int32)
    extras = {}
    if cfg.n_patches:
        extras["patches"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_patches, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.is_encdec:
        extras["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_audio_frames, cfg.d_model)) * 0.02,
            jnp.float32)

    engine = Engine(cfg, params,
                    n_cache=args.ctx + (cfg.n_patches or 0) + args.gen + 32)
    res = engine.generate(prompts, args.gen,
                          SamplerConfig(temperature=args.temperature,
                                        top_k=50), extras=extras)
    print(f"[{cfg.name} | {mode}] prefill {res.prefill_s:.2f}s  "
          f"decode {res.decode_s:.2f}s  TPOT {res.tpot_ms:.1f}ms")
    for b in range(args.batch):
        print(f"  req{b}: {res.tokens[b, :12].tolist()} ...")


if __name__ == "__main__":
    main()
