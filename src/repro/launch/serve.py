"""Serving launcher: batched requests against any --arch (reduced scale on
CPU; the production-mesh decode lowering is exercised by dryrun.py).

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --reduced --ctx 1024 --gen 32 --batch 2 [--no-lychee]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, LycheeConfig, get_config
from repro.models import model as MD
from repro.serving import Engine, SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ctx", type=int, default=1024)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--no-lychee", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    lychee = (LycheeConfig(enabled=False) if args.no_lychee else
              LycheeConfig(budget=args.budget, sink=16, buffer_size=64,
                           max_coarse=32, top_kg=8, full_attn_layers=0))
    cfg = get_config(args.arch, reduced=args.reduced).replace(
        dtype="float32", lychee=lychee)
    rng = np.random.default_rng(0)
    params = MD.init_model(jax.random.key(0), cfg)
    prompts = rng.integers(0, cfg.vocab,
                           size=(args.batch, args.ctx)).astype(np.int32)
    extras = {}
    if cfg.n_patches:
        extras["patches"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_patches, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.is_encdec:
        extras["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_audio_frames, cfg.d_model)) * 0.02,
            jnp.float32)

    engine = Engine(cfg, params,
                    n_cache=args.ctx + (cfg.n_patches or 0) + args.gen + 32)
    res = engine.generate(prompts, args.gen,
                          SamplerConfig(temperature=args.temperature,
                                        top_k=50), extras=extras)
    mode = "full" if args.no_lychee else f"lychee(budget={args.budget})"
    print(f"[{cfg.name} | {mode}] prefill {res.prefill_s:.2f}s  "
          f"decode {res.decode_s:.2f}s  TPOT {res.tpot_ms:.1f}ms")
    for b in range(args.batch):
        print(f"  req{b}: {res.tokens[b, :12].tolist()} ...")


if __name__ == "__main__":
    main()
