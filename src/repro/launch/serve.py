"""Serving launcher: batched requests against any --arch (reduced scale on
CPU; the production-mesh decode lowering is exercised by dryrun.py).

``--policy`` selects the KV cache-management policy for the managed layers
(lychee | quest | clusterkv | streaming | dense — the ``core.policy``
registry); every policy runs through the same engine. ``--no-lychee`` is a
legacy alias for ``--policy dense``.

Fixed-batch mode (default):

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --reduced --ctx 1024 --gen 32 --batch 2 [--policy quest]

Streaming mode (--stream): feeds a mixed-length request trace through the
continuous-batching scheduler — Poisson arrivals at --rate req/s (0 =
offline, everything queued at t=0), admission into freed slots via the
per-slot prefill splice:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --reduced --stream --requests 12 --slots 4 --rate 2.0

Multi-turn sessions (--stream --turns N): each request becomes an N-turn
conversation; later turns append their prompt delta onto the slot's live KV
cache and index (``model.extend_slot`` — no re-prefill), each turn draws
its own sampling temperature (mixed greedy/sampled batches, one fused
dispatch per token), and --stream-tokens prints tokens as they are sampled
via the ``on_token`` callback:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --reduced --stream --turns 3 --requests 6 --slots 2 --stream-tokens
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ARCH_IDS, LycheeConfig, SLOConfig,
                                get_config)
from repro.core.policy import list_policies
from repro.models import model as MD
from repro.serving import (Engine, SamplerParams, make_session_trace,
                           make_trace)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ctx", type=int, default=1024)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--policy", default="lychee",
                    choices=list(list_policies()),
                    help="KV cache-management policy for managed layers")
    ap.add_argument("--no-lychee", action="store_true",
                    help="legacy alias for --policy dense")
    ap.add_argument("--temperature", type=float, default=0.8)
    # --- streaming admission ------------------------------------------
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching over a request trace")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = offline")
    ap.add_argument("--turns", type=int, default=1,
                    help="turns per session (>1: multi-turn chat trace; "
                         "later turns reuse the slot's KV via extend_slot)")
    ap.add_argument("--stream-tokens", action="store_true",
                    help="print tokens as they are sampled (on_token)")
    ap.add_argument("--prefill-chunk", type=int, default=512,
                    help="chunked-admission chunk size: long prompts "
                         "prefill in chunks with one batched decode step "
                         "interleaved between chunks, so live slots never "
                         "stall longer than one chunk forward (0 = "
                         "monolithic admission; non-extendable archs "
                         "fall back automatically)")
    ap.add_argument("--chunk-state", default="rebuild",
                    choices=("rebuild", "stream"),
                    help="policy state of a chunk-admitted slot: 'rebuild' "
                         "= one end-of-admission monolithic build (token-"
                         "identical to monolithic admission), 'stream' = "
                         "per-chunk CachePolicy.extend")
    ap.add_argument("--prompt-lens", type=int, nargs="+",
                    default=[64, 256, 1024])
    # --- SLO scheduling / overload control (--stream only) ------------
    ap.add_argument("--slo", action="store_true",
                    help="deadline-ordered admission + overload ladder "
                         "(degrade -> preempt -> shed); see "
                         "configs.base.SLOConfig")
    ap.add_argument("--ttft-slo", type=float, default=2.0,
                    help="TTFT target (s) driving deadlines and shedding")
    ap.add_argument("--tpot-slo", type=float, default=0.0,
                    help="TPOT target (ms, informational; 0 = none)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="arrived-queue bound (0 = unbounded); overflow "
                         "sheds lowest-priority-first under --slo")
    ap.add_argument("--degrade-budget", action="store_true",
                    help="under overload, shrink non-premium slots' "
                         "retrieval budgets (recorded on Turn.degraded)")
    ap.add_argument("--shed-grace", type=float, default=4.0,
                    help="shed a queued session once its projected TTFT "
                         "exceeds grace x target")
    ap.add_argument("--priorities", type=int, nargs="+", default=None,
                    help="priority classes assigned round-robin to the "
                         "trace (0 = premium: never shed/degraded)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    policy = "dense" if args.no_lychee else args.policy
    lychee = LycheeConfig(policy=policy, enabled=policy != "dense",
                          budget=args.budget, sink=16, buffer_size=64,
                          max_coarse=32, top_kg=8, full_attn_layers=0)
    cfg = get_config(args.arch, reduced=args.reduced).replace(
        dtype="float32", lychee=lychee)
    cfg = cfg.replace(serving=cfg.serving.replace(
        prefill_chunk=args.prefill_chunk, chunk_state=args.chunk_state))
    if args.slo:
        cfg = cfg.replace(serving=cfg.serving.replace(slo=SLOConfig(
            enabled=True, ttft_target_s=args.ttft_slo,
            tpot_target_ms=args.tpot_slo, max_pending=args.max_pending,
            degrade_budget=args.degrade_budget,
            shed_grace=args.shed_grace)))
    rng = np.random.default_rng(args.seed)
    params = MD.init_model(jax.random.key(0), cfg)
    mode = "full" if policy == "dense" else \
        f"{policy}(budget={args.budget})"

    if args.stream:
        if args.turns > 1:
            trace = make_session_trace(
                rng, args.requests, cfg.vocab, n_turns=args.turns,
                first_lens=args.prompt_lens,
                delta_lens=(16, max(32, args.gen)),
                gen_lens=(max(1, args.gen // 2), args.gen),
                temperatures=(0.0, args.temperature),
                rate_rps=args.rate)
        else:
            trace = make_trace(rng, args.requests, cfg.vocab,
                               prompt_lens=args.prompt_lens,
                               gen_lens=(args.gen // 2, args.gen),
                               rate_rps=args.rate)
        if args.priorities:
            for i, sess in enumerate(trace):
                sess.priority = args.priorities[i % len(args.priorities)]
        n_cache = max(s.total_len() for s in trace) + 32
        engine = Engine(cfg, params, n_cache=n_cache)
        on_token = None
        if args.stream_tokens:
            on_token = lambda uid, tok: print(  # noqa: E731
                f"    [token] sess{uid} -> {tok}")
        res = engine.serve(trace, n_slots=args.slots, mode="continuous",
                           sampler=SamplerParams(
                               temperature=args.temperature, top_k=50),
                           verbose=True, on_token=on_token)
        print(f"[{cfg.name} | {mode} | stream] "
              f"{res.total_new_tokens} tokens / {res.wall_s:.2f}s "
              f"({res.idle_s:.2f}s idle) = "
              f"{res.tokens_per_s:.1f} tok/s over {res.n_steps} steps")
        print(f"  latency p50 {res.p50_latency_s:.2f}s  "
              f"p99 {res.p99_latency_s:.2f}s  "
              f"mean TTFT {res.mean_ttft_s:.2f}s  "
              f"TPOT {res.mean_tpot_ms:.1f}ms  "
              f"ITL p99 {res.p99_itl_ms:.1f}ms / max {res.max_itl_ms:.1f}ms")
        if args.slo and res.metrics is not None:
            c = res.metrics.to_dict()["counters"]
            print(f"  [slo] finished {c['finished']}  shed {c['shed']}  "
                  f"preempted {c['preempted']}  "
                  f"degraded turns {c['degraded_turns']}  "
                  f"queue overflow {c['queue_overflow']}")
            for uid, sr in sorted(res.shed.items()):
                print(f"    shed sess{uid} prio={sr.priority} "
                      f"({sr.reason}) at {sr.at_s:.2f}s, projected TTFT "
                      f"{sr.projected_ttft_s:.2f}s")
        for uid in sorted(res.requests)[:4]:
            s = res.requests[uid]
            per_turn = " | ".join(
                f"T{j + 1}(S={t.prompt_len}, ttft {1e3 * t.ttft_s:.0f}ms)"
                f" {t.tokens[:4]}..." for j, t in enumerate(s.turns))
            print(f"  sess{uid}: {per_turn}")
        return

    prompts = rng.integers(0, cfg.vocab,
                           size=(args.batch, args.ctx)).astype(np.int32)
    extras = {}
    if cfg.n_patches:
        extras["patches"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_patches, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.is_encdec:
        extras["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_audio_frames, cfg.d_model)) * 0.02,
            jnp.float32)

    engine = Engine(cfg, params,
                    n_cache=args.ctx + (cfg.n_patches or 0) + args.gen + 32)
    res = engine.generate(prompts, args.gen,
                          SamplerParams(temperature=args.temperature,
                                        top_k=50), extras=extras)
    print(f"[{cfg.name} | {mode}] prefill {res.prefill_s:.2f}s  "
          f"decode {res.decode_s:.2f}s  TPOT {res.tpot_ms:.1f}ms")
    for b in range(args.batch):
        print(f"  req{b}: {res.tokens[b, :12].tolist()} ...")


if __name__ == "__main__":
    main()
