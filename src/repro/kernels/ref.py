"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-6
_NEG = -1e30


def chunk_pool_ref(keys: jax.Array, starts: jax.Array, lens: jax.Array, *,
                   max_chunk: int = 16, pooling: str = "mean") -> jax.Array:
    """keys: (H, N, d); starts/lens: (M,). Returns (H, M, d)."""
    H, N, d = keys.shape
    keys_p = jnp.pad(keys.astype(jnp.float32),
                     ((0, 0), (0, max_chunk), (0, 0)))
    offs = jnp.arange(max_chunk)

    def per_chunk(start, ln):
        rows = jax.lax.dynamic_slice_in_dim(
            keys_p, jnp.clip(start, 0, N), max_chunk, axis=1)  # (H, mc, d)
        mask = (offs < ln)[None, :, None]
        if pooling == "mean":
            pooled = jnp.sum(jnp.where(mask, rows, 0.0), 1) / jnp.maximum(
                ln.astype(jnp.float32), 1.0)
        else:
            pooled = jnp.max(jnp.where(mask, rows, -jnp.inf), 1)
            pooled = jnp.where(jnp.isfinite(pooled), pooled, 0.0)
        nrm = pooled * jax.lax.rsqrt(
            jnp.sum(pooled * pooled, -1, keepdims=True) + _EPS)
        return jnp.where(ln > 0, nrm, 0.0)                      # (H, d)

    out = jax.vmap(per_chunk, in_axes=(0, 0), out_axes=1)(starts, lens)
    return out.astype(keys.dtype)


def hier_score_ref(probe: jax.Array, centroid: jax.Array, radius: jax.Array,
                   valid: jax.Array) -> jax.Array:
    """probe: (H, d); centroid: (H, L, d); radius/valid: (H, L)."""
    p = probe.astype(jnp.float32)
    c = centroid.astype(jnp.float32)
    qn = jnp.linalg.norm(p, axis=-1, keepdims=True)
    s = jnp.einsum("hld,hd->hl", c, p) + qn * radius.astype(jnp.float32)
    return jnp.where(valid.astype(bool), s, _NEG)


def sparse_chunk_attention_ref(q, k_cache, v_cache, starts, lens, *,
                               max_chunk: int = 16, scale: float = 1.0,
                               softcap: float = 0.0) -> jax.Array:
    """Same contract as kernels.sparse_attention.sparse_chunk_attention."""
    B, Hkv, G, dk = q.shape
    N = k_cache.shape[2]
    C = starts.shape[-1]
    offs = jnp.arange(max_chunk, dtype=jnp.int32)
    tok = jnp.clip(starts[..., None], 0, N) + offs          # (B, H, C, mc)
    mask = offs < jnp.clip(lens, 0, max_chunk)[..., None]
    tok = jnp.clip(tok, 0, N - 1).reshape(B, Hkv, C * max_chunk)
    mask = mask.reshape(B, Hkv, C * max_chunk)

    # oracle semantics: exact f32 math over the selected rows (gather
    # first so only the selection is cast; the bf16-partials GSPMD
    # optimisation lives in core.attention.sparse_span_attention)
    k_sel = jnp.take_along_axis(
        k_cache, tok[..., None], axis=2).astype(jnp.float32)
    v_sel = jnp.take_along_axis(
        v_cache, tok[..., None], axis=2).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k_sel) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(mask[:, :, None, :], logits, _NEG)
    m = jnp.max(logits, -1, keepdims=True)
    p = jnp.where(mask[:, :, None, :], jnp.exp(logits - m), 0.0)
    den = jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgs,bhsd->bhgd", p / den, v_sel)
    return out.astype(q.dtype)
