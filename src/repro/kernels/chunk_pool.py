"""Pallas TPU kernel: variable-length chunk pooling (paper App. A kernel 1).

Pools each chunk's token keys (a contiguous span of <= max_chunk rows) into
one representative key: masked mean (or max) + L2 normalisation. The paper
ships a CUDA kernel for this; the TPU adaptation streams each chunk's span
HBM -> VMEM with an async copy sized to the static ``max_chunk`` bound and
masks the tail — no dynamic shapes ever reach the compute units.

Grid: one program per tile of TM chunks. Chunk starts/lengths ride in SMEM
via scalar prefetch so the DMA addresses are known before the body runs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import HBM as _HBM

_EPS = 1e-6


def _kernel(starts_ref, lens_ref, k_hbm, out_ref, scratch, sem, *,
            max_chunk: int, pooling: str):
    i = pl.program_id(0)
    TM = out_ref.shape[0]

    def body(j, carry):
        m = i * TM + j
        start = starts_ref[m]
        ln = lens_ref[m]
        cp = pltpu.make_async_copy(
            k_hbm.at[pl.ds(start, max_chunk), :], scratch, sem)
        cp.start()
        cp.wait()
        rows = scratch[...].astype(jnp.float32)            # (mc, d)
        pos = jax.lax.broadcasted_iota(jnp.int32, rows.shape, 0)
        mask = pos < ln
        if pooling == "mean":
            s = jnp.sum(jnp.where(mask, rows, 0.0), axis=0)
            pooled = s / jnp.maximum(ln.astype(jnp.float32), 1.0)
        else:  # max
            pooled = jnp.max(jnp.where(mask, rows, -jnp.inf), axis=0)
            pooled = jnp.where(jnp.isfinite(pooled), pooled, 0.0)
        nrm = pooled * jax.lax.rsqrt(jnp.sum(pooled * pooled) + _EPS)
        nrm = jnp.where(ln > 0, nrm, 0.0)
        out_ref[pl.ds(j, 1), :] = nrm[None].astype(out_ref.dtype)
        return carry

    jax.lax.fori_loop(0, TM, body, 0)


@functools.partial(jax.jit, static_argnames=("max_chunk", "pooling",
                                             "tile_m", "interpret"))
def chunk_pool(keys: jax.Array, starts: jax.Array, lens: jax.Array, *,
               max_chunk: int = 16, pooling: str = "mean",
               tile_m: int = 8, interpret: bool = True) -> jax.Array:
    """keys: (H, N, d); starts/lens: (M,) int32. Returns (H, M, d).

    Spans are clamped so [start, start+max_chunk) stays in-bounds after a
    max_chunk-row zero pad; the mask uses the true length.
    """
    H, N, d = keys.shape
    M = starts.shape[0]
    TM = min(tile_m, M)
    Mp = ((M + TM - 1) // TM) * TM
    starts_p = jnp.clip(jnp.pad(starts, (0, Mp - M)), 0, N)
    lens_p = jnp.clip(jnp.pad(lens, (0, Mp - M)), 0, max_chunk)
    keys_p = jnp.pad(keys, ((0, 0), (0, max_chunk), (0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Mp // TM,),
        in_specs=[pl.BlockSpec(memory_space=_HBM)],
        out_specs=pl.BlockSpec((TM, d), lambda i, *_: (i, 0)),
        scratch_shapes=[pltpu.VMEM((max_chunk, d), keys.dtype),
                        pltpu.SemaphoreType.DMA],
    )
    call = pl.pallas_call(
        functools.partial(_kernel, max_chunk=max_chunk, pooling=pooling),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mp, d), keys.dtype),
        interpret=interpret,
        name="lychee_chunk_pool",
    )
    out = jax.vmap(lambda k: call(starts_p, lens_p, k))(keys_p)
    return out[:, :M]
