"""Pallas TPU kernel: budgeted sparse attention over retrieved chunks
(paper Algorithm 1 step 3 — the decode hot loop).

The active set produced by hierarchical retrieval is a list of *contiguous
chunk spans* (start, len <= max_chunk) — structure-aware chunks, the sink
span, and the recent-buffer spans all share this form. Each grid step DMAs a
tile of TC spans from the HBM-resident KV cache into VMEM (one contiguous
copy per span — this is why chunk-granular retrieval maps so well to TPU:
gathers become span DMAs, unlike token-scatter designs such as ClusterKV),
then runs one flash-attention update (online softmax, f32 accumulators).

Grid: (C // TC,) per (batch, kv-head); callers vmap the leading dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import HBM as _HBM

_NEG = -1e30


def _kernel(starts_ref, lens_ref, q_ref, k_hbm, v_hbm, out_ref,
            k_scr, v_scr, len_scr, m_scr, l_scr, acc_scr, ksem, vsem, *,
            max_chunk: int, tile_c: int, scale: float, softcap: float):
    i = pl.program_id(0)
    n_tiles = pl.num_programs(0)
    G = q_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # ---- DMA the tile's spans into VMEM ---------------------------------
    def fetch(j, carry):
        c = i * tile_c + j
        start = starts_ref[c]
        kcp = pltpu.make_async_copy(
            k_hbm.at[pl.ds(start, max_chunk), :],
            k_scr.at[pl.ds(j * max_chunk, max_chunk), :], ksem)
        vcp = pltpu.make_async_copy(
            v_hbm.at[pl.ds(start, max_chunk), :],
            v_scr.at[pl.ds(j * max_chunk, max_chunk), :], vsem)
        kcp.start()
        vcp.start()
        len_scr[pl.ds(j, 1)] = lens_ref[c][None].astype(jnp.int32)
        kcp.wait()
        vcp.wait()
        return carry

    jax.lax.fori_loop(0, tile_c, fetch, 0)

    # ---- flash update ----------------------------------------------------
    S = tile_c * max_chunk
    q = q_ref[...].astype(jnp.float32)                       # (G, dk)
    k = k_scr[...].astype(jnp.float32)                       # (S, dk)
    v = v_scr[...].astype(jnp.float32)                       # (S, dv)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jax.lax.broadcasted_iota(jnp.int32, (tile_c, max_chunk), 1)
    mask = (pos < len_scr[...][:, None]).reshape(1, S)
    logits = jnp.where(mask, logits, _NEG)

    m_prev = m_scr[...]                                      # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(i == n_tiles - 1)
    def _finish():
        out_ref[...] = (acc_scr[...] /
                        jnp.maximum(l_scr[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("max_chunk", "tile_c", "scale",
                                             "softcap", "interpret"))
def sparse_chunk_attention(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, starts: jax.Array,
                           lens: jax.Array, *, max_chunk: int = 16,
                           tile_c: int = 8, scale: float = 1.0,
                           softcap: float = 0.0,
                           interpret: bool = True) -> jax.Array:
    """Single-position decode attention over chunk spans.

    q: (B, Hkv, G, dk); k_cache: (B, Hkv, N, dk); v_cache: (B, Hkv, N, dv);
    starts/lens: (B, Hkv, C) int32 (len == 0 -> span skipped).
    Returns (B, Hkv, G, dv) in q.dtype.
    """
    B, Hkv, G, dk = q.shape
    N = k_cache.shape[2]
    dv = v_cache.shape[3]
    C = starts.shape[-1]
    TC = min(tile_c, C)
    Cp = ((C + TC - 1) // TC) * TC

    starts_p = jnp.clip(jnp.pad(starts, ((0, 0), (0, 0), (0, Cp - C))), 0, N)
    lens_p = jnp.clip(jnp.pad(lens, ((0, 0), (0, 0), (0, Cp - C))),
                      0, max_chunk)
    k_p = jnp.pad(k_cache, ((0, 0), (0, 0), (0, max_chunk), (0, 0)))
    v_p = jnp.pad(v_cache, ((0, 0), (0, 0), (0, max_chunk), (0, 0)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Cp // TC,),
        in_specs=[
            pl.BlockSpec((G, dk), lambda i, *_: (0, 0)),
            pl.BlockSpec(memory_space=_HBM),
            pl.BlockSpec(memory_space=_HBM),
        ],
        out_specs=pl.BlockSpec((G, dv), lambda i, *_: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((TC * max_chunk, dk), k_cache.dtype),
            pltpu.VMEM((TC * max_chunk, dv), v_cache.dtype),
            pltpu.VMEM((TC,), jnp.int32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dv), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    call = pl.pallas_call(
        functools.partial(_kernel, max_chunk=max_chunk, tile_c=TC,
                          scale=scale, softcap=softcap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, dv), q.dtype),
        interpret=interpret,
        name="lychee_sparse_attention",
    )
    inner = jax.vmap(jax.vmap(lambda s, ln, qq, kk, vv: call(s, ln, qq, kk, vv)))
    return inner(starts_p, lens_p, q, k_p, v_p)
