"""Pallas TPU kernel: budgeted sparse attention over retrieved chunks
(paper Algorithm 1 step 3 — the decode hot loop).

The active set produced by hierarchical retrieval is a list of *contiguous
chunk spans* (start, len <= max_chunk) — structure-aware chunks, the sink
span, and the recent-buffer spans all share this form. Each grid step DMAs a
tile of TC spans from the HBM-resident KV cache into VMEM (one contiguous
copy per span — this is why chunk-granular retrieval maps so well to TPU:
gathers become span DMAs, unlike token-scatter designs such as ClusterKV),
then runs one flash-attention update (online softmax, f32 accumulators).

Single compiled dispatch: the grid is ``(B, Hkv, C // TC)`` with the span
tables scalar-prefetched (SMEM-resident before the body runs, the paged-
attention pattern), so one ``pallas_call`` covers the whole batch — no outer
vmap, no per-(batch, head) relaunch.

Cache layout contract (tail slack): the caller allocates the KV cache with
at least ``max_chunk`` rows of slack past the last writable position (see
``core.types.cache_slack``), so a span DMA starting at any valid position
``start <= t - 1`` stays in bounds *by construction*. The wrapper therefore
never copies or pads the cache — the O(N)-per-token ``jnp.pad`` of the
pre-slack design is gone (``tests/test_decode_fused.py`` asserts no
cache-shaped copy survives in the jaxpr). Zero-length spans skip their DMAs
entirely (``pl.when`` guard), so padding slots in the span table cost
nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import HBM as _HBM

_NEG = -1e30


def _kernel(starts_ref, lens_ref, q_ref, k_hbm, v_hbm, out_ref,
            k_scr, v_scr, len_scr, m_scr, l_scr, acc_scr, ksem, vsem, *,
            max_chunk: int, tile_c: int, scale: float, softcap: float,
            shared_cache: bool):
    b = pl.program_id(0)
    h = pl.program_id(1)
    i = pl.program_id(2)
    n_tiles = pl.num_programs(2)

    @pl.when((b == 0) & (h == 0) & (i == 0))
    def _zero_scratch():
        # skipped spans leave their scratch rows untouched; rows never
        # DMA'd in this invocation must still be *finite* so the masked
        # p @ v contraction contributes exact zeros (0 * NaN would not)
        k_scr[...] = jnp.zeros_like(k_scr)
        v_scr[...] = jnp.zeros_like(v_scr)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # ---- DMA the tile's spans into VMEM ---------------------------------
    # Issue every guarded copy first (per-span semaphores), then wait:
    # the TC span fetches of a tile are in flight concurrently.
    def _copies(j):
        c = i * tile_c + j
        start = starts_ref[b, h, c]
        # shared_cache: one batchless page pool serves every slot — the
        # scalar-prefetched span table already carries slot-specific
        # PHYSICAL rows (page-table-translated), so only the batch index
        # collapses
        bk = 0 if shared_cache else b
        kcp = pltpu.make_async_copy(
            k_hbm.at[bk, h, pl.ds(start, max_chunk), :],
            k_scr.at[pl.ds(j * max_chunk, max_chunk), :], ksem.at[j])
        vcp = pltpu.make_async_copy(
            v_hbm.at[bk, h, pl.ds(start, max_chunk), :],
            v_scr.at[pl.ds(j * max_chunk, max_chunk), :], vsem.at[j])
        return kcp, vcp

    def fetch(j, carry):
        ln = lens_ref[b, h, i * tile_c + j]
        len_scr[pl.ds(j, 1)] = ln[None].astype(jnp.int32)

        @pl.when(ln > 0)          # len == 0 padding spans cost nothing
        def _start():
            kcp, vcp = _copies(j)
            kcp.start()
            vcp.start()
        return carry

    def drain(j, carry):
        ln = lens_ref[b, h, i * tile_c + j]

        @pl.when(ln > 0)
        def _wait():
            kcp, vcp = _copies(j)
            kcp.wait()
            vcp.wait()
        return carry

    jax.lax.fori_loop(0, tile_c, fetch, 0)
    jax.lax.fori_loop(0, tile_c, drain, 0)

    # ---- flash update ----------------------------------------------------
    S = tile_c * max_chunk
    q = q_ref[0, 0].astype(jnp.float32)                      # (G, dk)
    k = k_scr[...].astype(jnp.float32)                       # (S, dk)
    v = v_scr[...].astype(jnp.float32)                       # (S, dv)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jax.lax.broadcasted_iota(jnp.int32, (tile_c, max_chunk), 1)
    mask = (pos < len_scr[...][:, None]).reshape(1, S)
    logits = jnp.where(mask, logits, _NEG)

    m_prev = m_scr[...]                                      # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(i == n_tiles - 1)
    def _finish():
        out_ref[0, 0] = (acc_scr[...] /
                         jnp.maximum(l_scr[...], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("max_chunk", "tile_c", "scale",
                                             "softcap", "interpret",
                                             "shared_cache"))
def sparse_chunk_attention(q: jax.Array, k_cache: jax.Array,
                           v_cache: jax.Array, starts: jax.Array,
                           lens: jax.Array, *, max_chunk: int = 16,
                           tile_c: int = 8, scale: float = 1.0,
                           softcap: float = 0.0,
                           interpret: bool | None = None,
                           shared_cache: bool = False) -> jax.Array:
    """Single-position decode attention over chunk spans — ONE compiled
    ``pallas_call`` whose grid covers ``(B, Hkv, C // TC)``.

    q: (B, Hkv, G, dk); k_cache: (B, Hkv, N, dk); v_cache: (B, Hkv, N, dv);
    starts/lens: (B, Hkv, C) int32 (len == 0 -> span skipped, no DMA).
    Returns (B, Hkv, G, dv) in q.dtype.

    Contract: every span with len > 0 must satisfy ``start + max_chunk <=
    N`` — callers allocate ``core.types.cache_slack`` tail rows so this
    holds for any span starting below the logical capacity. The wrapper
    clips ``starts`` to that bound as a hard safety net but never copies
    the cache. ``interpret=None`` follows ``kernels.ops`` precedence:
    explicit arg > ``ops.INTERPRET`` override > backend default (compiled
    Mosaic on TPU, the interpreter oracle elsewhere).

    ``shared_cache=True`` is the paged-pool mode: ``k_cache``/``v_cache``
    are a batchless ``(1, Hkv, R, d*)`` page pool shared by every slot and
    ``starts`` carries page-table-translated PHYSICAL pool rows (still one
    contiguous DMA per span — the halo-page contract means translated
    spans never straddle a page).
    """
    if interpret is None:
        from repro.kernels import ops  # deferred: ops imports this module
        interpret = ops.resolve_interpret(None)
    B, Hkv, G, dk = q.shape
    N = k_cache.shape[2]
    if shared_cache:
        assert k_cache.shape[0] == 1 and v_cache.shape[0] == 1, (
            "shared_cache expects a batchless (1, Hkv, R, d) pool")
    assert N >= max_chunk, (
        f"cache has {N} rows < max_chunk={max_chunk}: reserve tail slack "
        "(core.types.cache_slack / usable_rows) so span DMAs stay in bounds")
    dv = v_cache.shape[3]
    C = starts.shape[-1]
    TC = min(tile_c, C)
    Cp = ((C + TC - 1) // TC) * TC

    starts_p = jnp.clip(jnp.pad(starts, ((0, 0), (0, 0), (0, Cp - C))),
                        0, N - max_chunk)
    lens_p = jnp.clip(jnp.pad(lens, ((0, 0), (0, 0), (0, Cp - C))),
                      0, max_chunk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, Cp // TC),
        in_specs=[
            pl.BlockSpec((1, 1, G, dk), lambda b, h, i, *_: (b, h, 0, 0)),
            pl.BlockSpec(memory_space=_HBM),
            pl.BlockSpec(memory_space=_HBM),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dv), lambda b, h, i, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((TC * max_chunk, dk), k_cache.dtype),
            pltpu.VMEM((TC * max_chunk, dv), v_cache.dtype),
            pltpu.VMEM((TC,), jnp.int32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dv), jnp.float32),
            pltpu.SemaphoreType.DMA((TC,)),
            pltpu.SemaphoreType.DMA((TC,)),
        ],
    )
    call = pl.pallas_call(
        functools.partial(_kernel, max_chunk=max_chunk, tile_c=TC,
                          scale=scale, softcap=softcap,
                          shared_cache=shared_cache),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dv), q.dtype),
        interpret=interpret,
        name="lychee_sparse_attention",
    )
    return call(starts_p, lens_p, q, k_cache, v_cache)
