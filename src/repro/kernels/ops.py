"""Jit'd public wrappers over the Pallas kernels.

``interpret`` resolution, in precedence order:

1. an explicit ``interpret=`` argument at the call site;
2. the module override ``repro.kernels.ops.INTERPRET`` (a bool forces every
   kernel one way — tests pin True, a TPU pod launcher may pin False);
3. the backend default (``INTERPRET = None``, the shipped setting): compiled
   Mosaic on TPU, the interpreter oracle on CPU/GPU — so the same decode
   code path is fast where it can be and correct everywhere.
"""
from __future__ import annotations

from repro.kernels import ref
from repro.kernels.chunk_pool import chunk_pool
from repro.kernels.hier_score import hier_score
from repro.kernels.pallas_compat import backend_interpret
from repro.kernels.sparse_attention import sparse_chunk_attention

INTERPRET: bool | None = None    # None -> backend-aware (see module doc)


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Apply the three-level precedence documented in the module docstring."""
    if interpret is not None:
        return interpret
    if INTERPRET is not None:
        return INTERPRET
    return backend_interpret()


def pool_chunk_keys(keys, starts, lens, *, max_chunk=16, pooling="mean",
                    interpret=None):
    return chunk_pool(keys, starts, lens, max_chunk=max_chunk,
                      pooling=pooling, interpret=resolve_interpret(interpret))


def score_upper_bound(probe, centroid, radius, valid, *, interpret=None):
    return hier_score(probe, centroid, radius, valid,
                      interpret=resolve_interpret(interpret))


def chunk_attention(q, k_cache, v_cache, starts, lens, *, max_chunk=16,
                    scale=1.0, softcap=0.0, interpret=None,
                    shared_cache=False):
    return sparse_chunk_attention(
        q, k_cache, v_cache, starts, lens, max_chunk=max_chunk, scale=scale,
        softcap=softcap, interpret=resolve_interpret(interpret),
        shared_cache=shared_cache)


__all__ = ["INTERPRET", "chunk_attention", "pool_chunk_keys", "ref",
           "resolve_interpret", "score_upper_bound"]
