"""Jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True so the kernels execute (and are tested) on
CPU; on a real TPU runtime set ``repro.kernels.ops.INTERPRET = False`` (or
pass explicitly) and the same code paths compile to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.chunk_pool import chunk_pool
from repro.kernels.hier_score import hier_score
from repro.kernels.sparse_attention import sparse_chunk_attention

INTERPRET = True


def pool_chunk_keys(keys, starts, lens, *, max_chunk=16, pooling="mean",
                    interpret=None):
    return chunk_pool(keys, starts, lens, max_chunk=max_chunk,
                      pooling=pooling,
                      interpret=INTERPRET if interpret is None else interpret)


def score_upper_bound(probe, centroid, radius, valid, *, interpret=None):
    return hier_score(probe, centroid, radius, valid,
                      interpret=INTERPRET if interpret is None else interpret)


def chunk_attention(q, k_cache, v_cache, starts, lens, *, max_chunk=16,
                    scale=1.0, softcap=0.0, interpret=None):
    return sparse_chunk_attention(
        q, k_cache, v_cache, starts, lens, max_chunk=max_chunk, scale=scale,
        softcap=softcap,
        interpret=INTERPRET if interpret is None else interpret)


__all__ = ["INTERPRET", "chunk_attention", "pool_chunk_keys", "ref",
           "score_upper_bound"]
