"""Pallas TPU kernel: hierarchical UB scoring (paper Eqn. 2).

Computes UB(q, u) = qᵀμ_u + ‖q‖₂·r_u for a tile of centroids per program —
one fused matvec + AXPY on the MXU/VPU, used at both the coarse and fine
levels of the index. Centroid tiles are BlockSpec-mapped into VMEM; the
query is broadcast to every program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _kernel(q_ref, cent_ref, rad_ref, valid_ref, out_ref):
    q = q_ref[0].astype(jnp.float32)                     # (d,)
    cent = cent_ref[0].astype(jnp.float32)               # (TL, d)
    qn = jnp.sqrt(jnp.sum(q * q))
    s = jnp.dot(cent, q[:, None],
                preferred_element_type=jnp.float32)[:, 0]  # (TL,)
    s = s + qn * rad_ref[0].astype(jnp.float32)
    s = jnp.where(valid_ref[0] > 0, s, _NEG)
    out_ref[0, :] = s.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_l", "interpret"))
def hier_score(probe: jax.Array, centroid: jax.Array, radius: jax.Array,
               valid: jax.Array, *, tile_l: int = 128,
               interpret: bool = True) -> jax.Array:
    """probe: (H, d); centroid: (H, L, d); radius/valid: (H, L).

    Returns float32 UB scores (H, L); invalid entries are -1e30.
    """
    H, L, d = centroid.shape
    TL = min(tile_l, L)
    Lp = ((L + TL - 1) // TL) * TL
    cent_p = jnp.pad(centroid, ((0, 0), (0, Lp - L), (0, 0)))
    rad_p = jnp.pad(radius, ((0, 0), (0, Lp - L)))
    valid_p = jnp.pad(valid.astype(jnp.int32), ((0, 0), (0, Lp - L)))

    out = pl.pallas_call(
        _kernel,
        grid=(H, Lp // TL),
        in_specs=[
            pl.BlockSpec((1, d), lambda h, l: (h, 0)),
            pl.BlockSpec((1, TL, d), lambda h, l: (h, l, 0)),
            pl.BlockSpec((1, TL), lambda h, l: (h, l)),
            pl.BlockSpec((1, TL), lambda h, l: (h, l)),
        ],
        out_specs=pl.BlockSpec((1, TL), lambda h, l: (h, l)),
        out_shape=jax.ShapeDtypeStruct((H, Lp), jnp.float32),
        interpret=interpret,
        name="lychee_hier_score",
    )(probe, cent_p, rad_p, valid_p)
    return out[:, :L]
