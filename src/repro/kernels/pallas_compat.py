"""Pallas TPU API compatibility across jax versions.

jax renamed ``TPUMemorySpace`` -> ``MemorySpace`` (and grew an ``HBM``
member; older versions spell it ``ANY``). The kernels import the resolved
``HBM`` token from here so the rename is absorbed in exactly one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

MEM = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
HBM = getattr(MEM, "HBM", MEM.ANY)
