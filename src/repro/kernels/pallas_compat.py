"""Pallas TPU API compatibility across jax versions.

jax renamed ``TPUMemorySpace`` -> ``MemorySpace`` (and grew an ``HBM``
member; older versions spell it ``ANY``). The kernels import the resolved
``HBM`` token from here so the rename is absorbed in exactly one place.
Also hosts the backend-aware ``interpret`` default shared by every kernel
wrapper: compiled Mosaic on a real TPU, the interpreter oracle elsewhere.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

MEM = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace
HBM = getattr(MEM, "HBM", MEM.ANY)


def backend_interpret() -> bool:
    """Resolved default for ``interpret=None``: False (compile to Mosaic)
    iff the default jax backend is a TPU; True (interpreter oracle) on
    CPU/GPU hosts, where Mosaic cannot lower."""
    return jax.default_backend() != "tpu"
