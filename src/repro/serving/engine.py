"""Serving engine: static batched generate + session-centric continuous
batching.

Two execution models over the same pure model functions:

* ``generate`` — the classic fixed batch: B prompts of one length prefill
  together, decode proceeds lock-step until every slot finishes. Simple,
  but a finished slot idles until the whole batch drains.
* ``serve`` — **continuous batching over sessions**: a :class:`~repro.
  serving.scheduler.Scheduler` feeds a FIFO trace of :class:`~repro.
  serving.scheduler.Session` objects (multi-turn conversations; single-turn
  sessions are the old requests) into ``B`` persistent decode slots. When a
  slot frees, the next session is admitted by a single-sequence prefill at
  its natural length whose KV caches, cache-policy selection state and
  position counter are spliced into that slot (``model.prefill_into_slot``)
  while the other slots keep decoding unperturbed. When a TURN finishes and
  the session has more turns, the slot is NOT released: the next turn's
  prompt delta is appended onto the slot's live KV rows and index by
  ``model.extend_slot`` — every :class:`~repro.core.policy.CachePolicy`
  extends through its streaming-update path (lychee lazy-grafts dynamic
  chunks, quest extends tail pages, clusterkv assigns to nearest
  centroids) — instead of re-prefilling the whole history. That reuse is
  the paper's "efficient streaming generation" claim applied across turns;
  ``benchmarks/session_reuse.py`` measures the turn-2 TTFT win and
  architectures without an extend path (SSM hybrids — ``model.can_extend``)
  transparently fall back to re-prefilling the concatenated history.

Sampling is per-slot and fused: each turn carries its own
:class:`~repro.serving.sampler.SamplerParams`, the engine keeps (B,)
temperature/top-k/top-p vectors, and the jitted decode step derives each
slot's PRNG key as ``fold_in(fold_in(base_key, uid), step)`` and samples
on-device — one dispatch and one (B,)-int host transfer per token even for
batches mixing greedy and temperature-0.9 requests (host-side sampling
happens only once per turn, on the prefill/extend logits). Because the key
depends only on (seed, session uid, per-session sample counter), sampled
outputs are independent of co-scheduled sessions, slot assignment and
admission order — the greedy serve==solo bit-identity invariant extended to
``temperature > 0``.

Per-turn stopping: an engine-level ``eos_id`` (or per-turn override) ends a
turn, and each turn may carry ``stop`` token sequences — matched on the
host against the sampled tail; a matched suffix is trimmed from the turn's
public ``tokens`` (the raw ``sampled`` list keeps it, because those tokens
live in the KV cache and in the next turn's history). ``on_token(uid,
token)`` streams every sampled token as it is produced.

Scheduler contract (who owns what):

* the scheduler owns WHICH session runs in which slot and when (FIFO order,
  arrival gating, lifecycle timestamps); it never touches device state;
* the engine owns the device state, turn transitions, and the admission
  *policy*: continuous mode admits into any free slot, static mode only
  admits when all slots are drained (the lock-step baseline measured by
  ``benchmarks/throughput.py``);
* greedy outputs per session are independent of co-scheduled sessions
  (decode is per-slot vmapped; prefill/extend are per-session at natural
  length), so continuous and static modes produce bit-identical greedy
  tokens — the invariant the throughput benchmark checks.

``serve_step`` is the pure function the decode dry-run shapes
(``decode_32k`` / ``long_500k``) lower: one new token against a seq_len KV
cache, including hierarchical retrieval, budgeted sparse attention and the
lazy index update. It stays jit-donated — the engine reuses the state
buffers in place every step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.policy import policy_for
from repro.core.types import usable_rows
from repro.models import model as MD
from repro.serving.sampler import (SamplerParams, sample, slot_keys)
from repro.serving.scheduler import Scheduler, Session, Turn


def serve_step(params, token, state, cfg: ModelConfig):
    """One decode step (the dry-run entry point). token: (B,) int32."""
    return MD.decode_step(params, token, state, cfg)


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray            # (B, max_new)
    n_generated: np.ndarray       # (B,)
    prefill_s: float
    decode_s: float
    tpot_ms: float                # time per output token (decode only)


@dataclasses.dataclass
class ServeResult:
    """Aggregate metrics of one trace replay (per-session/turn detail rides
    on the Session objects themselves)."""

    mode: str                     # "continuous" | "static"
    requests: Dict[int, Session]  # uid -> finished session (tokens filled)
    wall_s: float
    decode_s: float               # wall-clock inside lock-step decode only
                                  # (admission prefills + scheduling excluded)
    idle_s: float                 # open-loop wait for the next arrival while
                                  # every slot was empty (excluded from
                                  # tokens_per_s — idle is the trace's, not
                                  # the engine's)
    n_steps: int                  # batched decode steps executed
    total_new_tokens: int
    tokens_per_s: float
    p50_latency_s: float
    p99_latency_s: float
    mean_ttft_s: float


class Engine:
    """Batched inference engine over the pure model functions."""

    def __init__(self, cfg: ModelConfig, params, *, n_cache: int,
                 eos_id: Optional[int] = None, donate_state: bool = True,
                 policy: Optional[str] = None):
        """``policy`` overrides the cache-management policy of
        ``cfg.lychee`` (a name from the ``core.policy`` registry); ``None``
        keeps the config's own selection."""
        if policy is not None:
            cfg = cfg.replace(lychee=cfg.lychee.replace(
                policy=policy, enabled=policy != "dense"))
        self.cfg = cfg
        self.params = params
        self.n_cache = n_cache
        # the tail cache_slack rows are the Pallas kernel's DMA-overrun
        # region (core.types): requests may only fill the usable prefix
        self.usable = usable_rows(n_cache, cfg.lychee)
        self.eos_id = eos_id
        self.policy = policy_for(cfg.lychee).name
        # multi-turn KV/index reuse needs an extend path through every
        # decode block; SSM hybrids fall back to re-prefilling the history
        self.can_extend = MD.can_extend(cfg)
        # debug counters (reset per serve): host-side eager samples should
        # number one per TURN (prefill/extend logits), never per token
        self.last_host_samples = 0

        donate = (2,) if donate_state else ()
        self._prefill = jax.jit(
            lambda p, tk, extras: MD.prefill(p, tk, cfg, n_cache,
                                             extras=extras))
        self._step = jax.jit(
            lambda p, tok, st: serve_step(p, tok, st, cfg),
            donate_argnums=donate)

        def _greedy_step(p, tok, st):
            # greedy decode fuses the argmax into the jitted step: one
            # dispatch and one (B,)-int host transfer per token instead of
            # step + eager argmax over the (B, V) logits
            logits, ns = serve_step(p, tok, st, cfg)
            return jnp.argmax(logits, -1).astype(jnp.int32), ns

        def _sampled_step(p, tok, st, base, uid, step, temp, top_k, top_p):
            # fully fused per-slot sampling: logits never leave the device,
            # each slot's key is fold_in(fold_in(base, uid), step) — a pure
            # function of (seed, request, request-local counter), so co-
            # scheduling cannot perturb sampled outputs
            logits, ns = serve_step(p, tok, st, cfg)
            keys = slot_keys(base, uid, step)
            return sample(keys, logits, temp, top_k, top_p), ns

        self._step_greedy = jax.jit(_greedy_step, donate_argnums=donate)
        self._step_sampled = jax.jit(_sampled_step, donate_argnums=donate)
        self._prefill_slot = jax.jit(
            lambda p, tk, st, slot: MD.prefill_into_slot(
                p, tk, cfg, n_cache, st, slot),
            donate_argnums=donate)
        self._extend_slot = jax.jit(
            lambda p, tk, st, slot: MD.extend_slot(p, tk, cfg, st, slot),
            donate_argnums=donate)

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new: int,
                 sampler: SamplerParams = SamplerParams(),
                 extras: Optional[dict] = None, seed: int = 0
                 ) -> GenerateResult:
        """prompts: (B, S) int32 (right-padded prompts share one layout)."""
        B, S = prompts.shape
        assert S + max_new <= self.usable, \
            "cache too small (tail cache_slack rows are reserved)"
        extras = extras or {}
        base = jax.random.key(seed)
        uid_a = jnp.arange(B, dtype=jnp.int32)
        temp = jnp.full((B,), sampler.temperature, jnp.float32)
        top_k = jnp.full((B,), sampler.top_k, jnp.int32)
        top_p = jnp.full((B,), sampler.top_p, jnp.float32)

        t0 = time.perf_counter()
        logits, state = self._prefill(self.params, jnp.asarray(prompts),
                                      extras)
        logits.block_until_ready()
        t1 = time.perf_counter()

        pad = self.eos_id if self.eos_id is not None else 0
        greedy = sampler.temperature <= 0.0
        # pre-fill with the pad token: an early break (every row done) must
        # leave the unreached columns padded, not zero
        out = np.full((B, max_new), pad, np.int32)
        done = np.zeros((B,), bool)
        ngen = np.zeros((B,), np.int64)
        tok = sample(slot_keys(base, uid_a, jnp.zeros((B,), jnp.int32)),
                     logits, temp, top_k, top_p)
        for i in range(max_new):
            # finished slots keep decoding lock-step, but their sampled
            # tokens are garbage — pad them so ``tokens`` is trustworthy
            tok_np = np.asarray(tok)
            out[:, i] = np.where(done, pad, tok_np)
            ngen[~done] += 1
            if self.eos_id is not None:
                done |= tok_np == self.eos_id
                if done.all():
                    break
            if greedy:
                tok, state = self._step_greedy(self.params, tok, state)
            else:
                # one fused dispatch per token: row r of step i+1 samples
                # with key fold_in(fold_in(base, r), i + 1)
                tok, state = self._step_sampled(
                    self.params, tok, state, base, uid_a,
                    jnp.full((B,), i + 1, jnp.int32), temp, top_k, top_p)
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        n_steps = int(ngen.max()) or 1
        return GenerateResult(tokens=out, n_generated=ngen,
                              prefill_s=t1 - t0, decode_s=t2 - t1,
                              tpot_ms=1e3 * (t2 - t1) / n_steps)

    # ------------------------------------------------------------------
    # Continuous batching over sessions
    # ------------------------------------------------------------------
    def _zero_state(self, n_slots: int):
        """All-slots-empty decode state (valid: every mask False, t=0)."""
        dummy = jax.ShapeDtypeStruct(
            (n_slots, max(8, self.cfg.lychee.min_chunk)), jnp.int32)
        shapes = jax.eval_shape(
            lambda p, tk: MD.prefill(p, tk, self.cfg, self.n_cache)[1],
            self.params, dummy)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def serve(self, requests: Sequence[Session], *, n_slots: int,
              mode: str = "continuous",
              sampler: SamplerParams = SamplerParams(),
              seed: int = 0, verbose: bool = False,
              on_token: Optional[Callable[[int, int], None]] = None,
              reuse: str = "extend") -> ServeResult:
        """Replay a session trace through the slot scheduler.

        mode="continuous": a freed slot immediately admits the next pending
        session (prefill splice) while other slots keep decoding.
        mode="static": admission only when ALL slots are free — lock-step
        waves, the static-batching baseline.

        ``sampler`` is the default for turns without their own
        :class:`SamplerParams`; ``seed`` anchors the per-request RNG
        (fold_in(fold_in(key(seed), uid), step)). ``on_token(uid, token)``
        is invoked for every sampled token as it is produced (streaming).
        ``reuse`` picks the multi-turn admission primitive: "extend"
        (default) appends each later turn's delta onto the slot's live KV
        rows and index via ``model.extend_slot`` — automatic fallback to
        re-prefill on architectures without an extend path — while
        "reprefill" always rebuilds from the concatenated history (the
        baseline ``benchmarks/session_reuse.py`` compares against).

        Session objects are mutated in place (lifecycle timestamps +
        generated tokens); pass fresh copies to compare modes. Greedy
        outputs per session are identical across modes, across ``reuse``
        choices (up to policy-state graft scheduling) and to ``generate``
        of the session alone; sampled outputs are identical across
        co-scheduling/admission permutations (see module docstring).
        """
        assert mode in ("continuous", "static"), mode
        assert reuse in ("extend", "reprefill"), reuse
        assert not (self.cfg.is_encdec or self.cfg.n_patches), \
            "streaming admission serves text-only requests"
        for s in requests:
            assert s.total_len() <= self.usable, \
                f"session {s.uid}: cache too small (tail cache_slack " \
                f"reserved; total prompt+gen across turns must fit)"
            assert all(t.max_new >= 1 for t in s.turns), \
                f"session {s.uid}: every turn generates at least one " \
                f"token (its first sample IS its generation; max_new=0 " \
                f"would emit a token the total_len() guard never counted)"
        use_extend = reuse == "extend" and self.can_extend

        sched = Scheduler(n_slots)
        sched.submit_all(requests)
        state = self._zero_state(n_slots)
        base = jax.random.key(seed)
        cur = np.zeros((n_slots,), np.int32)
        active = np.zeros((n_slots,), bool)
        remaining = np.zeros((n_slots,), np.int64)
        uid = np.zeros((n_slots,), np.int32)
        stepc = np.zeros((n_slots,), np.int32)   # per-session sample counter
        temp = np.zeros((n_slots,), np.float32)
        top_k = np.zeros((n_slots,), np.int32)
        top_p = np.ones((n_slots,), np.float32)
        # an all-greedy trace keeps the leaner argmax-fused step
        all_greedy = sampler.temperature <= 0.0 and all(
            (t.sampling is None or t.sampling.temperature <= 0.0)
            for s in requests for t in s.turns)
        n_steps = 0
        decode_s = 0.0
        idle_s = 0.0
        self.last_host_samples = 0
        # uid/temperature/top-k/top-p only change at turn transitions —
        # cache their device copies so the hot loop uploads just the token
        # vector and the per-slot sample counter each step
        slots_dirty = True
        dev_slots = None
        t0 = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t0

        def begin_turn(slot: int, sess: Session) -> jax.Array:
            """Run this turn's admission primitive; returns its last-
            position logits (1, V). Turn 0 prefills into the freed slot;
            later turns extend the occupied slot (or re-prefill the
            concatenated history when extension is unavailable/disabled).
            The delta always leads with the previous turn's final sampled
            token — it was never fed back, so its KV row is still absent.
            """
            nonlocal state, slots_dirty
            slots_dirty = True
            turn = sess.turns[sess.cur]
            turn.started_s = now()
            remaining[slot] = turn.max_new
            sp = turn.sampling if turn.sampling is not None else sampler
            temp[slot] = sp.temperature
            top_k[slot] = sp.top_k
            top_p[slot] = sp.top_p
            if sess.cur == 0:
                logits, state = self._prefill_slot(
                    self.params, jnp.asarray(turn.prompt[None]), state,
                    jnp.int32(slot))
            elif use_extend:
                prev = sess.turns[sess.cur - 1]
                delta = np.concatenate([
                    np.asarray(prev.sampled[-1:], np.int32),
                    np.asarray(turn.prompt, np.int32)])
                logits, state = self._extend_slot(
                    self.params, jnp.asarray(delta[None]), state,
                    jnp.int32(slot))
            else:
                hist = sess.history_tokens(sess.cur)
                logits, state = self._prefill_slot(
                    self.params, jnp.asarray(hist[None]), state,
                    jnp.int32(slot))
            if verbose:
                kind = ("admit" if sess.cur == 0 else
                        "extend" if use_extend else "reprefill")
                print(f"[serve:{mode}] t={now():7.3f}s {kind} "
                      f"sess{sess.uid} turn {sess.cur + 1}/{sess.n_turns} "
                      f"(S={turn.prompt_len}, gen={turn.max_new}) "
                      f"-> slot {slot}")
            return logits

        def first_token(slot: int, turn: Turn, logits) -> int:
            """Sample this turn's first token from the prefill/extend
            logits (host-side — once per TURN, not per token) with the same
            (uid, step) key the fused loop would use."""
            keys = slot_keys(base, jnp.asarray([uid[slot]], jnp.int32),
                             jnp.asarray([stepc[slot]], jnp.int32))
            tok = int(np.asarray(sample(
                keys, logits, temp[slot:slot + 1], top_k[slot:slot + 1],
                top_p[slot:slot + 1]))[0])
            self.last_host_samples += 1
            stepc[slot] += 1
            cur[slot] = tok
            return tok

        def emit(slot: int, sess: Session, turn: Turn, tok: int) -> bool:
            """Record one sampled token; True when it ends the turn
            (budget, EOS, or a stop-sequence match — the matched suffix is
            trimmed from the public ``tokens`` but stays in ``sampled``:
            those tokens are in the KV cache and the next turn's history).
            """
            turn.sampled.append(tok)
            turn.tokens.append(tok)
            if turn.first_token_s is None:
                turn.first_token_s = now()
            if on_token is not None:
                on_token(sess.uid, tok)
            remaining[slot] -= 1
            eos = turn.eos_id if turn.eos_id is not None else self.eos_id
            done = remaining[slot] <= 0 or (eos is not None and tok == eos)
            for seq in turn.stop:
                L = len(seq)
                if L and len(turn.sampled) >= L and \
                        tuple(turn.sampled[-L:]) == tuple(seq):
                    del turn.tokens[-L:]
                    done = True
                    break
            if done:
                turn.finished_s = now()
            return done

        def advance(slot: int) -> None:
            """Current turn ended: start the next turn in place (the slot —
            and its KV/index — is retained) or retire the session."""
            sess = sched.slot_of(slot)
            while True:
                sess.cur += 1
                if sess.cur >= sess.n_turns:
                    sched.finish(slot, now())
                    active[slot] = False
                    cur[slot] = 0
                    if verbose:
                        ntok = sum(len(t.tokens) for t in sess.turns)
                        print(f"[serve:{mode}] t={now():7.3f}s finish "
                              f"sess{sess.uid} ({ntok} tok, "
                              f"{sess.n_turns} turns)")
                    return
                turn = sess.turns[sess.cur]
                logits = begin_turn(slot, sess)
                if not emit(slot, sess, turn, first_token(slot, turn,
                                                          logits)):
                    return

        while not sched.all_done:
            # ---- admission phase --------------------------------------
            if mode == "continuous" or sched.active == 0:
                for slot in sched.free_slots():
                    if sched.next_ready(now()) is None:
                        break
                    sess = sched.admit(slot, now())
                    sess.cur = 0
                    uid[slot] = sess.uid
                    stepc[slot] = 0
                    active[slot] = True
                    turn = sess.turns[0]
                    logits = begin_turn(slot, sess)
                    if emit(slot, sess, turn, first_token(slot, turn,
                                                          logits)):
                        advance(slot)
            if not active.any():
                if sched.pending:
                    # open-loop trace: nothing can happen before the FIFO
                    # head arrives — sleep until exactly then (no 10 ms
                    # busy-poll) and book the wait as trace idleness, not
                    # engine time
                    wait = (sched.next_arrival_s() or 0.0) - now()
                    if wait > 0:
                        time.sleep(wait)
                        idle_s += wait
                continue

            # ---- one lock-step decode over the live slots --------------
            t_step = time.perf_counter()
            if all_greedy:
                tok_d, state = self._step_greedy(self.params,
                                                 jnp.asarray(cur), state)
            else:
                if slots_dirty:
                    dev_slots = (jnp.asarray(uid), jnp.asarray(temp),
                                 jnp.asarray(top_k), jnp.asarray(top_p))
                    slots_dirty = False
                d_uid, d_temp, d_top_k, d_top_p = dev_slots
                tok_d, state = self._step_sampled(
                    self.params, jnp.asarray(cur), state, base,
                    d_uid, jnp.asarray(stepc), d_temp, d_top_k, d_top_p)
            tok = np.asarray(tok_d)
            n_steps += 1
            decode_s += time.perf_counter() - t_step
            for slot in range(n_slots):
                if not active[slot]:
                    continue
                sess = sched.slot_of(slot)
                turn = sess.turns[sess.cur]
                tk = int(tok[slot])
                stepc[slot] += 1
                cur[slot] = tk
                if emit(slot, sess, turn, tk):
                    advance(slot)

        jax.block_until_ready(state["t"])
        wall = now()
        done = sched.finished
        total = sum(len(t.tokens) for s in done.values() for t in s.turns)
        lats = np.asarray([s.latency_s for s in done.values()])
        ttfts = np.asarray([s.ttft_s for s in done.values()])
        busy = max(wall - idle_s, 1e-9)
        return ServeResult(
            mode=mode, requests=done, wall_s=wall, decode_s=decode_s,
            idle_s=idle_s, n_steps=n_steps, total_new_tokens=total,
            tokens_per_s=total / busy,
            p50_latency_s=float(np.percentile(lats, 50)) if len(lats) else 0.0,
            p99_latency_s=float(np.percentile(lats, 99)) if len(lats) else 0.0,
            mean_ttft_s=float(ttfts.mean()) if len(ttfts) else 0.0)
