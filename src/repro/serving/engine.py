"""Serving engine: static batched generate + session-centric continuous
batching.

Two execution models over the same pure model functions:

* ``generate`` — the classic fixed batch: B prompts of one length prefill
  together, decode proceeds lock-step until every slot finishes. Simple,
  but a finished slot idles until the whole batch drains.
* ``serve`` — **continuous batching over sessions**: a :class:`~repro.
  serving.scheduler.Scheduler` feeds a FIFO trace of :class:`~repro.
  serving.scheduler.Session` objects (multi-turn conversations; single-turn
  sessions are the old requests) into ``B`` persistent decode slots. When a
  slot frees, the next session is admitted by a single-sequence prefill at
  its natural length whose KV caches, cache-policy selection state and
  position counter are spliced into that slot (``model.prefill_into_slot``)
  while the other slots keep decoding unperturbed. When a TURN finishes and
  the session has more turns, the slot is NOT released: the next turn's
  prompt delta is appended onto the slot's live KV rows and index by
  ``model.extend_slot`` — every :class:`~repro.core.policy.CachePolicy`
  extends through its streaming-update path (lychee lazy-grafts dynamic
  chunks, quest extends tail pages, clusterkv assigns to nearest
  centroids) — instead of re-prefilling the whole history. That reuse is
  the paper's "efficient streaming generation" claim applied across turns;
  ``benchmarks/session_reuse.py`` measures the turn-2 TTFT win and
  architectures without an extend path (SSM hybrids — ``model.can_extend``)
  transparently fall back to re-prefilling the concatenated history.

Sampling is per-slot and fused: each turn carries its own
:class:`~repro.serving.sampler.SamplerParams`, the engine keeps (B,)
temperature/top-k/top-p vectors, and the jitted decode step derives each
slot's PRNG key as ``fold_in(fold_in(base_key, uid), step)`` and samples
on-device — one dispatch and one (B,)-int host transfer per token even for
batches mixing greedy and temperature-0.9 requests (host-side sampling
happens only once per turn, on the prefill/extend logits). Because the key
depends only on (seed, session uid, per-session sample counter), sampled
outputs are independent of co-scheduled sessions, slot assignment and
admission order — the greedy serve==solo bit-identity invariant extended to
``temperature > 0``.

Per-turn stopping: an engine-level ``eos_id`` (or per-turn override) ends a
turn, and each turn may carry ``stop`` token sequences — matched on the
host against the sampled tail; a matched suffix is trimmed from the turn's
public ``tokens`` (the raw ``sampled`` list keeps it, because those tokens
live in the KV cache and in the next turn's history). ``on_token(uid,
token)`` streams every sampled token as it is produced.

Admission is a **chunked-prefill state machine** (the serving-layer
counterpart of the compiled decode path): each admission/extend prompt is
split into fixed-size chunks (``cfg.serving.prefill_chunk``), the first
chunk prefills into the slot and the remaining chunks stream through the
delta-forward path (``model.extend_slot``), with ONE batched decode step
interleaved between chunks — so live decode slots never stall longer than
one chunk forward plus (in the default ``chunk_state="rebuild"`` mode) one
end-of-admission policy build, instead of the entire long-prompt prefill.
Token-budget contract: a MULTI-chunk admission contributes at most one
``prefill_chunk``-token chunk (or its deferred policy build) per engine
iteration, FIFO across in-flight admissions, alongside one batched decode
step (``B`` tokens); single-chunk admissions and turn transitions — each
itself at most one chunk of work — run to completion at admission time,
exactly like the pre-chunking engine, so a burst of K simultaneous short
arrivals still costs K (bounded) chunk forwards before the next decode
step. Slots therefore have three phases: idle, *prefilling* (an
``_AdmitJob`` feeds chunks), decoding.
Interleaved decode steps carry an active-slot mask: a mid-admission slot's
``t``/policy-state side effects are discarded (``model.mask_step_slots``)
and its single garbage KV row is overwritten by the next chunk append.
Architectures without an extend path (``model.can_extend`` False: SSM
hybrids, MoE FFN, enc-dec/VLM) fall back to monolithic admission exactly
as before. Prompts and deltas are padded to power-of-two length buckets
with a valid-length mask (``n_tokens``), so admission and ``generate``
compile O(log max_len) shapes instead of one per distinct prompt length.

Scheduler contract (who owns what):

* the scheduler owns WHICH session runs in which slot and when (FIFO order,
  arrival gating, lifecycle timestamps); it never touches device state;
* the engine owns the device state, turn transitions, and the admission
  *policy*: continuous mode admits into any free slot, static mode only
  admits when all slots are drained (the lock-step baseline measured by
  ``benchmarks/throughput.py``);
* greedy outputs per session are independent of co-scheduled sessions
  (decode is per-slot vmapped; prefill/extend are per-session at natural
  length), so continuous and static modes produce bit-identical greedy
  tokens — the invariant the throughput benchmark checks.

``serve_step`` is the pure function the decode dry-run shapes
(``decode_32k`` / ``long_500k``) lower: one new token against a seq_len KV
cache, including hierarchical retrieval, budgeted sparse attention and the
lazy index update. It stays jit-donated — the engine reuses the state
buffers in place every step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.paging import copy_page_rows, resolve_page_spec
from repro.core.policy import policy_for
from repro.core.types import usable_rows
from repro.models import model as MD
from repro.serving.metrics import EngineMetrics
from repro.serving.pagepool import PagePool, PoolStats
from repro.serving.sampler import (SamplerParams, sample, slot_keys)
from repro.serving.scheduler import (Scheduler, Session, ShedResult, Turn)


def serve_step(params, token, state, cfg: ModelConfig, budget=None):
    """One decode step (the dry-run entry point). token: (B,) int32.

    ``budget`` (optional (B,) int32, 0 = uncapped) caps each slot's
    retrieved-token budget — the overload-degradation valve (see
    ``MD.decode_step``). ``None`` traces the exact pre-existing step."""
    return MD.decode_step(params, token, state, cfg, budget=budget)


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray            # (B, max_new)
    n_generated: np.ndarray       # (B,)
    prefill_s: float
    decode_s: float
    tpot_ms: float                # time per output token (decode only)


@dataclasses.dataclass
class ServeResult:
    """Aggregate metrics of one trace replay (per-session/turn detail rides
    on the Session objects themselves)."""

    mode: str                     # "continuous" | "static"
    requests: Dict[int, Session]  # uid -> finished session (tokens filled)
    wall_s: float
    decode_s: float               # wall-clock inside lock-step decode only
                                  # (admission prefills + scheduling excluded)
    idle_s: float                 # open-loop wait for the next arrival while
                                  # every slot was empty (excluded from
                                  # tokens_per_s — idle is the trace's, not
                                  # the engine's)
    n_steps: int                  # batched decode steps executed
    total_new_tokens: int
    tokens_per_s: float
    p50_latency_s: float
    p99_latency_s: float
    mean_ttft_s: float
    # streaming smoothness (fed by Turn.token_times_s): mean per-turn TPOT
    # and the p99/max inter-token gap across ALL turns — the gap on a busy
    # slot while a long prompt admits is the stall the chunked-prefill
    # state machine bounds (benchmarks/interference.py).
    mean_tpot_ms: float = 0.0
    p99_itl_ms: float = 0.0
    max_itl_ms: float = 0.0
    # paged-pool observability (None on the contiguous layout): pages
    # allocated/free/shared, prefix-cache hit rates and bytes saved by
    # cross-request page sharing — serving.pagepool.PoolStats
    pool: Optional[PoolStats] = None
    # SLO/overload outcomes (empty without an SLO policy): sessions the
    # overload controller explicitly rejected, and sessions cancelled
    # mid-flight — disjoint from ``requests`` (finished sessions only)
    shed: Dict[int, ShedResult] = dataclasses.field(default_factory=dict)
    cancelled: Dict[int, Session] = dataclasses.field(default_factory=dict)
    # scheduling + latency observability: counters (admissions, deferrals,
    # preemptions, sheds, budget-degrade events) and TTFT/TPOT/ITL/queue-
    # depth histograms — serving.metrics.EngineMetrics
    metrics: Optional[EngineMetrics] = None


@dataclasses.dataclass
class _AdmitJob:
    """Host-side record of one in-flight chunked admission (a slot in the
    "prefilling" phase). ``tokens`` is the FULL stream this admission must
    feed (turn-0 prompt, extend delta led by the previous turn's final
    sampled token, or the re-prefill history); ``pos`` counts fed tokens."""

    slot: int
    sess: Session
    tokens: np.ndarray
    fresh: bool                   # True -> first piece overwrites the slot
    base_t: int                   # slot length before this job (0 if fresh)
    seq: int                      # admission order (FIFO chunk scheduling)
    pos: int = 0
    multi: bool = False           # >1 piece (rebuild mode defers the build)
    logits: object = None         # last piece's (1, V) logits


class Engine:
    """Batched inference engine over the pure model functions."""

    def __init__(self, cfg: ModelConfig, params, *, n_cache: int,
                 eos_id: Optional[int] = None, donate_state: bool = True,
                 policy: Optional[str] = None):
        """``policy`` overrides the cache-management policy of
        ``cfg.lychee`` (a name from the ``core.policy`` registry); ``None``
        keeps the config's own selection."""
        if policy is not None:
            cfg = cfg.replace(lychee=cfg.lychee.replace(
                policy=policy, enabled=policy != "dense"))
        self.cfg = cfg
        self.params = params
        self.n_cache = n_cache
        # the tail cache_slack rows are the Pallas kernel's DMA-overrun
        # region (core.types): requests may only fill the usable prefix
        self.usable = usable_rows(n_cache, cfg.lychee)
        self.eos_id = eos_id
        self.policy = policy_for(cfg.lychee).name
        # multi-turn KV/index reuse needs an extend path through every
        # decode block; SSM hybrids fall back to re-prefilling the history
        self.can_extend = MD.can_extend(cfg)
        # the same block property makes right-padded (masked) prefills
        # exact, which is what prompt-length bucketing and chunked
        # admission ride on
        self.can_pad = self.can_extend
        # stateless policies (dense, streaming) have nothing to rebuild —
        # their chunked admissions skip the deferred-build leg entirely
        self.policy_stateful = policy_for(cfg.lychee).stateful
        sv = cfg.serving
        self.prefill_chunk = int(sv.prefill_chunk)
        self.chunk_state = sv.chunk_state
        assert self.chunk_state in ("rebuild", "stream"), self.chunk_state
        self.chunked = self.prefill_chunk > 0 and self.can_extend
        # paged KV pool: one global refcounted page pool + per-slot page
        # tables instead of n_slots private contiguous caches. Silent
        # fallback to contiguous on unsupported archs / the dense policy
        # (model.can_page) — greedy outputs are identical either way.
        self.paged = bool(sv.paged) and MD.can_page(cfg)
        self.page_tokens = 0
        if self.paged:
            # pin the RESOLVED page size into cfg before any jit closes
            # over it: decode_step reconstructs the PageSpec from it
            spec1 = resolve_page_spec(n_cache, cfg.lychee,
                                      page_tokens=sv.page_tokens,
                                      n_slots=1)
            self.page_tokens = spec1.page_tokens
            cfg = cfg.replace(serving=sv.replace(
                page_tokens=spec1.page_tokens))
            self.cfg = cfg
        # debug counters (reset per serve): host-side eager samples should
        # number one per TURN (prefill/extend logits), never per token
        self.last_host_samples = 0
        # eval_shape of the all-slots-empty state, cached per n_slots so
        # repeated serve() calls on one Engine skip the re-trace
        self._zero_shapes: Dict[int, object] = {}

        donate = (2,) if donate_state else ()
        donate3 = (3,) if donate_state else ()
        self._prefill_nat = jax.jit(
            lambda p, tk, extras: MD.prefill(p, tk, cfg, n_cache,
                                             extras=extras))
        self._step = jax.jit(
            lambda p, tok, st: serve_step(p, tok, st, cfg),
            donate_argnums=donate)

        def _greedy_step(p, tok, st):
            # greedy decode fuses the argmax into the jitted step: one
            # dispatch and one (B,)-int host transfer per token instead of
            # step + eager argmax over the (B, V) logits
            logits, ns = serve_step(p, tok, st, cfg)
            return jnp.argmax(logits, -1).astype(jnp.int32), ns

        def _sampled_step(p, tok, st, base, uid, step, temp, top_k, top_p):
            # fully fused per-slot sampling: logits never leave the device,
            # each slot's key is fold_in(fold_in(base, uid), step) — a pure
            # function of (seed, request, request-local counter), so co-
            # scheduling cannot perturb sampled outputs
            logits, ns = serve_step(p, tok, st, cfg)
            keys = slot_keys(base, uid, step)
            return sample(keys, logits, temp, top_k, top_p), ns

        def _greedy_step_masked(p, tok, st, keep):
            # the chunk-interleaved variant: slots mid-admission (and idle
            # slots) discard the step's t/policy-state side effects
            logits, ns = serve_step(p, tok, st, cfg)
            ns = MD.mask_step_slots(st, ns, keep)
            return jnp.argmax(logits, -1).astype(jnp.int32), ns

        def _sampled_step_masked(p, tok, st, keep, base, uid, step, temp,
                                 top_k, top_p):
            logits, ns = serve_step(p, tok, st, cfg)
            ns = MD.mask_step_slots(st, ns, keep)
            keys = slot_keys(base, uid, step)
            return sample(keys, logits, temp, top_k, top_p), ns

        # degraded-step family: the same four steps with a (B,) per-slot
        # retrieval-budget cap threaded into the fused decode (the SLO
        # overload valve). Separate jits so the uncapped hot path keeps its
        # exact pre-existing trace; only used while some slot is degraded.
        def _greedy_step_d(p, tok, st, cap):
            logits, ns = serve_step(p, tok, st, cfg, budget=cap)
            return jnp.argmax(logits, -1).astype(jnp.int32), ns

        def _sampled_step_d(p, tok, st, cap, base, uid, step, temp, top_k,
                            top_p):
            logits, ns = serve_step(p, tok, st, cfg, budget=cap)
            keys = slot_keys(base, uid, step)
            return sample(keys, logits, temp, top_k, top_p), ns

        def _greedy_step_md(p, tok, st, keep, cap):
            logits, ns = serve_step(p, tok, st, cfg, budget=cap)
            ns = MD.mask_step_slots(st, ns, keep)
            return jnp.argmax(logits, -1).astype(jnp.int32), ns

        def _sampled_step_md(p, tok, st, keep, cap, base, uid, step, temp,
                             top_k, top_p):
            logits, ns = serve_step(p, tok, st, cfg, budget=cap)
            ns = MD.mask_step_slots(st, ns, keep)
            keys = slot_keys(base, uid, step)
            return sample(keys, logits, temp, top_k, top_p), ns

        self._step_greedy = jax.jit(_greedy_step, donate_argnums=donate)
        self._step_sampled = jax.jit(_sampled_step, donate_argnums=donate)
        self._step_greedy_m = jax.jit(_greedy_step_masked,
                                      donate_argnums=donate)
        self._step_sampled_m = jax.jit(_sampled_step_masked,
                                       donate_argnums=donate)
        self._step_greedy_d = jax.jit(_greedy_step_d, donate_argnums=donate)
        self._step_sampled_d = jax.jit(_sampled_step_d,
                                       donate_argnums=donate)
        self._step_greedy_md = jax.jit(_greedy_step_md,
                                       donate_argnums=donate)
        self._step_sampled_md = jax.jit(_sampled_step_md,
                                        donate_argnums=donate)
        self._prefill_slot = jax.jit(
            lambda p, tk, st, slot: MD.prefill_into_slot(
                p, tk, cfg, n_cache, st, slot),
            donate_argnums=donate)
        self._extend_slot = jax.jit(
            lambda p, tk, st, slot: MD.extend_slot(p, tk, cfg, st, slot),
            donate_argnums=donate)
        if self.can_pad:
            # bucketed (valid-length-masked) admission family: one compile
            # per pad bucket, not per distinct prompt length
            self._prefill = jax.jit(
                lambda p, tk, n, extras: MD.prefill(
                    p, tk, cfg, n_cache, extras=extras, n_tokens=n))
            self._prefill_slot_b = jax.jit(
                lambda p, tk, n, st, slot: MD.prefill_into_slot(
                    p, tk, cfg, n_cache, st, slot, n_tokens=n),
                donate_argnums=donate3)
            self._prefill_slot_nb = jax.jit(
                lambda p, tk, n, st, slot: MD.prefill_into_slot(
                    p, tk, cfg, n_cache, st, slot, n_tokens=n,
                    build_policy=False),
                donate_argnums=donate3)
            self._extend_slot_u = jax.jit(
                lambda p, tk, n, st, slot: MD.extend_slot(
                    p, tk, cfg, st, slot, n_tokens=n),
                donate_argnums=donate3)
            self._extend_slot_nu = jax.jit(
                lambda p, tk, n, st, slot: MD.extend_slot(
                    p, tk, cfg, st, slot, n_tokens=n, update_policy=False),
                donate_argnums=donate3)
            self._rebuild_slot = jax.jit(
                lambda p, tk, n, st, slot: MD.rebuild_slot_policy(
                    p, tk, cfg, n_cache, st, slot, n_tokens=n),
                donate_argnums=donate3)
        if self.paged:
            # the paged admission family mirrors the bucketed contiguous
            # one; the PageSpec rides as a static argument (hashable
            # NamedTuple of ints), so one Engine serves any pool size
            donate0 = (0,) if donate_state else ()
            self._p_prefill_slot_b = jax.jit(
                lambda p, tk, n, st, slot, row, spec:
                MD.prefill_into_slot_paged(p, tk, cfg, n_cache, st, slot,
                                           row, spec, n_tokens=n),
                static_argnums=(6,), donate_argnums=donate3)
            self._p_prefill_slot_nb = jax.jit(
                lambda p, tk, n, st, slot, row, spec:
                MD.prefill_into_slot_paged(p, tk, cfg, n_cache, st, slot,
                                           row, spec, n_tokens=n,
                                           build_policy=False),
                static_argnums=(6,), donate_argnums=donate3)
            self._p_extend_slot_u = jax.jit(
                lambda p, tk, n, st, slot, spec: MD.extend_slot_paged(
                    p, tk, cfg, st, slot, spec, n_tokens=n),
                static_argnums=(5,), donate_argnums=donate3)
            self._p_extend_slot_nu = jax.jit(
                lambda p, tk, n, st, slot, spec: MD.extend_slot_paged(
                    p, tk, cfg, st, slot, spec, n_tokens=n,
                    update_policy=False),
                static_argnums=(5,), donate_argnums=donate3)
            self._p_rebuild_slot = jax.jit(
                lambda p, tk, n, st, slot, spec:
                MD.rebuild_slot_policy_paged(p, tk, cfg, n_cache, st, slot,
                                             spec, n_tokens=n),
                static_argnums=(5,), donate_argnums=donate3)
            # prefix-cache machinery: snapshot a slot's residual state
            # (NOT donating — the snapshot outlives the state buffers),
            # splice a snapshot into a new slot (full hit keeps it
            # verbatim; partial truncates through CachePolicy.
            # splice_prefix), page copies and the finish-time table reset
            self._p_slice_slot = jax.jit(MD.slice_slot_paged)
            self._p_splice_full = jax.jit(
                lambda st, sub, slot, row: MD.write_slot_paged(
                    st, dict(sub, page_tbl=row[None]), slot),
                donate_argnums=donate0)
            self._p_splice_part = jax.jit(
                lambda st, sub, slot, row, keep: MD.write_slot_paged(
                    st, dict(MD.splice_sub_prefix(sub, cfg, keep),
                             page_tbl=row[None]), slot),
                donate_argnums=donate0)
            self._p_copy_pages = jax.jit(
                MD.copy_pool_pages, donate_argnums=donate0)
            self._p_reset_tbl = jax.jit(
                MD.reset_tbl_row, static_argnums=(2,),
                donate_argnums=donate0)

    def _pad_shape(self, n: int, cap: int) -> int:
        """Power-of-two pad bucket for a valid length ``n``, clamped to
        ``cap`` (so pad rows never spill into the reserved cache tail)."""
        n = int(n)
        if not self.cfg.serving.bucket_prompts:
            return n
        b = max(int(self.cfg.serving.min_bucket),
                1 << max(0, n - 1).bit_length())
        return max(n, min(b, int(cap)))

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new: int,
                 sampler: SamplerParams = SamplerParams(),
                 extras: Optional[dict] = None, seed: int = 0
                 ) -> GenerateResult:
        """prompts: (B, S) int32 (right-padded prompts share one layout)."""
        B, S = prompts.shape
        assert S + max_new <= self.usable, \
            "cache too small (tail cache_slack rows are reserved)"
        extras = extras or {}
        base = jax.random.key(seed)
        uid_a = jnp.arange(B, dtype=jnp.int32)
        temp = jnp.full((B,), sampler.temperature, jnp.float32)
        top_k = jnp.full((B,), sampler.top_k, jnp.int32)
        top_p = jnp.full((B,), sampler.top_p, jnp.float32)

        t0 = time.perf_counter()
        if self.can_pad:
            # pow2 prompt-length bucketing: pad + n_tokens mask, one jit
            # trace per bucket instead of one per distinct prompt length
            Sp = self._pad_shape(S, self.usable)
            padded = np.zeros((B, Sp), np.int32)
            padded[:, :S] = prompts
            logits, state = self._prefill(self.params, jnp.asarray(padded),
                                          jnp.int32(S), extras)
        else:
            logits, state = self._prefill_nat(self.params,
                                              jnp.asarray(prompts), extras)
        logits.block_until_ready()
        t1 = time.perf_counter()

        pad = self.eos_id if self.eos_id is not None else 0
        greedy = sampler.temperature <= 0.0
        # pre-fill with the pad token: an early break (every row done) must
        # leave the unreached columns padded, not zero
        out = np.full((B, max_new), pad, np.int32)
        done = np.zeros((B,), bool)
        ngen = np.zeros((B,), np.int64)
        tok = sample(slot_keys(base, uid_a, jnp.zeros((B,), jnp.int32)),
                     logits, temp, top_k, top_p)
        for i in range(max_new):
            # finished slots keep decoding lock-step, but their sampled
            # tokens are garbage — pad them so ``tokens`` is trustworthy
            tok_np = np.asarray(tok)
            out[:, i] = np.where(done, pad, tok_np)
            ngen[~done] += 1
            if self.eos_id is not None:
                done |= tok_np == self.eos_id
                if done.all():
                    break
            if greedy:
                tok, state = self._step_greedy(self.params, tok, state)
            else:
                # one fused dispatch per token: row r of step i+1 samples
                # with key fold_in(fold_in(base, r), i + 1)
                tok, state = self._step_sampled(
                    self.params, tok, state, base, uid_a,
                    jnp.full((B,), i + 1, jnp.int32), temp, top_k, top_p)
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        n_steps = int(ngen.max()) or 1
        return GenerateResult(tokens=out, n_generated=ngen,
                              prefill_s=t1 - t0, decode_s=t2 - t1,
                              tpot_ms=1e3 * (t2 - t1) / n_steps)

    # ------------------------------------------------------------------
    # Continuous batching over sessions
    # ------------------------------------------------------------------
    def _zero_state(self, n_slots: int):
        """All-slots-empty decode state (valid: every mask False, t=0).
        The ``eval_shape`` trace is cached per ``n_slots``, so repeated
        ``serve()`` calls on one Engine only re-allocate the zero buffers
        (they must be fresh — the decode step donates them)."""
        shapes = self._zero_shapes.get(n_slots)
        if shapes is None:
            dummy = jax.ShapeDtypeStruct(
                (n_slots, max(8, self.cfg.lychee.min_chunk)), jnp.int32)
            shapes = jax.eval_shape(
                lambda p, tk: MD.prefill(p, tk, self.cfg, self.n_cache)[1],
                self.params, dummy)
            self._zero_shapes[n_slots] = shapes
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def _zero_state_paged(self, n_slots: int, spec):
        """Paged all-slots-empty state: the contiguous eval_shape with the
        per-slot K/V rows swapped for the shared pools, plus the page
        table — initialised to the DUMP page (a zero table would alias
        physical page 0; see core.paging)."""
        key = (n_slots, spec)
        shapes = self._zero_shapes.get(key)
        if shapes is None:
            dummy = jax.ShapeDtypeStruct(
                (n_slots, max(8, self.cfg.lychee.min_chunk)), jnp.int32)
            cont = jax.eval_shape(
                lambda p, tk: MD.prefill(p, tk, self.cfg, self.n_cache)[1],
                self.params, dummy)
            shapes = MD.paged_state_struct(cont, spec)
            self._zero_shapes[key] = shapes
        state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        state["page_tbl"] = jnp.full((n_slots, spec.max_pages),
                                     spec.dump_page, jnp.int32)
        return state

    @staticmethod
    def _bytes_per_page(state, spec) -> int:
        """Device bytes one physical page costs across every layer's pool
        leaves (the unit of the sharing/bytes-saved accounting)."""
        total = 0
        for c in state["groups"]:
            if isinstance(c, dict):
                for k in ("pool_k", "pool_v", "pool_latent"):
                    if k in c:
                        leaf = c[k]
                        total += (leaf.size // spec.pool_rows) \
                            * spec.page_rows * leaf.dtype.itemsize
        return total

    def serve(self, requests: Sequence[Session], *, n_slots: int,
              mode: str = "continuous",
              sampler: SamplerParams = SamplerParams(),
              seed: int = 0, verbose: bool = False,
              on_token: Optional[Callable[[int, int], None]] = None,
              reuse: str = "extend", slo=None) -> ServeResult:
        """Replay a session trace through the slot scheduler.

        mode="continuous": a freed slot immediately admits the next pending
        session (prefill splice) while other slots keep decoding.
        mode="static": admission only when ALL slots are free — lock-step
        waves, the static-batching baseline.

        ``sampler`` is the default for turns without their own
        :class:`SamplerParams`; ``seed`` anchors the per-request RNG
        (fold_in(fold_in(key(seed), uid), step)). ``on_token(uid, token)``
        is invoked for every sampled token as it is produced (streaming).
        ``reuse`` picks the multi-turn admission primitive: "extend"
        (default) appends each later turn's delta onto the slot's live KV
        rows and index via ``model.extend_slot`` — automatic fallback to
        re-prefill on architectures without an extend path — while
        "reprefill" always rebuilds from the concatenated history (the
        baseline ``benchmarks/session_reuse.py`` compares against).

        ``cfg.serving.slo`` (see :class:`~repro.configs.base.SLOConfig`)
        turns on SLO-aware scheduling: deadline-ordered admission by
        (priority, arrival + TTFT target), bounded queues, cooperative
        cancellation and the staged overload ladder (budget degradation →
        admission preemption → load shedding) — see :class:`_ServeLoop`.

        Session objects are mutated in place (lifecycle timestamps +
        generated tokens); pass fresh copies to compare modes. Greedy
        outputs per session are identical across modes, across ``reuse``
        choices (up to policy-state graft scheduling) and to ``generate``
        of the session alone; sampled outputs are identical across
        co-scheduling/admission permutations (see module docstring).
        """
        loop = self.serve_loop(requests, n_slots=n_slots, mode=mode,
                               sampler=sampler, seed=seed, verbose=verbose,
                               on_token=on_token, reuse=reuse, slo=slo)
        loop.run()
        return loop.result()

    def serve_loop(self, requests: Sequence[Session], *, n_slots: int,
                   mode: str = "continuous",
                   sampler: SamplerParams = SamplerParams(),
                   seed: int = 0, verbose: bool = False,
                   on_token: Optional[Callable[[int, int], None]] = None,
                   reuse: str = "extend", clock=None,
                   slo=None) -> "_ServeLoop":
        """Build the step-driven serve loop WITHOUT running it — the
        journey-fuzzing entry point: the harness interleaves ``step()``
        with mid-run ``submit()``/``Session.cancel()`` and checks engine
        invariants between steps, under an injectable virtual ``clock``
        (deterministic replay of randomized schedules). ``slo`` overrides
        ``cfg.serving.slo`` for THIS loop only (the oracle replay runs
        SLO-free on the same engine, reusing its jit caches). ``serve``
        is exactly ``serve_loop(...).run()`` + ``result()``."""
        return _ServeLoop(self, requests, n_slots=n_slots, mode=mode,
                          sampler=sampler, seed=seed, verbose=verbose,
                          on_token=on_token, reuse=reuse, clock=clock,
                          slo=slo)


class _RealClock:
    """Wall-clock time source (the serve default). The journey harness
    swaps in a virtual clock (``now_s``/``sleep``) so randomized schedules
    replay deterministically and idle waits cost nothing."""

    now_s = staticmethod(time.perf_counter)
    sleep = staticmethod(time.sleep)


class _ServeLoop:
    """One ``Engine.serve`` invocation as an explicit, step-driven object.

    Every iteration of the old monolithic serve loop is one ``step()``:

    1. honor cooperative cancellations (queued, mid-prefill at a chunk
       boundary, mid-decode) — slot, policy state and paged-pool refs are
       reclaimed immediately;
    2. SLO overload control (``cfg.serving.slo``): enforce the queue
       bound, then — when overloaded (deep queue / low free pages /
       projected head TTFT past target) — walk the degradation ladder:
       stage 1 shrinks the retrieval budget of non-premium ACTIVE slots
       (opt-in: trades bit-exactness, recorded on ``Turn.degraded``),
       stage 2 preempts fresh lower-priority chunked admissions in favor
       of a higher-priority arrival (chunk-boundary yield; no emitted
       token is ever lost), stage 3 sheds queued sessions whose projected
       TTFT is hopeless (``ShedResult``, exactly once, never priority 0);
    3. admission phase: bind arrivals to free slots — FIFO, or
       deadline-ordered under the SLO policy;
    4. one bounded admission chunk (the chunked-prefill state machine);
    5. one lock-step decode over the live slots (the degraded-budget jit
       variants run ONLY while some slot is capped, so the unloaded hot
       path keeps its exact pre-existing trace).

    The loop's clock is injectable: the journey fuzzer drives a virtual
    clock, submits sessions mid-run and asserts engine invariants between
    steps (``serving.journeys``)."""

    def __init__(self, eng: Engine, requests: Sequence[Session], *,
                 n_slots: int, mode: str = "continuous",
                 sampler: SamplerParams = SamplerParams(), seed: int = 0,
                 verbose: bool = False,
                 on_token: Optional[Callable[[int, int], None]] = None,
                 reuse: str = "extend", clock=None, slo=None):
        assert mode in ("continuous", "static"), mode
        assert reuse in ("extend", "reprefill"), reuse
        assert not (eng.cfg.is_encdec or eng.cfg.n_patches), \
            "streaming admission serves text-only requests"
        self.eng = eng
        self.n_slots = n_slots
        self.mode = mode
        self.sampler = sampler
        self.verbose = verbose
        self.on_token = on_token
        self.clock = clock if clock is not None else _RealClock()
        for s in requests:
            self._check_session(s)
        self.use_extend = reuse == "extend" and eng.can_extend

        slo = slo if slo is not None else eng.cfg.serving.slo
        self.slo = slo
        self.sched = Scheduler(
            n_slots,
            max_pending=slo.max_pending if slo.enabled else 0,
            order="slo" if slo.enabled else "fifo",
            default_ttft_s=slo.ttft_target_s if slo.enabled else 0.0)
        self.metrics = EngineMetrics()
        self.sched.on_shed = self._on_shed
        self.sched.submit_all(requests)
        self.spec = None
        self.pool: Optional[PagePool] = None
        self.slot_pages = [[] for _ in range(n_slots)]  # refs slot holds
        self.slot_rows = [None] * n_slots               # (max_pages,) rows
        if eng.paged:
            self.spec = resolve_page_spec(
                eng.n_cache, eng.cfg.lychee,
                page_tokens=eng.page_tokens,
                pool_pages=eng.cfg.serving.pool_pages, n_slots=n_slots)
            self.state = eng._zero_state_paged(n_slots, self.spec)
            self.pool = PagePool(
                self.spec,
                bytes_per_page=eng._bytes_per_page(self.state, self.spec),
                prefix_cache=eng.cfg.serving.prefix_cache)
        else:
            self.state = eng._zero_state(n_slots)
        self.base = jax.random.key(seed)
        self.cur = np.zeros((n_slots,), np.int32)
        self.active = np.zeros((n_slots,), bool)
        self.remaining = np.zeros((n_slots,), np.int64)
        self.uid = np.zeros((n_slots,), np.int32)
        self.stepc = np.zeros((n_slots,), np.int32)  # per-session samples
        self.temp = np.zeros((n_slots,), np.float32)
        self.top_k = np.zeros((n_slots,), np.int32)
        self.top_p = np.ones((n_slots,), np.float32)
        self.slot_t = np.zeros((n_slots,), np.int64)  # host mirror of t
        self.jobs: Dict[int, _AdmitJob] = {}   # slot -> in-flight admission
        self.job_seq = 0
        # an all-greedy trace keeps the leaner argmax-fused step
        self.all_greedy = sampler.temperature <= 0.0 and all(
            (t.sampling is None or t.sampling.temperature <= 0.0)
            for s in requests for t in s.turns)
        self.n_steps = 0
        self.decode_s = 0.0
        self.idle_s = 0.0
        eng.last_host_samples = 0
        # static mode keeps its lock-step-wave timing: admissions drain all
        # their chunks back to back (the throughput baseline); continuous
        # mode interleaves one decode step per chunk
        self.interleave = eng.chunked and mode == "continuous"
        # uid/temperature/top-k/top-p only change at turn transitions —
        # cache their device copies so the hot loop uploads just the token
        # vector and the per-slot sample counter each step
        self.slots_dirty = True
        self.dev_slots = None
        # SLO runtime state: per-slot retrieval-budget caps (0 = uncapped;
        # recomputed every step by stage 1), an EMA of turn-0 admission
        # service time (the projected-TTFT estimator; seeded
        # optimistically, corrected by the first real admission) and the
        # current overload verdict
        self._cap = np.zeros((n_slots,), np.int32)
        self.admit_ema = 0.05
        self.overloaded = False
        self._deg_cap_val = 0
        ly = eng.cfg.lychee
        if slo.enabled and slo.degrade_budget and ly.enabled:
            pol = policy_for(ly)
            if not pol.is_dense:
                self._deg_cap_val = max(
                    int(pol.span_len),
                    int(ly.budget * slo.min_budget_frac))
        self.t0 = self.clock.now_s()

    # -- plumbing ----------------------------------------------------------
    def _check_session(self, s: Session) -> None:
        assert s.total_len() <= self.eng.usable, \
            f"session {s.uid}: cache too small (tail cache_slack " \
            f"reserved; total prompt+gen across turns must fit)"
        assert all(t.max_new >= 1 for t in s.turns), \
            f"session {s.uid}: every turn generates at least one " \
            f"token (its first sample IS its generation; max_new=0 " \
            f"would emit a token the total_len() guard never counted)"

    def _on_shed(self, sess: Session, res) -> None:
        if res.reason == "queue_overflow":
            self.metrics.queue_overflow += 1
        if self.verbose:
            print(f"[serve:{self.mode}] t={res.at_s:7.3f}s SHED "
                  f"sess{sess.uid} prio={sess.priority} ({res.reason}, "
                  f"depth={res.queue_depth}, "
                  f"proj_ttft={res.projected_ttft_s:.3f}s)")

    def now(self) -> float:
        return self.clock.now_s() - self.t0

    @property
    def done(self) -> bool:
        return self.sched.all_done

    def submit(self, sess: Session, now_s: Optional[float] = None) -> bool:
        """Mid-run submission (how the journey harness feeds the loop).
        Returns False iff the session itself was shed by the queue bound.
        """
        self._check_session(sess)
        ok = self.sched.submit(
            sess, now_s=self.now() if now_s is None else now_s)
        if ok and self.all_greedy:
            for t in sess.turns:
                sp = t.sampling if t.sampling is not None else self.sampler
                if sp.temperature > 0.0:
                    self.all_greedy = False
                    break
        return ok

    def _n_pieces(self, total: int) -> int:
        if not self.eng.chunked:
            return 1
        return -(-total // self.eng.prefill_chunk)

    def _release_slot_pages(self, slot: int) -> None:
        """Paged slot teardown, shared by finish/cancel/preempt: reset the
        table row to the dump page BEFORE freeing — the freed pages may be
        re-allocated immediately, and an inactive lock-stepped slot keeps
        appending garbage rows through its table every decode step."""
        if not self.eng.paged:
            return
        self.state = self.eng._p_reset_tbl(self.state, jnp.int32(slot),
                                           self.spec)
        self.pool.decref(self.slot_pages[slot])
        self.slot_pages[slot] = []
        self.slot_rows[slot] = None

    # -- turn / admission machinery (one method per old closure) ----------
    def _setup_turn(self, slot: int, sess: Session) -> Turn:
        """Per-turn slot bookkeeping shared by every admission path
        (jobs and the zero-forward prefix-hit splice)."""
        self.slots_dirty = True
        turn = sess.turns[sess.cur]
        turn.started_s = self.now()
        self.remaining[slot] = turn.max_new
        sp = turn.sampling if turn.sampling is not None else self.sampler
        self.temp[slot] = sp.temperature
        self.top_k[slot] = sp.top_k
        self.top_p[slot] = sp.top_p
        return turn

    def _begin_job(self, slot: int, sess: Session, toks=None, fresh=None,
                   base_t=None) -> None:
        """Create this turn's admission job. Turn 0 (and the re-prefill
        fallback) is ``fresh`` — its first piece overwrites the slot;
        extend turns feed their delta (led by the previous turn's final
        sampled token — it was never fed back, so its KV row is still
        absent) onto the slot's live rows. ``toks``/``fresh``/``base_t``
        override the defaults for the prefix-cache partial-hit path
        (the suffix streams onto the spliced prefix)."""
        turn = self._setup_turn(slot, sess)
        if toks is None:
            if sess.cur == 0:
                toks, fresh = np.asarray(turn.prompt, np.int32), True
            elif self.use_extend:
                prev = sess.turns[sess.cur - 1]
                toks = np.concatenate([
                    np.asarray(prev.sampled[-1:], np.int32),
                    np.asarray(turn.prompt, np.int32)])
                fresh = False
            else:
                toks, fresh = sess.history_tokens(sess.cur), True
        self.active[slot] = False
        self.jobs[slot] = _AdmitJob(
            slot=slot, sess=sess, tokens=toks, fresh=fresh,
            base_t=(0 if fresh else int(self.slot_t[slot]))
            if base_t is None else base_t, seq=self.job_seq,
            multi=self._n_pieces(len(toks)) > 1)
        self.job_seq += 1
        if self.verbose:
            kind = ("admit" if sess.cur == 0 else
                    "extend" if self.use_extend else "reprefill")
            how = (f"{self._n_pieces(len(toks))}"
                   f"x{self.eng.prefill_chunk}-chunked"
                   if self._n_pieces(len(toks)) > 1 else "monolithic")
            print(f"[serve:{self.mode}] t={self.now():7.3f}s {kind} "
                  f"({how}) sess{sess.uid} turn "
                  f"{sess.cur + 1}/{sess.n_turns} "
                  f"(S={turn.prompt_len}, gen={turn.max_new}) "
                  f"-> slot {slot}")

    def _needs_rebuild(self, job: _AdmitJob) -> bool:
        eng = self.eng
        return job.fresh and job.multi and eng.can_pad and \
            eng.chunk_state == "rebuild" and eng.policy_stateful

    def _rebuild_leg(self, slot: int, job: _AdmitJob) -> None:
        """ONE monolithic CachePolicy.build over the chunk-streamed
        cache rows, at the exact bucket/shape a monolithic admission
        would have used — the monolithic-build oracle, so chunked
        greedy outputs are token-identical to monolithic admission at
        any retrieval budget."""
        eng = self.eng
        total = len(job.tokens)
        Sp = eng._pad_shape(total, eng.usable)
        buf = np.zeros((1, Sp), np.int32)
        buf[0, :total] = job.tokens
        if eng.paged:
            self.state = eng._p_rebuild_slot(
                eng.params, jnp.asarray(buf), jnp.int32(total), self.state,
                jnp.int32(slot), self.spec)
        else:
            self.state = eng._rebuild_slot(
                eng.params, jnp.asarray(buf), jnp.int32(total), self.state,
                jnp.int32(slot))

    def _job_piece(self, slot: int) -> bool:
        """Run ONE bounded unit of the slot's admission per engine
        iteration: a chunk forward, or (rebuild mode) the deferred
        policy build as its own leg — so the worst interleaved stall is
        max(chunk forward, policy build), never their sum. True when
        the admission is complete — ``job.logits`` then holds the
        admission logits of the full prompt."""
        eng = self.eng
        job = self.jobs[slot]
        total = len(job.tokens)
        if job.pos >= total:
            # all chunks fed; the deferred build is its own iteration
            self._rebuild_leg(slot, job)
            return True
        left = total - job.pos
        C = eng.prefill_chunk if eng.chunked else left
        take = min(C, left)
        last = take == left
        piece = job.tokens[job.pos:job.pos + take]
        t_cur = job.base_t + job.pos
        dev_slot = jnp.int32(slot)
        if not eng.can_pad:
            # monolithic natural-length admission (SSM/MoE/enc-dec)
            logits, self.state = eng._prefill_slot(
                eng.params, jnp.asarray(piece[None]), self.state, dev_slot)
        else:
            # full chunks run at the one static chunk shape; the tail
            # (or a short/monolithic prompt) pads to its pow2 bucket,
            # clamped so pad rows never reach the reserved cache tail
            shape = take if (eng.chunked and
                             take == eng.prefill_chunk) else \
                eng._pad_shape(take, eng.usable - t_cur)
            buf = np.zeros((1, shape), np.int32)
            buf[0, :take] = piece
            tk, n = jnp.asarray(buf), jnp.int32(take)
            if eng.paged:
                # paged dispatch: a fresh first piece scatters the
                # prefilled rows through the slot's freshly-planned
                # page-table row; later pieces/extends stream onto the
                # live table
                if job.fresh and job.pos == 0:
                    fn = eng._p_prefill_slot_nb \
                        if self._needs_rebuild(job) \
                        else eng._p_prefill_slot_b
                    logits, self.state = fn(
                        eng.params, tk, n, self.state, dev_slot,
                        jnp.asarray(self.slot_rows[slot]), self.spec)
                else:
                    fn = eng._p_extend_slot_nu \
                        if job.fresh and self._needs_rebuild(job) \
                        else eng._p_extend_slot_u
                    logits, self.state = fn(
                        eng.params, tk, n, self.state, dev_slot, self.spec)
            else:
                if job.fresh and job.pos == 0:
                    fn = eng._prefill_slot_nb if self._needs_rebuild(job) \
                        else eng._prefill_slot_b
                elif job.fresh and self._needs_rebuild(job):
                    fn = eng._extend_slot_nu
                else:
                    fn = eng._extend_slot_u
                logits, self.state = fn(eng.params, tk, n, self.state,
                                        dev_slot)
        job.pos += take
        job.logits = logits
        if not last:
            return False
        if self._needs_rebuild(job):
            if self.interleave:
                return False        # build in its own iteration
            self._rebuild_leg(slot, job)
        return True

    def _register_prefix(self, slot: int, job: _AdmitJob) -> None:
        """Snapshot a freshly-prefilled turn-0 prompt into the prefix
        cache. Safe pages (halo rows complete — see ``core.paging``)
        are shared by reference; the 1-2 unsafe tail pages (the slot
        keeps appending into them) are deep-copied into entry-owned
        pages; the residual per-slot state (policy selection state,
        prelude caches, ``t``) plus the admission logits are stored so
        a later EXACT hit replays the admission with zero forwards."""
        eng, spec, pool = self.eng, self.spec, self.pool
        tokens = np.asarray(job.tokens, np.int32)
        Lc = len(tokens)
        P = spec.page_tokens
        n_cov = -(-Lc // P)
        n_safe = min(max(0, (Lc - spec.slack) // P), n_cov)
        n_copy = n_cov - n_safe
        owned = pool.alloc(n_copy)
        if owned is None:
            return              # pool too tight to snapshot — skip
        if n_copy:
            src_rows, dst_rows = copy_page_rows(
                spec, self.slot_pages[slot][n_safe:n_cov], owned)
            self.state = eng._p_copy_pages(
                self.state, jnp.asarray(src_rows), jnp.asarray(dst_rows))
        shared = self.slot_pages[slot][:n_safe]
        pool.incref(shared)
        sub = eng._p_slice_slot(self.state, jnp.int32(slot))
        pool.register(tokens, shared + owned, n_safe, sub,
                      job.logits, uid=job.sess.uid)

    def _complete_job(self, slot: int) -> None:
        """Admission complete: mark the slot decoding and sample the
        turn's first token from the last chunk's logits."""
        eng = self.eng
        job = self.jobs.pop(slot)
        sess = job.sess
        self.slot_t[slot] = job.base_t + len(job.tokens)
        self.active[slot] = True
        if sess.cur == 0 and sess.admitted_s is not None:
            # turn-0 admission service time feeds the projected-TTFT EMA
            delta = max(0.0, self.now() - sess.admitted_s)
            self.admit_ema = 0.8 * self.admit_ema + 0.2 * delta
        if eng.paged and self.pool.prefix_cache and job.fresh and \
                sess.cur == 0 and job.base_t == 0:
            self._register_prefix(slot, job)
        turn = sess.turns[sess.cur]
        if self._emit(slot, sess, turn,
                      self._first_token(slot, turn, job.logits)):
            self._advance(slot)

    def _run_job(self, slot: int) -> None:
        """Drain the slot's admission (and any follow-up turn jobs its
        completion spawns) without interleaving — the monolithic-timing
        path (static mode / single-piece jobs / chunking disabled). In
        interleave mode a multi-piece job — including one spawned
        mid-drain by an instantly-completing turn — is left to the
        chunk phase, preserving the bounded-stall contract."""
        while slot in self.jobs:
            if self.interleave and self.jobs[slot].multi:
                return
            if self._job_piece(slot):
                self._complete_job(slot)

    def _first_token(self, slot: int, turn: Turn, logits) -> int:
        """Sample this turn's first token from the prefill/extend
        logits (host-side — once per TURN, not per token) with the same
        (uid, step) key the fused loop would use."""
        keys = slot_keys(self.base,
                         jnp.asarray([self.uid[slot]], jnp.int32),
                         jnp.asarray([self.stepc[slot]], jnp.int32))
        tok = int(np.asarray(sample(
            keys, logits, self.temp[slot:slot + 1],
            self.top_k[slot:slot + 1], self.top_p[slot:slot + 1]))[0])
        self.eng.last_host_samples += 1
        self.stepc[slot] += 1
        self.cur[slot] = tok
        return tok

    def _emit(self, slot: int, sess: Session, turn: Turn,
              tok: int) -> bool:
        """Record one sampled token; True when it ends the turn
        (budget, EOS, or a stop-sequence match — the matched suffix is
        trimmed from the public ``tokens`` but stays in ``sampled``:
        those tokens are in the KV cache and the next turn's history).
        """
        now = self.now()
        turn.sampled.append(tok)
        turn.tokens.append(tok)
        turn.token_times_s.append(now)
        if turn.first_token_s is None:
            turn.first_token_s = now
            if sess.cur == 0:
                self.metrics.observe_ttft(now - sess.arrival_s)
        if self.on_token is not None:
            self.on_token(sess.uid, tok)
        self.remaining[slot] -= 1
        eos = turn.eos_id if turn.eos_id is not None else self.eng.eos_id
        done = self.remaining[slot] <= 0 or \
            (eos is not None and tok == eos)
        for seq in turn.stop:
            L = len(seq)
            if L and len(turn.sampled) >= L and \
                    tuple(turn.sampled[-L:]) == tuple(seq):
                del turn.tokens[-L:]
                done = True
                break
        if done:
            turn.finished_s = self.now()
            tp = turn.tpot_ms
            if tp is not None:
                self.metrics.tpot_ms.observe(tp)
            for g in turn.itl_ms:
                self.metrics.itl_ms.observe(g)
        return done

    def _advance(self, slot: int) -> None:
        """Current turn ended: start the next turn in place (the slot —
        and its KV/index — is retained) or retire the session. A next
        turn becomes an admission job; single-piece jobs run to
        completion immediately (the pre-chunking timing), multi-piece
        jobs interleave with decode in continuous mode."""
        sess = self.sched.slot_of(slot)
        sess.cur += 1
        if sess.cur >= sess.n_turns:
            self.sched.finish(slot, self.now())
            self.active[slot] = False
            self.cur[slot] = 0
            self._release_slot_pages(slot)
            if self.verbose:
                ntok = sum(len(t.tokens) for t in sess.turns)
                print(f"[serve:{self.mode}] t={self.now():7.3f}s finish "
                      f"sess{sess.uid} ({ntok} tok, "
                      f"{sess.n_turns} turns)")
            return
        self._begin_job(slot, sess)
        self._run_job(slot)

    def _plan_admission(self, sess: Session):
        """Paged admission planning: reserve every page the session
        will EVER need (all-or-nothing — an admitted session can
        always run to completion, the pool never deadlocks) and
        consult the prefix cache for the first turn's prompt. Under
        page pressure, LRU prefix entries are evicted (the hit being
        spliced is protected); if the pool is still too full the
        admission is DEFERRED — a free slot without free pages waits,
        so concurrency is bounded by pool pages, not slot count.
        Returns None to defer, else (kind, entry, keep, shared,
        copy_src, fresh) where ``shared`` are increfed safe pages of
        the hit, ``copy_src`` its unsafe pages to deep-copy, and
        ``fresh`` this session's own pages."""
        spec, pool = self.spec, self.pool
        P = spec.page_tokens
        total_pages = -(-sess.total_len() // P)
        prompt = np.asarray(sess.turns[0].prompt, np.int32)
        kind, entry, keep = pool.lookup(prompt)
        if kind is not None:
            n_cov = -(-keep // P) if kind == "full" else keep // P
            # the reader may only share pages whose halo rows are
            # complete RELATIVE TO ITS OWN coverage: its first append
            # halo-writes into page keep//P - 1 when keep%P < slack
            n_share = min(entry.n_safe, max(0, (keep - spec.slack) // P))
            copy_src = entry.pages[n_share:n_cov]
        else:
            n_share, copy_src = 0, []
        fresh = pool.alloc(total_pages - n_share)
        while fresh is None and pool.evict_lru(protect=entry):
            fresh = pool.alloc(total_pages - n_share)
        if fresh is None and kind is not None:
            # the protected hit itself may be what keeps the pool
            # full (it can be the last remaining entry): degrade to a
            # miss so IT becomes evictable — a plain reservation
            # always fits an otherwise idle pool (total_pages <=
            # max_pages <= n_pages), so this cannot livelock
            kind, entry, keep, n_share, copy_src = None, None, 0, 0, []
            fresh = pool.alloc(total_pages)
            while fresh is None and pool.evict_lru():
                fresh = pool.alloc(total_pages)
        if fresh is None:
            pool.deferred_admissions += 1
            return None
        shared = entry.pages[:n_share] if n_share else []
        pool.incref(shared)
        return kind, entry, keep, shared, copy_src, fresh

    def _admit_paged(self, slot: int, sess: Session, plan) -> None:
        """Bind a planned paged admission to ``slot``: install the
        page table, deep-copy the hit's unsafe tail pages, splice the
        cached snapshot (full hit: zero forward passes; partial hit:
        truncate via ``CachePolicy.splice_prefix`` then stream only
        the suffix), or fall through to a normal prefill job."""
        eng, spec = self.eng, self.spec
        kind, entry, keep, shared, copy_src, fresh = plan
        pages = shared + fresh
        self.slot_pages[slot] = pages
        row = np.full((spec.max_pages,), spec.dump_page, np.int32)
        row[:len(pages)] = pages
        self.slot_rows[slot] = row
        row_dev = jnp.asarray(row)
        if copy_src:
            src_rows, dst_rows = copy_page_rows(
                spec, copy_src, fresh[:len(copy_src)])
            self.state = eng._p_copy_pages(
                self.state, jnp.asarray(src_rows), jnp.asarray(dst_rows))
        if kind == "full":
            self.state = eng._p_splice_full(
                self.state, entry.sub, jnp.int32(slot), row_dev)
            self.slot_t[slot] = len(sess.turns[0].prompt)
            turn = self._setup_turn(slot, sess)
            self.active[slot] = True
            if self.verbose:
                print(f"[serve:{self.mode}] t={self.now():7.3f}s admit "
                      f"(prefix-cache FULL hit, 0 forwards) "
                      f"sess{sess.uid} -> slot {slot}")
            if self._emit(slot, sess, turn,
                          self._first_token(slot, turn, entry.logits)):
                self._advance(slot)
            return
        if kind == "partial":
            self.state = eng._p_splice_part(
                self.state, entry.sub, jnp.int32(slot), row_dev,
                jnp.int32(keep))
            self.slot_t[slot] = keep
            prompt = np.asarray(sess.turns[0].prompt, np.int32)
            if self.verbose:
                print(f"[serve:{self.mode}] t={self.now():7.3f}s admit "
                      f"(prefix-cache partial hit, keep={keep}) "
                      f"sess{sess.uid} -> slot {slot}")
            self._begin_job(slot, sess, toks=prompt[keep:], fresh=False,
                            base_t=keep)
            self._run_job(slot)
            return
        self._begin_job(slot, sess)
        self._run_job(slot)

    # -- SLO control -------------------------------------------------------
    def _process_cancellations(self, now: float) -> None:
        """Honor ``Session.cancel()`` at this step boundary: queued
        sessions leave the queue; a mid-prefill slot aborts its job at the
        chunk boundary; a mid-decode slot stops emitting — in every case
        the slot, its policy state (masked out of future steps) and its
        paged-pool page refs are reclaimed immediately."""
        sched = self.sched
        for s in [q for q in sched.queued() if q.cancel_requested]:
            sched.cancel_queued(s, now)
            if self.verbose:
                print(f"[serve:{self.mode}] t={now:7.3f}s cancel "
                      f"sess{s.uid} (queued)")
        for slot in range(self.n_slots):
            sess = sched.slot_of(slot)
            if sess is None or not sess.cancel_requested:
                continue
            where = "mid-prefill" if slot in self.jobs else "mid-decode"
            self.jobs.pop(slot, None)
            if sess.cur < sess.n_turns:
                turn = sess.turns[sess.cur]
                if turn.started_s is not None and turn.finished_s is None:
                    turn.finished_s = now
            self.active[slot] = False
            self.cur[slot] = 0
            self.slots_dirty = True
            self._release_slot_pages(slot)
            sched.cancel_active(slot, now)
            if self.verbose:
                print(f"[serve:{self.mode}] t={now:7.3f}s cancel "
                      f"sess{sess.uid} ({where}, slot {slot})")

    def _ttft_target(self, sess: Session) -> float:
        return sess.ttft_target_s if sess.ttft_target_s is not None \
            else self.slo.ttft_target_s

    def _overload_check(self, now: float) -> bool:
        """Overload = deep queue OR paged-pool pressure OR the head's
        projected TTFT already past its target."""
        slo, sched = self.slo, self.sched
        qh = slo.queue_high if slo.queue_high > 0 else 2 * self.n_slots
        if len(sched.arrived(now)) > qh:
            return True
        if self.pool is not None and slo.pool_low_frac > 0.0 and \
                self.pool.pages_free < slo.pool_low_frac * \
                self.spec.n_pages:
            return True
        head = sched.next_ready(now)
        if head is not None:
            target = self._ttft_target(head)
            if target > 0 and \
                    (now - head.arrival_s) + self.admit_ema > target:
                return True
        return False

    def _slo_control(self, now: float) -> None:
        """The staged overload ladder (see class docstring): queue bound,
        stage-3 shedding of hopeless queued sessions, stage-1 retrieval-
        budget degradation of non-premium active slots."""
        slo = self.slo
        if not slo.enabled:
            return
        self.sched.enforce_bound(now)
        over = self._overload_check(now)
        self.overloaded = over
        if over and slo.shed:
            arrived = sorted(self.sched.arrived(now),
                             key=self.sched.slo_key)
            for i, s in enumerate(arrived):
                if s.priority <= 0:
                    continue        # premium is never shed
                target = self._ttft_target(s)
                if target <= 0:
                    continue
                projected = (now - s.arrival_s) + \
                    (i // self.n_slots + 1) * self.admit_ema
                if projected > slo.shed_grace * target:
                    self.sched.shed_queued(
                        s, reason="slo", now_s=now,
                        projected_ttft_s=projected)
        new_cap = np.zeros_like(self._cap)
        if over and self._deg_cap_val:
            for slot in range(self.n_slots):
                sess = self.sched.slot_of(slot)
                if sess is None or not self.active[slot]:
                    continue
                if sess.priority > 0:   # premium is never degraded
                    new_cap[slot] = self._deg_cap_val
        self.metrics.degrade_events += int(
            ((self._cap == 0) & (new_cap > 0)).sum())
        self._cap = new_cap

    def _maybe_preempt(self, now: float) -> None:
        """Stage 2: when overloaded with no free slot, a strictly-higher-
        priority arrival evicts the worst FRESH in-flight admission (a
        turn-0 job that has emitted nothing — its chunks are abandoned at
        the boundary, its pages refunded, and it re-queues keeping its
        arrival time). Sessions with any emitted token are never
        preempted: their KV rows are live state a re-admission would have
        to rebuild."""
        if not (self.slo.enabled and self.slo.preempt and
                self.mode == "continuous" and self.overloaded):
            return
        if not self.jobs or self.sched.free_slots():
            return
        head = self.sched.next_ready(now)
        if head is None:
            return
        cands = [(j.sess.priority, j.seq, s)
                 for s, j in self.jobs.items()
                 if j.sess.cur == 0 and
                 not any(t.sampled for t in j.sess.turns)]
        if not cands:
            return
        pr, _seq, slot = max(cands)
        if head.priority >= pr:
            return
        victim = self.jobs.pop(slot).sess
        self._release_slot_pages(slot)
        self.sched.release(slot)
        victim.cur = 0
        self.slots_dirty = True
        self.metrics.preempted += 1
        if self.verbose:
            print(f"[serve:{self.mode}] t={now:7.3f}s preempt "
                  f"sess{victim.uid} prio={pr} (slot {slot}) for "
                  f"sess{head.uid} prio={head.priority}")

    # -- the loop ----------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: cancellations -> SLO control ->
        admission -> one bounded admission chunk -> one lock-step decode
        (or an idle wait when nothing is live)."""
        eng, sched = self.eng, self.sched
        if sched.all_done:
            return
        now = self.now()
        self._process_cancellations(now)
        self._slo_control(now)
        # ---- admission phase: bind arrivals to free slots --------------
        if self.mode == "continuous" or sched.active == 0:
            self._maybe_preempt(now)
            for slot in sched.free_slots():
                head = sched.next_ready(now)
                if head is None:
                    break
                plan = None
                if eng.paged:
                    plan = self._plan_admission(head)
                    if plan is None:
                        break       # page pressure: defer admission
                sess = sched.admit(slot, now, head)
                sess.cur = 0
                self.uid[slot] = sess.uid
                self.stepc[slot] = 0
                # single-piece jobs prefill + emit their first token
                # right here (the monolithic-timing path); multi-piece
                # jobs are left to the bounded chunk phase
                if eng.paged:
                    self._admit_paged(slot, sess, plan)
                else:
                    self._begin_job(slot, sess)
                    self._run_job(slot)
        # ---- one admission chunk (bounded: <= prefill_chunk toks) ------
        if self.jobs:
            slot = min(self.jobs, key=lambda s: self.jobs[s].seq)
            if self._job_piece(slot):
                self._complete_job(slot)
        self.metrics.observe_depth(sched.pending, sched.active)
        if not self.active.any():
            if not self.jobs and sched.pending:
                # open-loop trace: nothing can happen before the next
                # arrival — sleep until exactly then (no 10 ms busy-poll)
                # and book the wait as trace idleness, not engine time
                wait = (sched.next_arrival_s() or 0.0) - self.now()
                if wait > 0:
                    self.clock.sleep(wait)
                    self.idle_s += wait
            return

        # ---- one lock-step decode over the live slots ------------------
        # (with an in-flight admission the masked step discards the
        # prefilling/idle slots' side effects — see mask_step_slots; with
        # any degraded slot the capped-step variants thread the per-slot
        # retrieval-budget vector)
        stepped = self.active.copy()
        capped = bool(self._cap.any())
        t_step = time.perf_counter()
        cur_d = jnp.asarray(self.cur)
        if self.all_greedy:
            if self.jobs:
                if capped:
                    tok_d, self.state = eng._step_greedy_md(
                        eng.params, cur_d, self.state,
                        jnp.asarray(stepped), jnp.asarray(self._cap))
                else:
                    tok_d, self.state = eng._step_greedy_m(
                        eng.params, cur_d, self.state,
                        jnp.asarray(stepped))
            elif capped:
                tok_d, self.state = eng._step_greedy_d(
                    eng.params, cur_d, self.state, jnp.asarray(self._cap))
            else:
                tok_d, self.state = eng._step_greedy(
                    eng.params, cur_d, self.state)
        else:
            if self.slots_dirty:
                self.dev_slots = (jnp.asarray(self.uid),
                                  jnp.asarray(self.temp),
                                  jnp.asarray(self.top_k),
                                  jnp.asarray(self.top_p))
                self.slots_dirty = False
            d_uid, d_temp, d_top_k, d_top_p = self.dev_slots
            if self.jobs:
                if capped:
                    tok_d, self.state = eng._step_sampled_md(
                        eng.params, cur_d, self.state,
                        jnp.asarray(stepped), jnp.asarray(self._cap),
                        self.base, d_uid, jnp.asarray(self.stepc),
                        d_temp, d_top_k, d_top_p)
                else:
                    tok_d, self.state = eng._step_sampled_m(
                        eng.params, cur_d, self.state,
                        jnp.asarray(stepped), self.base, d_uid,
                        jnp.asarray(self.stepc), d_temp, d_top_k,
                        d_top_p)
            elif capped:
                tok_d, self.state = eng._step_sampled_d(
                    eng.params, cur_d, self.state, jnp.asarray(self._cap),
                    self.base, d_uid, jnp.asarray(self.stepc), d_temp,
                    d_top_k, d_top_p)
            else:
                tok_d, self.state = eng._step_sampled(
                    eng.params, cur_d, self.state, self.base,
                    d_uid, jnp.asarray(self.stepc), d_temp, d_top_k,
                    d_top_p)
        tok = np.asarray(tok_d)
        self.n_steps += 1
        self.decode_s += time.perf_counter() - t_step
        self.slot_t[stepped] += 1     # mirrors the device-side t + 1
        for slot in range(self.n_slots):
            if not stepped[slot]:
                continue
            sess = sched.slot_of(slot)
            turn = sess.turns[sess.cur]
            if capped and self._cap[slot] > 0:
                # this token decoded with a shrunken retrieval budget:
                # record the bit-exactness trade on the turn, visibly
                self.metrics.degraded_steps += 1
                if not turn.degraded:
                    turn.degraded = True
                    self.metrics.degraded_turns += 1
            tk = int(tok[slot])
            self.stepc[slot] += 1
            self.cur[slot] = tk
            if self._emit(slot, sess, turn, tk):
                self._advance(slot)

    def run(self) -> None:
        while not self.sched.all_done:
            self.step()

    def result(self) -> ServeResult:
        """Final accounting (call once, after the loop drains)."""
        sched = self.sched
        jax.block_until_ready(self.state["t"])
        wall = self.now()
        done = sched.finished
        total = sum(len(t.tokens) for s in done.values() for t in s.turns)
        lats = np.asarray([s.latency_s for s in done.values()])
        ttfts = np.asarray([s.ttft_s for s in done.values()
                            if s.ttft_s is not None])
        tpots = [t.tpot_ms for s in done.values() for t in s.turns
                 if t.tpot_ms is not None]
        gaps = [g for s in done.values() for t in s.turns for g in t.itl_ms]
        busy = max(wall - self.idle_s, 1e-9)
        m = self.metrics
        m.admitted = sched.n_admitted
        m.finished = len(done)
        m.cancelled = len(sched.cancelled)
        m.shed = len(sched.shed)
        m.preempted = sched.n_preempted
        if self.pool is not None:
            m.admit_deferred = self.pool.deferred_admissions
        return ServeResult(
            mode=self.mode, requests=done, wall_s=wall,
            decode_s=self.decode_s, idle_s=self.idle_s,
            n_steps=self.n_steps, total_new_tokens=total,
            tokens_per_s=total / busy,
            p50_latency_s=float(np.percentile(lats, 50)) if len(lats)
            else 0.0,
            p99_latency_s=float(np.percentile(lats, 99)) if len(lats)
            else 0.0,
            mean_ttft_s=float(ttfts.mean()) if len(ttfts) else 0.0,
            mean_tpot_ms=float(np.mean(tpots)) if tpots else 0.0,
            p99_itl_ms=float(np.percentile(gaps, 99)) if gaps else 0.0,
            max_itl_ms=float(max(gaps)) if gaps else 0.0,
            pool=self.pool.stats() if self.pool is not None else None,
            shed=dict(sched.shed), cancelled=dict(sched.cancelled),
            metrics=m)
