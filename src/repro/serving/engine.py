"""Serving engine: static batched generate + continuous-batching serve.

Two execution models over the same pure model functions:

* ``generate`` — the classic fixed batch: B prompts of one length prefill
  together, decode proceeds lock-step until every slot finishes. Simple,
  but a finished slot idles until the whole batch drains.
* ``serve`` — **continuous batching**: a :class:`~repro.serving.scheduler.
  Scheduler` feeds a FIFO request trace into ``B`` persistent decode slots.
  When a slot frees, the next request is admitted by a single-sequence
  prefill at its natural length whose KV caches, cache-policy selection
  state, recent-buffer bookkeeping and position counter are spliced into
  that slot (``model.prefill_into_slot``) while the other slots keep
  decoding unperturbed. The per-slot policy state makes this cheap: all
  decode state is per-(layer, batch-element), so admission is one
  ``dynamic_update_slice`` per leaf.

The KV selection strategy of policy-managed layers is pluggable
(:mod:`repro.core.policy`): pass ``policy="lychee" | "quest" | "clusterkv"
| "streaming" | "dense"`` to run any registered :class:`CachePolicy`
through the identical prefill/decode/serve machinery — the apples-to-apples
§5.1 comparison surface (``benchmarks/policy_e2e.py``).

Scheduler contract (who owns what):

* the scheduler owns WHICH request runs in which slot and when (FIFO order,
  arrival gating, lifecycle timestamps); it never touches device state;
* the engine owns the device state and the admission *policy*: continuous
  mode admits into any free slot, static mode only admits when all slots
  are drained (the lock-step baseline measured by
  ``benchmarks/throughput.py``);
* per-request greedy outputs are independent of co-scheduled requests
  (decode is per-slot vmapped; prefill is per-request at natural length),
  so continuous and static modes produce bit-identical greedy tokens —
  the invariant the throughput benchmark checks. (MoE archs route per
  token independently at decode, so this holds there too; capacity drops
  only arise in training-time batched dispatch.)

``serve_step`` is the pure function the decode dry-run shapes
(``decode_32k`` / ``long_500k``) lower: one new token against a seq_len KV
cache, including hierarchical retrieval, budgeted sparse attention and the
lazy index update. It stays jit-donated — the engine reuses the state
buffers in place every step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.policy import policy_for
from repro.core.types import usable_rows
from repro.models import model as MD
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import Request, Scheduler


def serve_step(params, token, state, cfg: ModelConfig):
    """One decode step (the dry-run entry point). token: (B,) int32."""
    return MD.decode_step(params, token, state, cfg)


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray            # (B, max_new)
    n_generated: np.ndarray       # (B,)
    prefill_s: float
    decode_s: float
    tpot_ms: float                # time per output token (decode only)


@dataclasses.dataclass
class ServeResult:
    """Aggregate metrics of one trace replay (per-request detail rides on
    the Request objects themselves)."""

    mode: str                     # "continuous" | "static"
    requests: Dict[int, Request]  # uid -> finished request (tokens filled)
    wall_s: float
    decode_s: float               # wall-clock inside lock-step decode only
                                  # (admission prefills + scheduling excluded)
    n_steps: int                  # batched decode steps executed
    total_new_tokens: int
    tokens_per_s: float
    p50_latency_s: float
    p99_latency_s: float
    mean_ttft_s: float


class Engine:
    """Batched inference engine over the pure model functions."""

    def __init__(self, cfg: ModelConfig, params, *, n_cache: int,
                 eos_id: Optional[int] = None, donate_state: bool = True,
                 policy: Optional[str] = None):
        """``policy`` overrides the cache-management policy of
        ``cfg.lychee`` (a name from the ``core.policy`` registry); ``None``
        keeps the config's own selection."""
        if policy is not None:
            cfg = cfg.replace(lychee=cfg.lychee.replace(
                policy=policy, enabled=policy != "dense"))
        self.cfg = cfg
        self.params = params
        self.n_cache = n_cache
        # the tail cache_slack rows are the Pallas kernel's DMA-overrun
        # region (core.types): requests may only fill the usable prefix
        self.usable = usable_rows(n_cache, cfg.lychee)
        self.eos_id = eos_id
        self.policy = policy_for(cfg.lychee).name

        donate = (2,) if donate_state else ()
        self._prefill = jax.jit(
            lambda p, tk, extras: MD.prefill(p, tk, cfg, n_cache,
                                             extras=extras))
        self._step = jax.jit(
            lambda p, tok, st: serve_step(p, tok, st, cfg),
            donate_argnums=donate)

        def _greedy_step(p, tok, st):
            # greedy decode fuses the argmax into the jitted step: one
            # dispatch and one (B,)-int host transfer per token instead of
            # step + eager argmax over the (B, V) logits
            logits, ns = serve_step(p, tok, st, cfg)
            return jnp.argmax(logits, -1).astype(jnp.int32), ns

        self._step_greedy = jax.jit(_greedy_step, donate_argnums=donate)
        self._prefill_slot = jax.jit(
            lambda p, tk, st, slot: MD.prefill_into_slot(
                p, tk, cfg, n_cache, st, slot),
            donate_argnums=donate)

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new: int,
                 sampler: SamplerConfig = SamplerConfig(),
                 extras: Optional[dict] = None, seed: int = 0
                 ) -> GenerateResult:
        """prompts: (B, S) int32 (right-padded prompts share one layout)."""
        B, S = prompts.shape
        assert S + max_new <= self.usable, \
            "cache too small (tail cache_slack rows are reserved)"
        extras = extras or {}
        key = jax.random.key(seed)

        t0 = time.perf_counter()
        logits, state = self._prefill(self.params, jnp.asarray(prompts),
                                      extras)
        logits.block_until_ready()
        t1 = time.perf_counter()

        pad = self.eos_id if self.eos_id is not None else 0
        greedy = sampler.temperature <= 0.0
        # pre-fill with the pad token: an early break (every row done) must
        # leave the unreached columns padded, not zero
        out = np.full((B, max_new), pad, np.int32)
        done = np.zeros((B,), bool)
        ngen = np.zeros((B,), np.int64)
        tok = sample(key, logits, sampler)
        for i in range(max_new):
            # finished slots keep decoding lock-step, but their sampled
            # tokens are garbage — pad them so ``tokens`` is trustworthy
            tok_np = np.asarray(tok)
            out[:, i] = np.where(done, pad, tok_np)
            ngen[~done] += 1
            if self.eos_id is not None:
                done |= tok_np == self.eos_id
                if done.all():
                    break
            key, sub = jax.random.split(key)
            if greedy:
                tok, state = self._step_greedy(self.params, tok, state)
            else:
                logits, state = self._step(self.params, tok, state)
                tok = sample(sub, logits, sampler)
        jax.block_until_ready(tok)
        t2 = time.perf_counter()
        n_steps = int(ngen.max()) or 1
        return GenerateResult(tokens=out, n_generated=ngen,
                              prefill_s=t1 - t0, decode_s=t2 - t1,
                              tpot_ms=1e3 * (t2 - t1) / n_steps)

    # ------------------------------------------------------------------
    # Continuous batching
    # ------------------------------------------------------------------
    def _zero_state(self, n_slots: int):
        """All-slots-empty decode state (valid: every mask False, t=0)."""
        dummy = jax.ShapeDtypeStruct(
            (n_slots, max(8, self.cfg.lychee.min_chunk)), jnp.int32)
        shapes = jax.eval_shape(
            lambda p, tk: MD.prefill(p, tk, self.cfg, self.n_cache)[1],
            self.params, dummy)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def serve(self, requests: Sequence[Request], *, n_slots: int,
              mode: str = "continuous",
              sampler: SamplerConfig = SamplerConfig(),
              seed: int = 0, verbose: bool = False) -> ServeResult:
        """Replay a request trace through the slot scheduler.

        mode="continuous": a freed slot immediately admits the next pending
        request (prefill splice) while other slots keep decoding.
        mode="static": admission only when ALL slots are free — lock-step
        waves, the static-batching baseline.

        Request objects are mutated in place (lifecycle timestamps +
        generated tokens); pass fresh copies to compare modes. Greedy
        outputs per request are identical across modes and to
        ``generate`` of the request alone (see module docstring).
        """
        assert mode in ("continuous", "static"), mode
        assert not (self.cfg.is_encdec or self.cfg.n_patches), \
            "streaming admission serves text-only requests"
        for r in requests:
            assert r.prompt_len + r.max_new <= self.usable, \
                f"req {r.uid}: cache too small (tail cache_slack reserved)"

        sched = Scheduler(n_slots)
        sched.submit_all(requests)
        state = self._zero_state(n_slots)
        cur = np.zeros((n_slots,), np.int32)
        active = np.zeros((n_slots,), bool)
        remaining = np.zeros((n_slots,), np.int64)
        key = jax.random.key(seed)
        n_steps = 0
        decode_s = 0.0
        t0 = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t0

        def retire(slot: int, req: Request, tok: int) -> bool:
            if remaining[slot] <= 0 or \
                    (self.eos_id is not None and tok == self.eos_id):
                sched.finish(slot, now())
                active[slot] = False
                cur[slot] = 0
                if verbose:
                    print(f"[serve:{mode}] t={now():7.3f}s finish "
                          f"req{req.uid} ({len(req.tokens)} tok)")
                return True
            return False

        while not sched.all_done:
            # ---- admission phase --------------------------------------
            if mode == "continuous" or sched.active == 0:
                for slot in sched.free_slots():
                    if sched.next_ready(now()) is None:
                        break
                    req = sched.admit(slot, now())
                    logits, state = self._prefill_slot(
                        self.params, jnp.asarray(req.prompt[None]), state,
                        jnp.int32(slot))
                    key, sub = jax.random.split(key)
                    tok0 = int(np.asarray(sample(sub, logits, sampler))[0])
                    req.tokens.append(tok0)
                    req.first_token_s = now()
                    cur[slot] = tok0
                    active[slot] = True
                    remaining[slot] = req.max_new - 1
                    if verbose:
                        print(f"[serve:{mode}] t={now():7.3f}s admit "
                              f"req{req.uid} (S={req.prompt_len}, "
                              f"gen={req.max_new}) -> slot {slot}")
                    retire(slot, req, tok0)
            if not active.any():
                if sched.pending:
                    # open-loop trace: head not arrived yet — idle briefly
                    wait = (sched.next_arrival_s() or 0.0) - now()
                    time.sleep(min(max(wait, 0.0), 0.01))
                continue

            # ---- one lock-step decode over the live slots --------------
            t_step = time.perf_counter()
            key, sub = jax.random.split(key)
            if sampler.temperature <= 0.0:
                tok_d, state = self._step_greedy(self.params,
                                                 jnp.asarray(cur), state)
                tok = np.asarray(tok_d)
            else:
                logits, state = self._step(self.params, jnp.asarray(cur),
                                           state)
                tok = np.asarray(sample(sub, logits, sampler))
            n_steps += 1
            decode_s += time.perf_counter() - t_step
            for slot in range(n_slots):
                if not active[slot]:
                    continue
                req = sched.slot_of(slot)
                tk = int(tok[slot])
                req.tokens.append(tk)
                remaining[slot] -= 1
                cur[slot] = tk
                retire(slot, req, tk)

        jax.block_until_ready(state["t"])
        wall = now()
        done = sched.finished
        total = sum(len(r.tokens) for r in done.values())
        lats = np.asarray([r.latency_s for r in done.values()])
        ttfts = np.asarray([r.ttft_s for r in done.values()])
        return ServeResult(
            mode=mode, requests=done, wall_s=wall, decode_s=decode_s,
            n_steps=n_steps, total_new_tokens=total,
            tokens_per_s=total / wall if wall > 0 else 0.0,
            p50_latency_s=float(np.percentile(lats, 50)) if len(lats) else 0.0,
            p99_latency_s=float(np.percentile(lats, 99)) if len(lats) else 0.0,
            mean_ttft_s=float(ttfts.mean()) if len(ttfts) else 0.0)
