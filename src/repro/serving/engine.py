"""Batched serving engine.

The engine serves fixed-capacity batches: requests are packed into ``batch``
slots, right-aligned prompts are prefilled together (padding masked through
the chunk layout's ``n_tokens``), then decode proceeds lock-step with
per-slot completion masks — the standard static-batching TPU serving shape
(continuous batching swaps finished slots between generate() calls).

``serve_step`` is the pure function the decode dry-run shapes
(``decode_32k`` / ``long_500k``) lower: one new token against a seq_len KV
cache, including hierarchical retrieval, budgeted sparse attention and the
lazy index update.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MD
from repro.serving.sampler import SamplerConfig, sample


def serve_step(params, token, state, cfg: ModelConfig):
    """One decode step (the dry-run entry point). token: (B,) int32."""
    return MD.decode_step(params, token, state, cfg)


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray            # (B, max_new)
    n_generated: np.ndarray       # (B,)
    prefill_s: float
    decode_s: float
    tpot_ms: float                # time per output token (decode only)


class Engine:
    """Minimal batched inference engine over the pure model functions."""

    def __init__(self, cfg: ModelConfig, params, *, n_cache: int,
                 eos_id: Optional[int] = None, donate_state: bool = True):
        self.cfg = cfg
        self.params = params
        self.n_cache = n_cache
        self.eos_id = eos_id

        self._prefill = jax.jit(
            lambda p, tk, extras: MD.prefill(p, tk, cfg, n_cache,
                                             extras=extras))
        self._step = jax.jit(
            lambda p, tok, st: serve_step(p, tok, st, cfg),
            donate_argnums=(2,) if donate_state else ())

    # ------------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new: int,
                 sampler: SamplerConfig = SamplerConfig(),
                 extras: Optional[dict] = None, seed: int = 0
                 ) -> GenerateResult:
        """prompts: (B, S) int32 (right-padded prompts share one layout)."""
        B, S = prompts.shape
        assert S + max_new <= self.n_cache, "cache too small"
        extras = extras or {}
        key = jax.random.key(seed)

        t0 = time.perf_counter()
        logits, state = self._prefill(self.params, jnp.asarray(prompts),
                                      extras)
        logits.block_until_ready()
        t1 = time.perf_counter()

        out = np.zeros((B, max_new), np.int32)
        done = np.zeros((B,), bool)
        ngen = np.zeros((B,), np.int64)
        tok = sample(key, logits, sampler)
        for i in range(max_new):
            out[:, i] = np.asarray(tok)
            ngen[~done] += 1
            if self.eos_id is not None:
                done |= np.asarray(tok) == self.eos_id
                if done.all():
                    break
            key, sub = jax.random.split(key)
            logits, state = self._step(self.params, tok, state)
            tok = sample(sub, logits, sampler)
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        n_steps = int(ngen.max()) or 1
        return GenerateResult(tokens=out, n_generated=ngen,
                              prefill_s=t1 - t0, decode_s=t2 - t1,
                              tpot_ms=1e3 * (t2 - t1) / n_steps)
