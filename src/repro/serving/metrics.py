"""Prometheus-style serving metrics: counters, gauges, histograms.

Pure-Python, dependency-free observability for the serving engine.
Every serve loop owns one :class:`EngineMetrics`; the engine bumps
counters as scheduling events happen (admission, deferral, preemption,
shed, budget degradation) and feeds latency observations (TTFT, TPOT,
ITL) into fixed-bucket histograms. The result rides on
``ServeResult.metrics`` and is serialized into every serve-driven
benchmark ``--json`` artifact via :meth:`EngineMetrics.to_dict`, so
overload behaviour is auditable offline alongside pool stats.

The histogram is the classic Prometheus cumulative-bucket shape
(``le`` upper bounds, ``+Inf`` implicit via ``count``), which keeps
percentile estimates mergeable across runs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence


# Default latency buckets (seconds) — log-spaced 1 ms .. 60 s.
_LATENCY_BUCKETS_S = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)
# Queue-depth buckets (sessions).
_DEPTH_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


@dataclasses.dataclass
class Histogram:
    """Fixed-bucket cumulative histogram (Prometheus ``le`` semantics)."""

    buckets: Sequence[float]
    counts: List[int] = dataclasses.field(default_factory=list)
    count: int = 0
    total: float = 0.0
    min: float = math.inf
    max: float = -math.inf

    def __post_init__(self) -> None:
        assert list(self.buckets) == sorted(self.buckets)
        if not self.counts:
            self.counts = [0] * len(self.buckets)

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        self.min = v if v < self.min else self.min
        self.max = v if v > self.max else self.max
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile from cumulative buckets (upper bound
        of the first bucket whose cumulative count covers rank q)."""
        assert 0.0 <= q <= 1.0
        if self.count == 0:
            return 0.0
        rank = q * self.count
        for le, c in zip(self.buckets, self.counts):
            if c >= rank:
                return min(le, self.max)
        return self.max

    def to_dict(self) -> Dict[str, object]:
        return {
            "count": self.count,
            "sum": round(self.total, 6),
            "mean": round(self.mean, 6),
            "min": round(self.min, 6) if self.count else 0.0,
            "max": round(self.max, 6) if self.count else 0.0,
            "p50": round(self.quantile(0.50), 6),
            "p90": round(self.quantile(0.90), 6),
            "p99": round(self.quantile(0.99), 6),
            "buckets": {str(le): c
                        for le, c in zip(self.buckets, self.counts)},
        }


def latency_histogram() -> Histogram:
    return Histogram(buckets=_LATENCY_BUCKETS_S)


def depth_histogram() -> Histogram:
    return Histogram(buckets=_DEPTH_BUCKETS)


@dataclasses.dataclass
class EngineMetrics:
    """One serve loop's worth of scheduling + latency observability."""

    # counters -----------------------------------------------------------
    admitted: int = 0          # sessions granted a slot
    finished: int = 0          # sessions that completed every turn
    cancelled: int = 0         # sessions cancelled (queued or active)
    shed: int = 0              # sessions rejected by the SLO policy
    preempted: int = 0         # chunked admissions yielded to higher prio
    admit_deferred: int = 0    # paged admissions deferred on page pressure
    queue_overflow: int = 0    # max_pending overflow events
    degrade_events: int = 0    # slots entering degraded-budget mode
    degraded_steps: int = 0    # decode steps taken with a shrunken budget
    degraded_turns: int = 0    # turns flagged Turn.degraded
    # gauges (last observed) --------------------------------------------
    queue_depth: int = 0
    active_slots: int = 0
    # histograms ---------------------------------------------------------
    ttft_s: Histogram = dataclasses.field(default_factory=latency_histogram)
    tpot_ms: Histogram = dataclasses.field(default_factory=latency_histogram)
    itl_ms: Histogram = dataclasses.field(default_factory=latency_histogram)
    queue_depth_hist: Histogram = dataclasses.field(
        default_factory=depth_histogram)

    # -- observation helpers --------------------------------------------
    def observe_depth(self, pending: int, active: int) -> None:
        self.queue_depth = pending
        self.active_slots = active
        self.queue_depth_hist.observe(float(pending))

    def observe_ttft(self, seconds: float) -> None:
        self.ttft_s.observe(seconds)

    def observe_turn(self, decode_s: float, n_tokens: int) -> None:
        """Record per-turn decode-rate stats: TPOT is the mean
        time-per-output-token over the turn; ITL gets one sample per
        inter-token gap at that mean (per-token timestamps are not kept
        on the hot path)."""
        if n_tokens <= 0:
            return
        per_tok_ms = 1e3 * decode_s / n_tokens
        self.tpot_ms.observe(per_tok_ms)
        for _ in range(max(0, n_tokens - 1)):
            self.itl_ms.observe(per_tok_ms)

    def to_dict(self) -> Dict[str, object]:
        return {
            "counters": {
                "admitted": self.admitted,
                "finished": self.finished,
                "cancelled": self.cancelled,
                "shed": self.shed,
                "preempted": self.preempted,
                "admit_deferred": self.admit_deferred,
                "queue_overflow": self.queue_overflow,
                "degrade_events": self.degrade_events,
                "degraded_steps": self.degraded_steps,
                "degraded_turns": self.degraded_turns,
            },
            "gauges": {
                "queue_depth": self.queue_depth,
                "active_slots": self.active_slots,
            },
            "histograms": {
                "ttft_s": self.ttft_s.to_dict(),
                "tpot_ms": self.tpot_ms.to_dict(),
                "itl_ms": self.itl_ms.to_dict(),
                "queue_depth": self.queue_depth_hist.to_dict(),
            },
        }
