from repro.serving.engine import (Engine, GenerateResult, ServeResult,
                                  serve_step)
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import Request, Scheduler, make_trace

__all__ = ["Engine", "GenerateResult", "Request", "SamplerConfig",
           "Scheduler", "ServeResult", "make_trace", "sample", "serve_step"]
