from repro.serving.engine import (Engine, GenerateResult, ServeResult,
                                  serve_step)
from repro.serving.pagepool import PagePool, PoolStats, PrefixEntry
from repro.serving.sampler import (SamplerConfig, SamplerParams, sample,
                                   slot_keys)
from repro.serving.scheduler import (Request, Scheduler, Session, Turn,
                                     make_session_trace, make_trace)

__all__ = ["Engine", "GenerateResult", "PagePool", "PoolStats",
           "PrefixEntry", "Request", "SamplerConfig", "SamplerParams",
           "Scheduler", "ServeResult", "Session", "Turn",
           "make_session_trace", "make_trace", "sample", "serve_step",
           "slot_keys"]
