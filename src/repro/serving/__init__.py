from repro.serving.engine import Engine, serve_step
from repro.serving.sampler import SamplerConfig, sample

__all__ = ["Engine", "SamplerConfig", "sample", "serve_step"]
