from repro.serving.engine import (Engine, GenerateResult, ServeResult,
                                  serve_step)
from repro.serving.metrics import EngineMetrics, Histogram
from repro.serving.pagepool import PagePool, PoolStats, PrefixEntry
from repro.serving.sampler import (SamplerConfig, SamplerParams, sample,
                                   slot_keys)
from repro.serving.scheduler import (QueueFullError, Request, Scheduler,
                                     Session, ShedResult, Turn,
                                     make_session_trace, make_trace)

__all__ = ["Engine", "EngineMetrics", "GenerateResult", "Histogram",
           "PagePool", "PoolStats", "PrefixEntry", "QueueFullError",
           "Request", "SamplerConfig", "SamplerParams", "Scheduler",
           "ServeResult", "Session", "ShedResult", "Turn",
           "make_session_trace", "make_trace", "sample", "serve_step",
           "slot_keys"]
