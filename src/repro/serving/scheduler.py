"""Session scheduler for the continuous-batching engine.

The scheduling unit is a **Session** — one multi-turn conversation. Each
:class:`Turn` carries its own prompt *delta* (only the tokens new in that
turn), generation budget, stop spec and :class:`~repro.serving.sampler.
SamplerParams`, so heterogeneous sampling coexists inside one decode batch.
A session occupies one decode slot from admission until its LAST turn
finishes: turn boundaries never release the slot, which is what lets the
engine append the next turn's delta onto the slot's live KV cache and index
(``model.extend_slot``) instead of re-prefilling the whole history — the
paper's lazy-update streaming story applied across turns.

The scheduler itself is pure host-side bookkeeping — it never touches
device state. It owns:

* a FIFO **session queue** (arrival-time gated, so a Poisson trace replays
  faithfully in wall-clock time);
* the **slot table**: which session occupies which of the engine's ``B``
  decode slots;
* per-session/turn **lifecycle records** (queued -> running -> finished)
  with the timing fields latency/TTFT percentiles are computed from.

The engine drives it: ``next_ready`` + ``admit`` when a slot frees,
``finish`` when a session's final turn completes. Turn *transitions* are
engine-internal (the slot is retained). Admission policy (continuous vs
static waves) lives in the engine — the scheduler only answers "who is
next" and "what is free".

``Request(uid, prompt, max_new, ...)`` remains as a factory building a
single-turn Session, so single-shot traces (and the pre-session benchmarks)
read exactly as before.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.sampler import SamplerParams


@dataclasses.dataclass
class Turn:
    """One turn of a session: a prompt delta plus its generation spec.

    ``prompt`` holds ONLY this turn's new tokens; the session history
    (earlier prompts + everything sampled, including tokens later trimmed
    by a stop match) is implicit in the slot's KV cache.
    """

    prompt: np.ndarray                 # (S,) int32 delta tokens
    max_new: int
    sampling: Optional[SamplerParams] = None   # None -> serve() default
    stop: Tuple[Tuple[int, ...], ...] = ()     # stop token sequences
    eos_id: Optional[int] = None       # per-turn EOS override (None -> engine)

    # lifecycle (filled by the engine) ------------------------------------
    started_s: Optional[float] = None  # prefill/extend for this turn began
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    # every sampled token, pre-stop-trim — the exact device-side history
    # (``tokens`` may drop a matched stop suffix; the KV cache cannot)
    sampled: List[int] = dataclasses.field(default_factory=list)
    # wall-clock timestamp of EVERY sampled token (trace-relative seconds,
    # parallel to ``sampled``) — the raw series TPOT and the inter-token-gap
    # percentiles are derived from. The max/p99 gap on a busy slot is the
    # stall metric ``benchmarks/interference.py`` uses to show chunked
    # admission bounding long-prompt interference.
    token_times_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def ttft_s(self) -> Optional[float]:
        """First token relative to the turn's own start (for turn >= 2 this
        is the extend-vs-reprefill number ``benchmarks/session_reuse.py``
        measures)."""
        if self.first_token_s is None or self.started_s is None:
            return None
        return self.first_token_s - self.started_s

    @property
    def itl_ms(self) -> List[float]:
        """Inter-token gaps (ms) between consecutive sampled tokens of this
        turn — empty for single-token turns."""
        ts = self.token_times_s
        return [1e3 * (b - a) for a, b in zip(ts, ts[1:])]

    @property
    def tpot_ms(self) -> Optional[float]:
        """Per-turn time-per-output-token: mean inter-token gap after the
        first token (decode-only — TTFT is excluded by construction)."""
        gaps = self.itl_ms
        return sum(gaps) / len(gaps) if gaps else None

    @property
    def max_itl_ms(self) -> Optional[float]:
        gaps = self.itl_ms
        return max(gaps) if gaps else None

    @property
    def p99_itl_ms(self) -> Optional[float]:
        gaps = self.itl_ms
        if not gaps:
            return None
        return float(np.percentile(np.asarray(gaps), 99))


@dataclasses.dataclass
class Session:
    """One conversation in a serving trace (single-turn == old Request)."""

    uid: int
    turns: List[Turn]
    arrival_s: float = 0.0        # offset from trace start (0 = offline)

    # lifecycle (filled by the scheduler / engine) ------------------------
    admitted_s: Optional[float] = None
    finished_s: Optional[float] = None
    cur: int = 0                  # index of the active turn

    # -- compat / convenience views --------------------------------------
    @property
    def prompt(self) -> np.ndarray:
        return self.turns[0].prompt

    @property
    def prompt_len(self) -> int:
        return self.turns[0].prompt_len

    @property
    def max_new(self) -> int:
        return self.turns[0].max_new

    @property
    def tokens(self) -> List[int]:
        """Generated tokens across all turns (stop-trimmed), flattened."""
        return [tk for t in self.turns for tk in t.tokens]

    @property
    def first_token_s(self) -> Optional[float]:
        return self.turns[0].first_token_s

    @property
    def n_turns(self) -> int:
        return len(self.turns)

    @property
    def latency_s(self) -> Optional[float]:
        """Queueing + all turns: finish relative to arrival."""
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    def total_len(self) -> int:
        """Cache rows the session needs: every delta + every budget (the
        engine admits only sessions with ``total_len() <= usable_rows``)."""
        return sum(t.prompt_len + t.max_new for t in self.turns)

    def history_tokens(self, upto: int) -> np.ndarray:
        """Device-side history BEFORE turn ``upto``'s generation: deltas
        interleaved with raw sampled tokens of turns ``< upto``, plus turn
        ``upto``'s own delta — exactly the concatenation the re-prefill
        fallback/oracle feeds a fresh slot."""
        parts: List[np.ndarray] = []
        for t in self.turns[:upto]:
            parts.append(np.asarray(t.prompt, np.int32))
            parts.append(np.asarray(t.sampled, np.int32))
        parts.append(np.asarray(self.turns[upto].prompt, np.int32))
        return np.concatenate(parts) if parts else np.zeros((0,), np.int32)


def Request(uid: int, prompt: np.ndarray, max_new: int,
            arrival_s: float = 0.0,
            sampling: Optional[SamplerParams] = None,
            stop: Tuple[Tuple[int, ...], ...] = ()) -> Session:
    """Single-turn Session factory — the pre-session ``Request`` surface."""
    return Session(uid=uid, arrival_s=arrival_s,
                   turns=[Turn(prompt=np.asarray(prompt, np.int32),
                               max_new=max_new, sampling=sampling,
                               stop=tuple(tuple(s) for s in stop))])


class Scheduler:
    """FIFO session queue + slot table for a fixed-capacity decode batch."""

    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self._queue: Deque[Session] = deque()
        self._slots: List[Optional[Session]] = [None] * n_slots
        self.finished: Dict[int, Session] = {}
        self.n_admitted = 0

    # -- queue -------------------------------------------------------------
    def submit(self, sess: Session) -> None:
        self._queue.append(sess)

    def submit_all(self, sessions: Sequence[Session]) -> None:
        for s in sorted(sessions, key=lambda s: s.arrival_s):
            self.submit(s)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def all_done(self) -> bool:
        return not self._queue and self.active == 0

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def slot_of(self, slot: int) -> Optional[Session]:
        return self._slots[slot]

    def next_arrival_s(self) -> Optional[float]:
        return self._queue[0].arrival_s if self._queue else None

    def next_ready(self, now_s: float) -> Optional[Session]:
        """Peek the FIFO head if it has arrived by ``now_s``."""
        if self._queue and self._queue[0].arrival_s <= now_s:
            return self._queue[0]
        return None

    # -- slot lifecycle ------------------------------------------------------
    def admit(self, slot: int, now_s: float) -> Session:
        """Pop the FIFO head into ``slot`` (held until its LAST turn)."""
        assert self._slots[slot] is None, f"slot {slot} busy"
        sess = self._queue.popleft()
        sess.admitted_s = now_s
        self._slots[slot] = sess
        self.n_admitted += 1
        return sess

    def finish(self, slot: int, now_s: float) -> Session:
        sess = self._slots[slot]
        assert sess is not None, f"slot {slot} already free"
        sess.finished_s = now_s
        self._slots[slot] = None
        self.finished[sess.uid] = sess
        return sess


# ---------------------------------------------------------------------------
# Trace synthesis
# ---------------------------------------------------------------------------
def make_trace(rng: np.random.Generator, n_requests: int, vocab: int,
               prompt_lens: Sequence[int] = (64, 256, 1024),
               gen_lens: Sequence[int] = (8, 64),
               rate_rps: float = 0.0) -> List[Session]:
    """Synthesise a mixed-length SINGLE-turn trace (the classic benchmark
    driver).

    Prompt lengths and generation budgets are drawn uniformly from the given
    choices; ``rate_rps > 0`` spaces arrivals by exponential gaps (a Poisson
    arrival process — the standard open-loop serving-benchmark driver),
    ``rate_rps == 0`` queues everything at t=0 (offline / batch mode).
    """
    gaps = (rng.exponential(1.0 / rate_rps, size=n_requests)
            if rate_rps > 0 else np.zeros(n_requests))
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n_requests):
        S = int(rng.choice(list(prompt_lens)))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, vocab, size=(S,)).astype(np.int32),
            max_new=int(rng.choice(list(gen_lens))),
            arrival_s=float(arrivals[i])))
    return reqs


def make_session_trace(rng: np.random.Generator, n_sessions: int, vocab: int,
                       n_turns: int = 2,
                       first_lens: Sequence[int] = (256, 1024),
                       delta_lens: Sequence[int] = (32, 128),
                       gen_lens: Sequence[int] = (8, 64),
                       temperatures: Sequence[float] = (0.0, 0.8),
                       rate_rps: float = 0.0) -> List[Session]:
    """Synthesise a MULTI-turn chat trace with heterogeneous sampling.

    Turn 1 draws from ``first_lens`` (the long system-prompt/history), later
    turns from ``delta_lens`` (short follow-ups — the regime where KV/index
    reuse pays). Each turn draws its own temperature from ``temperatures``
    (0.0 entries make greedy turns), so mixed greedy/sampled batches arise
    naturally.
    """
    gaps = (rng.exponential(1.0 / rate_rps, size=n_sessions)
            if rate_rps > 0 else np.zeros(n_sessions))
    arrivals = np.cumsum(gaps)
    sessions = []
    for i in range(n_sessions):
        turns = []
        for j in range(n_turns):
            S = int(rng.choice(list(first_lens if j == 0 else delta_lens)))
            temp = float(rng.choice(list(temperatures)))
            turns.append(Turn(
                prompt=rng.integers(0, vocab, size=(S,)).astype(np.int32),
                max_new=int(rng.choice(list(gen_lens))),
                sampling=SamplerParams(temperature=temp,
                                       top_k=50 if temp > 0 else 0)))
        sessions.append(Session(uid=i, turns=turns,
                                arrival_s=float(arrivals[i])))
    return sessions
