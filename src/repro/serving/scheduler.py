"""Session scheduler for the continuous-batching engine.

The scheduling unit is a **Session** — one multi-turn conversation. Each
:class:`Turn` carries its own prompt *delta* (only the tokens new in that
turn), generation budget, stop spec and :class:`~repro.serving.sampler.
SamplerParams`, so heterogeneous sampling coexists inside one decode batch.
A session occupies one decode slot from admission until its LAST turn
finishes: turn boundaries never release the slot, which is what lets the
engine append the next turn's delta onto the slot's live KV cache and index
(``model.extend_slot``) instead of re-prefilling the whole history — the
paper's lazy-update streaming story applied across turns.

The scheduler itself is pure host-side bookkeeping — it never touches
device state. It owns:

* a FIFO **session queue** (arrival-time gated, so a Poisson trace replays
  faithfully in wall-clock time);
* the **slot table**: which session occupies which of the engine's ``B``
  decode slots;
* per-session/turn **lifecycle records** (queued -> running -> finished)
  with the timing fields latency/TTFT percentiles are computed from.

The engine drives it: ``next_ready`` + ``admit`` when a slot frees,
``finish`` when a session's final turn completes. Turn *transitions* are
engine-internal (the slot is retained). Admission policy (continuous vs
static waves) lives in the engine — the scheduler only answers "who is
next" and "what is free".

``Request(uid, prompt, max_new, ...)`` remains as a factory building a
single-turn Session, so single-shot traces (and the pre-session benchmarks)
read exactly as before.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.sampler import SamplerParams


class QueueFullError(RuntimeError):
    """``Scheduler.submit`` past ``max_pending`` without an SLO policy:
    the caller asked for a bounded queue but configured no shed policy, so
    overflow is an error instead of silent unbounded (or silently dropped)
    queuing."""


@dataclasses.dataclass
class ShedResult:
    """One session rejected by overload control — the explicit record the
    engine surfaces instead of silent unbounded queuing. Every shed session
    appears in exactly one of these (``Scheduler.shed``), once."""

    uid: int
    priority: int
    reason: str                   # "queue_overflow" | "slo"
    at_s: float                   # trace-relative shed time
    queue_depth: int              # pending sessions at shed time
    projected_ttft_s: float = 0.0  # estimate that triggered an "slo" shed


@dataclasses.dataclass
class Turn:
    """One turn of a session: a prompt delta plus its generation spec.

    ``prompt`` holds ONLY this turn's new tokens; the session history
    (earlier prompts + everything sampled, including tokens later trimmed
    by a stop match) is implicit in the slot's KV cache.
    """

    prompt: np.ndarray                 # (S,) int32 delta tokens
    max_new: int
    sampling: Optional[SamplerParams] = None   # None -> serve() default
    stop: Tuple[Tuple[int, ...], ...] = ()     # stop token sequences
    eos_id: Optional[int] = None       # per-turn EOS override (None -> engine)

    # lifecycle (filled by the engine) ------------------------------------
    # True once ANY of this turn's tokens decoded with an overload-shrunken
    # retrieval budget (SLOConfig.degrade_budget): the turn's output is no
    # longer bit-comparable to the unloaded oracle — deliberately traded
    # and recorded, never silent
    degraded: bool = False
    started_s: Optional[float] = None  # prefill/extend for this turn began
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    # every sampled token, pre-stop-trim — the exact device-side history
    # (``tokens`` may drop a matched stop suffix; the KV cache cannot)
    sampled: List[int] = dataclasses.field(default_factory=list)
    # wall-clock timestamp of EVERY sampled token (trace-relative seconds,
    # parallel to ``sampled``) — the raw series TPOT and the inter-token-gap
    # percentiles are derived from. The max/p99 gap on a busy slot is the
    # stall metric ``benchmarks/interference.py`` uses to show chunked
    # admission bounding long-prompt interference.
    token_times_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def ttft_s(self) -> Optional[float]:
        """First token relative to the turn's own start (for turn >= 2 this
        is the extend-vs-reprefill number ``benchmarks/session_reuse.py``
        measures)."""
        if self.first_token_s is None or self.started_s is None:
            return None
        return self.first_token_s - self.started_s

    @property
    def itl_ms(self) -> List[float]:
        """Inter-token gaps (ms) between consecutive sampled tokens of this
        turn — empty for single-token turns."""
        ts = self.token_times_s
        return [1e3 * (b - a) for a, b in zip(ts, ts[1:])]

    @property
    def tpot_ms(self) -> Optional[float]:
        """Per-turn time-per-output-token: mean inter-token gap after the
        first token (decode-only — TTFT is excluded by construction)."""
        gaps = self.itl_ms
        return sum(gaps) / len(gaps) if gaps else None

    @property
    def max_itl_ms(self) -> Optional[float]:
        gaps = self.itl_ms
        return max(gaps) if gaps else None

    @property
    def p99_itl_ms(self) -> Optional[float]:
        gaps = self.itl_ms
        if not gaps:
            return None
        return float(np.percentile(np.asarray(gaps), 99))


@dataclasses.dataclass
class Session:
    """One conversation in a serving trace (single-turn == old Request)."""

    uid: int
    turns: List[Turn]
    arrival_s: float = 0.0        # offset from trace start (0 = offline)
    # SLO scheduling (see configs.base.SLOConfig): 0 = highest priority
    # (premium — never budget-degraded, never shed); ties admit by
    # deadline (arrival + TTFT target), then arrival
    priority: int = 1
    ttft_target_s: Optional[float] = None   # per-session override

    # lifecycle (filled by the scheduler / engine) ------------------------
    admitted_s: Optional[float] = None
    finished_s: Optional[float] = None
    cur: int = 0                  # index of the active turn
    # cooperative cancellation: set via cancel(); the engine honors it at
    # its next step boundary — mid-queue, mid-prefill (chunk boundary) or
    # mid-decode — reclaiming the slot, policy state and paged-pool refs
    cancel_requested: bool = False
    # terminal outcome: "" while live, then "finished"|"shed"|"cancelled"
    outcome: str = ""

    def cancel(self) -> None:
        self.cancel_requested = True

    # -- compat / convenience views --------------------------------------
    @property
    def prompt(self) -> np.ndarray:
        return self.turns[0].prompt

    @property
    def prompt_len(self) -> int:
        return self.turns[0].prompt_len

    @property
    def max_new(self) -> int:
        return self.turns[0].max_new

    @property
    def tokens(self) -> List[int]:
        """Generated tokens across all turns (stop-trimmed), flattened."""
        return [tk for t in self.turns for tk in t.tokens]

    @property
    def first_token_s(self) -> Optional[float]:
        return self.turns[0].first_token_s

    @property
    def n_turns(self) -> int:
        return len(self.turns)

    @property
    def latency_s(self) -> Optional[float]:
        """Queueing + all turns: finish relative to arrival."""
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    def total_len(self) -> int:
        """Cache rows the session needs: every delta + every budget (the
        engine admits only sessions with ``total_len() <= usable_rows``)."""
        return sum(t.prompt_len + t.max_new for t in self.turns)

    def history_tokens(self, upto: int) -> np.ndarray:
        """Device-side history BEFORE turn ``upto``'s generation: deltas
        interleaved with raw sampled tokens of turns ``< upto``, plus turn
        ``upto``'s own delta — exactly the concatenation the re-prefill
        fallback/oracle feeds a fresh slot."""
        parts: List[np.ndarray] = []
        for t in self.turns[:upto]:
            parts.append(np.asarray(t.prompt, np.int32))
            parts.append(np.asarray(t.sampled, np.int32))
        parts.append(np.asarray(self.turns[upto].prompt, np.int32))
        return np.concatenate(parts) if parts else np.zeros((0,), np.int32)


def Request(uid: int, prompt: np.ndarray, max_new: int,
            arrival_s: float = 0.0,
            sampling: Optional[SamplerParams] = None,
            stop: Tuple[Tuple[int, ...], ...] = (),
            priority: int = 1,
            ttft_target_s: Optional[float] = None) -> Session:
    """Single-turn Session factory — the pre-session ``Request`` surface."""
    return Session(uid=uid, arrival_s=arrival_s, priority=priority,
                   ttft_target_s=ttft_target_s,
                   turns=[Turn(prompt=np.asarray(prompt, np.int32),
                               max_new=max_new, sampling=sampling,
                               stop=tuple(tuple(s) for s in stop))])


class Scheduler:
    """Session queue + slot table for a fixed-capacity decode batch.

    ``order="fifo"`` (default) keeps the original arrival-ordered queue.
    ``order="slo"`` makes ``next_ready`` deadline-ordered: among arrived
    sessions, admit the one minimizing (priority, arrival + TTFT target,
    arrival, uid) — premium traffic overtakes the backlog instead of
    queuing behind it.

    ``max_pending`` bounds the queue. Overflow without the SLO policy
    raises :class:`QueueFullError`; with it, the WORST queued-or-new
    session (lowest priority, latest deadline) is shed with an explicit
    :class:`ShedResult`. Terminal bookkeeping is a strict partition:
    every submitted session ends in exactly one of ``finished``,
    ``shed_sessions`` or ``cancelled``.
    """

    def __init__(self, n_slots: int, *, max_pending: int = 0,
                 order: str = "fifo", default_ttft_s: float = 0.0):
        assert n_slots >= 1
        assert order in ("fifo", "slo"), order
        self.n_slots = n_slots
        self.max_pending = int(max_pending)
        self.order = order
        self.default_ttft_s = float(default_ttft_s)
        self._queue: Deque[Session] = deque()
        self._slots: List[Optional[Session]] = [None] * n_slots
        self.finished: Dict[int, Session] = {}
        self.shed: Dict[int, ShedResult] = {}
        self.shed_sessions: Dict[int, Session] = {}
        self.cancelled: Dict[int, Session] = {}
        self.n_admitted = 0
        self.n_preempted = 0
        # optional observer, called once per shed (engine metrics hook)
        self.on_shed: Optional[Callable[[Session, ShedResult], None]] = None

    # -- SLO ordering ------------------------------------------------------
    def deadline_s(self, sess: Session) -> float:
        target = sess.ttft_target_s if sess.ttft_target_s is not None \
            else self.default_ttft_s
        return sess.arrival_s + (target if target > 0 else 0.0)

    def slo_key(self, sess: Session):
        return (sess.priority, self.deadline_s(sess), sess.arrival_s,
                sess.uid)

    def _shed_key(self, sess: Session):
        """Worst-first ordering for overflow shedding (max of this key)."""
        return (sess.priority, self.deadline_s(sess), -sess.arrival_s,
                sess.uid)

    # -- queue -------------------------------------------------------------
    def _remove(self, sess: Session) -> None:
        """Drop ``sess`` from the queue by IDENTITY (Session is a dataclass
        whose ``__eq__`` compares numpy prompt arrays — deque.remove would
        be wrong/ambiguous on duplicate uids)."""
        for i, s in enumerate(self._queue):
            if s is sess:
                del self._queue[i]
                return
        raise ValueError(f"session {sess.uid} not queued")

    def arrived(self, now_s: float) -> List[Session]:
        return [s for s in self._queue if s.arrival_s <= now_s]

    def submit(self, sess: Session, now_s: float = 0.0) -> bool:
        """Queue a session. ``max_pending`` bounds the ARRIVED backlog (a
        pre-loaded open-loop trace is not a queue yet): on overflow, raise
        :class:`QueueFullError` without an SLO policy, else shed the worst
        arrived session. Returns False iff ``sess`` itself was shed."""
        if self.max_pending and sess.arrival_s <= now_s:
            arrived = self.arrived(now_s)
            if len(arrived) >= self.max_pending:
                if self.order != "slo":
                    raise QueueFullError(
                        f"scheduler queue full ({len(arrived)} arrived >= "
                        f"max_pending={self.max_pending}) and no SLO shed "
                        f"policy configured — refusing to queue session "
                        f"{sess.uid} unboundedly")
                victim = max(arrived + [sess], key=self._shed_key)
                if victim is not sess:
                    self._remove(victim)
                self.shed_session(victim, reason="queue_overflow",
                                  now_s=now_s)
                if victim is sess:
                    return False
        self._queue.append(sess)
        return True

    def enforce_bound(self, now_s: float) -> int:
        """Shed arrived overflow down to ``max_pending`` (SLO order only —
        the engine calls this every step as pre-loaded arrivals come
        due)."""
        if not (self.max_pending and self.order == "slo"):
            return 0
        n = 0
        while True:
            arrived = self.arrived(now_s)
            if len(arrived) <= self.max_pending:
                return n
            victim = max(arrived, key=self._shed_key)
            self._remove(victim)
            self.shed_session(victim, reason="queue_overflow", now_s=now_s)
            n += 1

    def submit_all(self, sessions: Sequence[Session]) -> None:
        for s in sorted(sessions, key=lambda s: s.arrival_s):
            self.submit(s, now_s=0.0)

    def queued(self) -> List[Session]:
        return list(self._queue)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def all_done(self) -> bool:
        return not self._queue and self.active == 0

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def slot_of(self, slot: int) -> Optional[Session]:
        return self._slots[slot]

    def slot_index(self, sess: Session) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is sess:
                return i
        return None

    def next_arrival_s(self) -> Optional[float]:
        if not self._queue:
            return None
        if self.order == "slo":
            return min(s.arrival_s for s in self._queue)
        return self._queue[0].arrival_s

    def next_ready(self, now_s: float) -> Optional[Session]:
        """Peek the next admissible session: the FIFO head if arrived, or
        (SLO order) the arrived session with the smallest
        (priority, deadline, arrival, uid)."""
        if not self._queue:
            return None
        if self.order != "slo":
            if self._queue[0].arrival_s <= now_s:
                return self._queue[0]
            return None
        ready = [s for s in self._queue if s.arrival_s <= now_s]
        if not ready:
            return None
        return min(ready, key=self.slo_key)

    # -- slot lifecycle ------------------------------------------------------
    def admit(self, slot: int, now_s: float,
              sess: Optional[Session] = None) -> Session:
        """Pop ``sess`` (default: the FIFO head) into ``slot`` (held until
        its LAST turn)."""
        assert self._slots[slot] is None, f"slot {slot} busy"
        if sess is None:
            sess = self._queue.popleft()
        else:
            self._remove(sess)
        sess.admitted_s = now_s
        self._slots[slot] = sess
        self.n_admitted += 1
        return sess

    def finish(self, slot: int, now_s: float) -> Session:
        sess = self._slots[slot]
        assert sess is not None, f"slot {slot} already free"
        sess.finished_s = now_s
        sess.outcome = "finished"
        self._slots[slot] = None
        self.finished[sess.uid] = sess
        return sess

    def release(self, slot: int) -> Session:
        """Preemption: un-admit the slot's session back to the queue HEAD
        (it keeps its arrival time, so its deadline — and its eventual
        TTFT accounting — includes the wasted admission)."""
        sess = self._slots[slot]
        assert sess is not None, f"slot {slot} already free"
        self._slots[slot] = None
        sess.admitted_s = None
        self._queue.appendleft(sess)
        self.n_preempted += 1
        return sess

    # -- terminal records (shed / cancel) ----------------------------------
    def shed_session(self, sess: Session, *, reason: str, now_s: float,
                     projected_ttft_s: float = 0.0) -> ShedResult:
        """Record a shed session (must already be OFF the queue). Each
        session is shed at most once — double-shedding is a bug."""
        assert sess.uid not in self.shed, \
            f"session {sess.uid} shed twice"
        assert all(s is not sess for s in self._queue)
        assert all(s is not sess for s in self._slots)
        sess.outcome = "shed"
        sess.finished_s = now_s
        res = ShedResult(uid=sess.uid, priority=sess.priority,
                         reason=reason, at_s=now_s,
                         queue_depth=len(self._queue),
                         projected_ttft_s=projected_ttft_s)
        self.shed[sess.uid] = res
        self.shed_sessions[sess.uid] = sess
        if self.on_shed is not None:
            self.on_shed(sess, res)
        return res

    def shed_queued(self, sess: Session, *, reason: str, now_s: float,
                    projected_ttft_s: float = 0.0) -> ShedResult:
        self._remove(sess)
        return self.shed_session(sess, reason=reason, now_s=now_s,
                                 projected_ttft_s=projected_ttft_s)

    def cancel_queued(self, sess: Session, now_s: float) -> None:
        self._remove(sess)
        sess.outcome = "cancelled"
        sess.finished_s = now_s
        self.cancelled[sess.uid] = sess

    def cancel_active(self, slot: int, now_s: float) -> Session:
        """Release a cancelled slot WITHOUT marking it finished."""
        sess = self._slots[slot]
        assert sess is not None, f"slot {slot} already free"
        self._slots[slot] = None
        sess.outcome = "cancelled"
        sess.finished_s = now_s
        self.cancelled[sess.uid] = sess
        return sess


# ---------------------------------------------------------------------------
# Trace synthesis
# ---------------------------------------------------------------------------
def make_trace(rng: np.random.Generator, n_requests: int, vocab: int,
               prompt_lens: Sequence[int] = (64, 256, 1024),
               gen_lens: Sequence[int] = (8, 64),
               rate_rps: float = 0.0) -> List[Session]:
    """Synthesise a mixed-length SINGLE-turn trace (the classic benchmark
    driver).

    Prompt lengths and generation budgets are drawn uniformly from the given
    choices; ``rate_rps > 0`` spaces arrivals by exponential gaps (a Poisson
    arrival process — the standard open-loop serving-benchmark driver),
    ``rate_rps == 0`` queues everything at t=0 (offline / batch mode).
    """
    gaps = (rng.exponential(1.0 / rate_rps, size=n_requests)
            if rate_rps > 0 else np.zeros(n_requests))
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n_requests):
        S = int(rng.choice(list(prompt_lens)))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, vocab, size=(S,)).astype(np.int32),
            max_new=int(rng.choice(list(gen_lens))),
            arrival_s=float(arrivals[i])))
    return reqs


def make_session_trace(rng: np.random.Generator, n_sessions: int, vocab: int,
                       n_turns: int = 2,
                       first_lens: Sequence[int] = (256, 1024),
                       delta_lens: Sequence[int] = (32, 128),
                       gen_lens: Sequence[int] = (8, 64),
                       temperatures: Sequence[float] = (0.0, 0.8),
                       rate_rps: float = 0.0) -> List[Session]:
    """Synthesise a MULTI-turn chat trace with heterogeneous sampling.

    Turn 1 draws from ``first_lens`` (the long system-prompt/history), later
    turns from ``delta_lens`` (short follow-ups — the regime where KV/index
    reuse pays). Each turn draws its own temperature from ``temperatures``
    (0.0 entries make greedy turns), so mixed greedy/sampled batches arise
    naturally.
    """
    gaps = (rng.exponential(1.0 / rate_rps, size=n_sessions)
            if rate_rps > 0 else np.zeros(n_sessions))
    arrivals = np.cumsum(gaps)
    sessions = []
    for i in range(n_sessions):
        turns = []
        for j in range(n_turns):
            S = int(rng.choice(list(first_lens if j == 0 else delta_lens)))
            temp = float(rng.choice(list(temperatures)))
            turns.append(Turn(
                prompt=rng.integers(0, vocab, size=(S,)).astype(np.int32),
                max_new=int(rng.choice(list(gen_lens))),
                sampling=SamplerParams(temperature=temp,
                                       top_k=50 if temp > 0 else 0)))
        sessions.append(Session(uid=i, turns=turns,
                                arrival_s=float(arrivals[i])))
    return sessions
