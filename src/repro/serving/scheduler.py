"""Request scheduler for the continuous-batching engine.

The scheduler is pure host-side bookkeeping — it never touches device
state. It owns:

* a FIFO **request queue** (arrival-time gated, so a Poisson trace replays
  faithfully in wall-clock time);
* the **slot table**: which request occupies which of the engine's ``B``
  decode slots, plus per-slot admit/finish timestamps;
* per-request **lifecycle records** (queued -> running -> finished) with the
  timing fields the latency percentiles are computed from.

The engine drives it: ``next_ready`` + ``admit`` when a slot frees,
``finish`` when a slot's request completes. Admission *policy* (continuous
vs static waves) lives in the engine — the scheduler only answers "who is
next" and "what is free".
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request in a serving trace."""

    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    arrival_s: float = 0.0        # offset from trace start (0 = offline)

    # lifecycle (filled by the scheduler / engine) ------------------------
    admitted_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
    tokens: List[int] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def latency_s(self) -> Optional[float]:
        """Queueing + prefill + decode: finish relative to arrival."""
        if self.finished_s is None:
            return None
        return self.finished_s - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s


class Scheduler:
    """FIFO queue + slot table for a fixed-capacity decode batch."""

    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self._queue: Deque[Request] = deque()
        self._slots: List[Optional[Request]] = [None] * n_slots
        self.finished: Dict[int, Request] = {}
        self.n_admitted = 0

    # -- queue -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self._queue.append(req)

    def submit_all(self, reqs: Sequence[Request]) -> None:
        for r in sorted(reqs, key=lambda r: r.arrival_s):
            self.submit(r)

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self._slots)

    @property
    def all_done(self) -> bool:
        return not self._queue and self.active == 0

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def slot_of(self, slot: int) -> Optional[Request]:
        return self._slots[slot]

    def next_arrival_s(self) -> Optional[float]:
        return self._queue[0].arrival_s if self._queue else None

    def next_ready(self, now_s: float) -> Optional[Request]:
        """Peek the FIFO head if it has arrived by ``now_s``."""
        if self._queue and self._queue[0].arrival_s <= now_s:
            return self._queue[0]
        return None

    # -- slot lifecycle ------------------------------------------------------
    def admit(self, slot: int, now_s: float) -> Request:
        """Pop the FIFO head into ``slot``."""
        assert self._slots[slot] is None, f"slot {slot} busy"
        req = self._queue.popleft()
        req.admitted_s = now_s
        self._slots[slot] = req
        self.n_admitted += 1
        return req

    def finish(self, slot: int, now_s: float) -> Request:
        req = self._slots[slot]
        assert req is not None, f"slot {slot} already free"
        req.finished_s = now_s
        self._slots[slot] = None
        self.finished[req.uid] = req
        return req


# ---------------------------------------------------------------------------
# Trace synthesis
# ---------------------------------------------------------------------------
def make_trace(rng: np.random.Generator, n_requests: int, vocab: int,
               prompt_lens: Sequence[int] = (64, 256, 1024),
               gen_lens: Sequence[int] = (8, 64),
               rate_rps: float = 0.0) -> List[Request]:
    """Synthesise a mixed-length request trace.

    Prompt lengths and generation budgets are drawn uniformly from the given
    choices; ``rate_rps > 0`` spaces arrivals by exponential gaps (a Poisson
    arrival process — the standard open-loop serving-benchmark driver),
    ``rate_rps == 0`` queues everything at t=0 (offline / batch mode).
    """
    gaps = (rng.exponential(1.0 / rate_rps, size=n_requests)
            if rate_rps > 0 else np.zeros(n_requests))
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n_requests):
        S = int(rng.choice(list(prompt_lens)))
        reqs.append(Request(
            uid=i,
            prompt=rng.integers(0, vocab, size=(S,)).astype(np.int32),
            max_new=int(rng.choice(list(gen_lens))),
            arrival_s=float(arrivals[i])))
    return reqs
