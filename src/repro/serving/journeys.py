"""Invariant-fuzzing journey harness for the serving engine.

venomqa-style journey testing: drive a REAL :class:`~repro.serving.
engine.Engine` through randomized *action sequences* — submit /
extend-turn / cancel / overload-burst / clock-advance / engine step —
and check machine-checkable invariants after EVERY step, under a
virtual clock so each seeded journey replays deterministically.

Checked invariants (``JourneyRunner.check_invariants``):

* **slot-table consistency** — the scheduler's slot table, the engine's
  ``active`` mask and the in-flight admission jobs agree (a slot is
  decoding XOR prefilling XOR free; a job's session IS the slot's);
* **monotone per-slot position** — a slot's host-mirrored ``t`` never
  decreases while the same (session, turn) occupies it;
* **token-budget accounting** — no turn ever emits more than
  ``max_new`` samples; public ``tokens`` never exceeds raw ``sampled``;
* **paged ledger** — free + in-use pages == ``n_pages``; every page's
  refcount equals the number of slot page-lists plus prefix-cache
  entries holding it; free pages have refcount 0, no duplicates;
* **terminal partition** — finished / shed / cancelled are disjoint,
  outcomes match, every SLO-shed session is surfaced exactly once, and
  (with the queue bound) the arrived backlog never exceeds
  ``max_pending`` after a step;
* **drain cleanliness** — once the journey drains and the prefix cache
  is cleared, the pool is fully free (zero leaked pages);
* **oracle token identity** — every finished session whose turns were
  never budget-degraded replays SOLO on the same engine (same seed,
  SLO off) with bit-identical per-turn ``sampled`` tokens — the
  serve==solo invariant fuzzed across cancellation, preemption,
  shedding and overload.

Failures raise :class:`InvariantViolation` carrying the seed and the
full action log, so a failing journey is a committable regression test
(``JourneyRunner.replay`` re-runs an action log verbatim).

CLI (the CI fuzz gate)::

    python -m repro.serving.journeys --seeds 0 1 2 --actions 200 \
        --artifact journey-failure.json

exits non-zero on the first violated journey after writing the
seed + action log + violation to ``--artifact``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig, SLOConfig
from repro.serving.sampler import SamplerParams
from repro.serving.scheduler import Session, Turn


class InvariantViolation(AssertionError):
    """One journey invariant failed; carries the replayable evidence."""

    def __init__(self, message: str, *, seed: int, step: int,
                 log: List[Tuple]):
        super().__init__(message)
        self.seed = seed
        self.step = step
        self.log = log


@dataclasses.dataclass(frozen=True)
class JourneySpec:
    """One fuzzed configuration axis: which engine variant to drive."""

    policy: str = "lychee"        # lychee | quest | streaming | ...
    paged: bool = False
    n_slots: int = 2
    n_cache: int = 160
    prefill_chunk: int = 16       # 0 = monolithic admission
    slo: Optional[SLOConfig] = None   # None -> a fuzz-friendly default

    def slo_config(self) -> SLOConfig:
        if self.slo is not None:
            return self.slo
        return SLOConfig(enabled=True, ttft_target_s=0.5,
                         max_pending=8, queue_high=4,
                         degrade_budget=True, min_budget_frac=0.25,
                         preempt=True, shed=True, shed_grace=4.0)


def journey_config(spec: JourneySpec) -> ModelConfig:
    """The tiny test-scale model config the journeys run on (matches the
    tier-1 serving-test fixture scale, so compiles stay in seconds)."""
    cfg = get_config("granite-3-8b", reduced=True).replace(dtype="float32")
    ly = cfg.lychee.replace(budget=64, sink=4, buffer_size=16,
                            max_coarse=8, top_kg=4, full_attn_layers=0,
                            policy=spec.policy,
                            enabled=spec.policy != "dense")
    sv = cfg.serving.replace(paged=spec.paged,
                             prefill_chunk=spec.prefill_chunk,
                             slo=spec.slo_config())
    return cfg.replace(lychee=ly, serving=sv)


class FakeClock:
    """Virtual time: ``sleep`` advances it, nothing ever blocks — the
    loop's arrival gating, SLO deadlines and idle waits all replay
    deterministically and instantly."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def now_s(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += max(0.0, float(dt))


def clone_session(sess: Session) -> Session:
    """A fresh lifecycle-clean copy for the solo oracle replay (same uid:
    sampling keys fold the uid, so identity must be preserved)."""
    return Session(
        uid=sess.uid, arrival_s=0.0, priority=sess.priority,
        ttft_target_s=None,
        turns=[Turn(prompt=np.asarray(t.prompt, np.int32),
                    max_new=t.max_new, sampling=t.sampling,
                    stop=t.stop, eos_id=t.eos_id) for t in sess.turns])


class JourneyRunner:
    """Drives one engine through a journey; checks invariants per step.

    ``engine`` is shared across journeys of the same spec (jit caches are
    the expensive part); every journey builds a fresh ``_ServeLoop`` so
    device state starts clean.
    """

    # action weights for the randomized walk (steps dominate so queues
    # actually drain; bursts + cancels keep the SLO machinery hot)
    ACTIONS = (("step", 10), ("submit", 3), ("burst", 1), ("cancel", 2),
               ("sleep", 2))

    def __init__(self, engine, *, seed: int, n_slots: int = 2,
                 max_live: int = 12):
        self.eng = engine
        self.seed = int(seed)
        self.n_slots = n_slots
        self.max_live = max_live
        self.rng = np.random.default_rng(seed)
        self.clock = FakeClock()
        self.loop = engine.serve_loop([], n_slots=n_slots, seed=seed,
                                      clock=self.clock)
        self.sessions: Dict[int, Session] = {}
        self.log: List[Tuple] = []
        self.next_uid = 0
        self.steps = 0
        self._slot_marks = [None] * n_slots   # (sess id, cur, t) mirrors

    # -- session synthesis ---------------------------------------------
    def _new_session(self, *, priority: int, n_turns: int,
                     lens: List[int], gens: List[int],
                     temps: List[float], target: float) -> Session:
        turns = []
        for j in range(n_turns):
            S, gen, temp = lens[j], gens[j], temps[j]
            sp = SamplerParams(temperature=temp,
                               top_k=20 if temp > 0 else 0)
            prompt = self.rng.integers(
                0, self.eng.cfg.vocab, size=(S,)).astype(np.int32)
            turns.append(Turn(prompt=prompt, max_new=gen, sampling=sp))
        sess = Session(uid=self.next_uid, turns=turns,
                       arrival_s=self.clock.t, priority=priority,
                       ttft_target_s=target if target > 0 else None)
        self.next_uid += 1
        return sess

    def _rand_session_args(self) -> dict:
        rng = self.rng
        n_turns = int(rng.integers(1, 3))
        return dict(
            priority=int(rng.choice([0, 1, 1, 2])),
            n_turns=n_turns,
            lens=[int(rng.choice([8, 24, 48])) for _ in range(n_turns)],
            gens=[int(rng.integers(1, 8)) for _ in range(n_turns)],
            temps=[float(rng.choice([0.0, 0.0, 0.8]))
                   for _ in range(n_turns)],
            target=float(rng.choice([0.0, 0.2, 1.0])))

    def _live_uids(self) -> List[int]:
        return [u for u, s in self.sessions.items() if s.outcome == ""]

    # -- actions --------------------------------------------------------
    def do(self, action: str, **kw) -> None:
        """Execute one journey action and append it to the replay log
        (``burst`` logs as its inner submits, so logs replay verbatim)."""
        if action == "burst":
            for _ in range(kw["n"]):
                self.do("submit",
                        **{k: v for k, v in kw.items() if k != "n"})
            return
        self.log.append((action, kw))
        if action == "submit":
            sess = self._new_session(**kw)
            if sess.total_len() > self.eng.usable:
                return
            self.sessions[sess.uid] = sess
            self.loop.submit(sess)
        elif action == "cancel":
            sess = self.sessions.get(kw["uid"])
            if sess is not None and sess.outcome == "":
                sess.cancel()
        elif action == "sleep":
            self.clock.sleep(kw["dt"])
        elif action == "step":
            self.loop.step()
            self.steps += 1
            self.check_invariants()
        else:
            raise ValueError(f"unknown journey action {action!r}")

    def random_action(self) -> None:
        names = [n for n, _ in self.ACTIONS]
        weights = np.asarray([w for _, w in self.ACTIONS], np.float64)
        act = str(self.rng.choice(names, p=weights / weights.sum()))
        if act == "submit":
            if len(self._live_uids()) >= self.max_live:
                act = "step"
            else:
                return self.do("submit", **self._rand_session_args())
        if act == "burst":
            if len(self._live_uids()) >= self.max_live:
                act = "step"
            else:
                args = self._rand_session_args()
                args["n"] = int(self.rng.integers(3, 7))
                return self.do("burst", **args)
        if act == "cancel":
            live = self._live_uids()
            if not live:
                act = "step"
            else:
                return self.do("cancel",
                               uid=int(self.rng.choice(live)))
        if act == "sleep":
            return self.do("sleep",
                           dt=float(self.rng.choice([0.05, 0.3, 1.0])))
        return self.do("step")

    def run(self, n_actions: int) -> None:
        """The fuzz loop: ``n_actions`` random actions, drain, then the
        final leak + oracle sweep."""
        for _ in range(n_actions):
            self.random_action()
        self.drain()
        self.check_drained()
        self.check_oracle()

    def replay(self, log: List[Tuple]) -> None:
        """Re-run a recorded action log verbatim (shrunken regression
        journeys commit these), then the same final sweep as ``run``."""
        for action, kw in log:
            self.do(action, **kw)
        self.drain()
        self.check_drained()
        self.check_oracle()

    def drain(self, max_steps: int = 20_000) -> None:
        for _ in range(max_steps):
            if self.loop.done:
                return
            self.do("step")
        self._fail(f"journey failed to drain within {max_steps} steps "
                   f"(pending={self.loop.sched.pending}, "
                   f"active={self.loop.sched.active})")

    # -- invariants -----------------------------------------------------
    def _fail(self, msg: str) -> None:
        raise InvariantViolation(
            f"[seed={self.seed} step={self.steps}] {msg}",
            seed=self.seed, step=self.steps, log=self.log)

    def _ok(self, cond: bool, msg: str) -> None:
        if not cond:
            self._fail(msg)

    def check_invariants(self) -> None:
        loop, sched = self.loop, self.loop.sched
        # 1. slot-table consistency
        for slot in range(self.n_slots):
            sess = sched.slot_of(slot)
            job = loop.jobs.get(slot)
            if loop.active[slot]:
                self._ok(sess is not None,
                         f"slot {slot} active without a session")
                self._ok(job is None,
                         f"slot {slot} active AND prefilling")
            if job is not None:
                self._ok(sess is job.sess,
                         f"slot {slot} job session mismatch")
            if sess is None:
                self._ok(not loop.active[slot] and job is None,
                         f"free slot {slot} still live")
            else:
                self._ok(sess.cur < sess.n_turns,
                         f"slot {slot} session past its last turn")
        # 2. monotone per-slot t while the same (session, turn) occupies
        for slot in range(self.n_slots):
            sess = sched.slot_of(slot)
            if sess is None or not loop.active[slot]:
                self._slot_marks[slot] = None
                continue
            mark = (id(sess), sess.cur)
            t = int(loop.slot_t[slot])
            prev = self._slot_marks[slot]
            if prev is not None and prev[0] == mark:
                self._ok(t >= prev[1],
                         f"slot {slot} position went backwards "
                         f"({prev[1]} -> {t})")
            self._slot_marks[slot] = (mark, t)
            self._ok(0 <= t <= self.eng.usable,
                     f"slot {slot} position {t} out of range")
        # 3. token budgets
        for sess in self.sessions.values():
            for j, turn in enumerate(sess.turns):
                self._ok(len(turn.sampled) <= turn.max_new,
                         f"sess{sess.uid} turn {j} over budget: "
                         f"{len(turn.sampled)} > {turn.max_new}")
                self._ok(len(turn.tokens) <= len(turn.sampled),
                         f"sess{sess.uid} turn {j} tokens > sampled")
        # 4. paged ledger
        if loop.pool is not None:
            self._check_pool_ledger()
        # 5. terminal partition + shed-exactly-once + queue bound
        fin, shd, can = (set(sched.finished), set(sched.shed),
                         set(sched.cancelled))
        self._ok(not (fin & shd) and not (fin & can) and not (shd & can),
                 f"terminal sets overlap: fin&shd={fin & shd} "
                 f"fin&can={fin & can} shd&can={shd & can}")
        self._ok(set(sched.shed_sessions) == shd,
                 "shed records and shed sessions disagree")
        for uid in shd:
            self._ok(sched.shed_sessions[uid].outcome == "shed",
                     f"sess{uid} shed without outcome")
        queued_uids = [s.uid for s in sched.queued()]
        self._ok(len(queued_uids) == len(set(queued_uids)),
                 "duplicate session in queue")
        for uid in queued_uids:
            self._ok(uid not in fin | shd | can,
                     f"terminal sess{uid} still queued")
        if loop.slo.enabled and loop.slo.max_pending:
            arrived = sched.arrived(self.clock.t - loop.t0)
            self._ok(len(arrived) <= loop.slo.max_pending,
                     f"arrived backlog {len(arrived)} exceeds "
                     f"max_pending={loop.slo.max_pending}")

    def _check_pool_ledger(self) -> None:
        loop = self.loop
        pool, spec = loop.pool, loop.spec
        self._ok(pool.pages_free + pool.pages_in_use == spec.n_pages,
                 "pool free+in_use != n_pages")
        self._ok(len(set(pool._free)) == len(pool._free),
                 "duplicate page on the free list")
        refs = np.zeros((spec.n_pages,), np.int64)
        for pages in loop.slot_pages:
            for p in pages:
                refs[p] += 1
        for entry in pool._entries:
            for p in entry.pages:
                refs[p] += 1
        if not np.array_equal(refs, pool._ref):
            bad = np.nonzero(refs != pool._ref)[0][:8]
            self._fail(
                "page refcount ledger mismatch at pages "
                f"{bad.tolist()}: expected {refs[bad].tolist()}, "
                f"allocator has {pool._ref[bad].tolist()}")
        for p in pool._free:
            self._ok(pool._ref[p] == 0, f"free page {p} with refs")

    def check_drained(self) -> None:
        """After the queue drains: no jobs, no active slots and — once
        the prefix cache is dropped — zero allocated pages (the leak
        check cancellation/preemption regressions are caught by)."""
        loop = self.loop
        self._ok(loop.done, "drain finished with live sessions")
        self._ok(not loop.jobs, "drained loop still has admission jobs")
        self._ok(not loop.active.any(), "drained loop has active slots")
        for uid, sess in self.sessions.items():
            self._ok(sess.outcome in ("finished", "shed", "cancelled"),
                     f"sess{uid} drained without a terminal outcome "
                     f"({sess.outcome!r})")
        if loop.pool is not None:
            loop.pool.clear_prefix_cache()
            self._check_pool_ledger()
            self._ok(loop.pool.pages_in_use == 0,
                     f"{loop.pool.pages_in_use} pages leaked after "
                     f"drain + prefix-cache clear")

    def check_oracle(self) -> None:
        """Solo-replay every finished, never-degraded session on the SAME
        engine (fresh loop state, shared jit caches, SLO off) and demand
        bit-identical per-turn sampled tokens."""
        saved = self.eng.last_host_samples
        try:
            for uid, sess in sorted(self.sessions.items()):
                if sess.outcome != "finished":
                    continue
                if any(t.degraded for t in sess.turns):
                    continue
                ref = clone_session(sess)
                oloop = self.eng.serve_loop(
                    [ref], n_slots=self.n_slots, seed=self.seed,
                    clock=FakeClock(), slo=SLOConfig())
                oloop.run()
                for j, (got, want) in enumerate(zip(sess.turns,
                                                    ref.turns)):
                    if got.sampled != want.sampled:
                        self._fail(
                            f"oracle mismatch sess{uid} turn {j}: "
                            f"served {got.sampled} != solo "
                            f"{want.sampled}")
        finally:
            self.eng.last_host_samples = saved


def verify_drained_loop(loop, sessions) -> None:
    """One-shot invariant sweep over a DRAINED serve loop — the subset of
    journey checks that make sense post-hoc (benchmarks use this as their
    zero-violations gate): terminal partition + shed-exactly-once, token
    budgets, and the paged refcount ledger incl. drain cleanliness.

    ``sessions`` is every Session ever submitted to the loop. Raises
    :class:`InvariantViolation` on the first failure.
    """

    def fail(msg):
        raise InvariantViolation(msg, seed=-1, step=-1, log=[])

    sched = loop.sched
    if not loop.done:
        fail("loop not drained")
    if loop.jobs or loop.active.any():
        fail("drained loop still has live slots/jobs")
    fin, shd, can = (set(sched.finished), set(sched.shed),
                     set(sched.cancelled))
    if (fin & shd) or (fin & can) or (shd & can):
        fail("terminal sets overlap")
    if set(sched.shed_sessions) != shd:
        fail("shed records and shed sessions disagree")
    for sess in sessions:
        if sess.outcome not in ("finished", "shed", "cancelled"):
            fail(f"sess{sess.uid} has no terminal outcome")
        want = {"finished": fin, "shed": shd, "cancelled": can}
        if sess.uid not in want[sess.outcome]:
            fail(f"sess{sess.uid} outcome {sess.outcome!r} not surfaced")
        for j, turn in enumerate(sess.turns):
            if len(turn.sampled) > turn.max_new:
                fail(f"sess{sess.uid} turn {j} over token budget")
    if loop.pool is not None:
        loop.pool.clear_prefix_cache()
        refs = np.zeros((loop.spec.n_pages,), np.int64)
        for pages in loop.slot_pages:
            for p in pages:
                refs[p] += 1
        for entry in loop.pool._entries:
            for p in entry.pages:
                refs[p] += 1
        if not np.array_equal(refs, loop.pool._ref):
            fail("page refcount ledger mismatch after drain")
        if loop.pool.pages_in_use != 0:
            fail(f"{loop.pool.pages_in_use} pages leaked after drain")


def _build_engine(spec: JourneySpec):
    import jax
    from repro.models import model as MD
    from repro.serving.engine import Engine
    cfg = journey_config(spec)
    params = MD.init_model(jax.random.key(0), cfg)
    return Engine(cfg, params, n_cache=spec.n_cache)


def run_sweep(specs, seeds, n_actions: int,
              artifact: Optional[str] = None, verbose: bool = True
              ) -> int:
    """Run every (spec, seed) journey; on the first violation, dump the
    seed + action log + message to ``artifact`` and return 1."""
    for spec in specs:
        eng = _build_engine(spec)
        for seed in seeds:
            runner = JourneyRunner(eng, seed=seed, n_slots=spec.n_slots)
            try:
                runner.run(n_actions)
            except InvariantViolation as e:
                if verbose:
                    print(f"FAIL {spec.policy} paged={spec.paged} "
                          f"seed={seed}: {e}", file=sys.stderr)
                if artifact:
                    with open(artifact, "w") as f:
                        json.dump({
                            "spec": dataclasses.asdict(spec),
                            "seed": e.seed, "step": e.step,
                            "violation": str(e),
                            "log": [[a, kw] for a, kw in e.log],
                        }, f, indent=2, default=str)
                return 1
            if verbose:
                print(f"ok   {spec.policy:10s} paged={int(spec.paged)} "
                      f"seed={seed}: {runner.steps} steps, "
                      f"{len(runner.sessions)} sessions "
                      f"({len(runner.loop.sched.finished)} finished, "
                      f"{len(runner.loop.sched.shed)} shed, "
                      f"{len(runner.loop.sched.cancelled)} cancelled)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1])
    ap.add_argument("--actions", type=int, default=200)
    ap.add_argument("--policies", nargs="+",
                    default=["lychee", "quest", "streaming"])
    ap.add_argument("--layouts", nargs="+", default=["contiguous",
                                                     "paged"],
                    choices=["contiguous", "paged"])
    ap.add_argument("--n-slots", type=int, default=2)
    ap.add_argument("--artifact", default="journey-failure.json")
    args = ap.parse_args(argv)
    specs = [JourneySpec(policy=p, paged=(lay == "paged"),
                         n_slots=args.n_slots)
             for p in args.policies for lay in args.layouts]
    return run_sweep(specs, args.seeds, args.actions,
                     artifact=args.artifact)


if __name__ == "__main__":
    sys.exit(main())
