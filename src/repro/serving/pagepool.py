"""Host-side page-pool allocator + radix prefix cache for paged serving.

This module owns the HOST bookkeeping of the paged KV layout
(``core.paging`` owns the device math): which physical pages are free,
how many readers each allocated page has, and which previously admitted
prompts can donate their pages to a new request.

Allocator
---------
``PagePool`` manages ``spec.n_pages`` physical pages (the dump page is
outside the allocator — it is never owned). Pages are refcounted:
``alloc`` hands out pages at refcount 1, ``incref`` adds a reader
(prefix sharing), ``decref`` releases one and returns the page to the
free list at zero. Allocation is all-or-nothing — the engine reserves a
session's worst-case page count (``ceil(total_len / page_tokens)``) at
admission, so an admitted session can always run to completion and the
pool can never deadlock mid-decode.

Radix prefix cache
------------------
A page-granular trie keyed by per-page token hashes. ``register`` stores
one finished admission: its token array, the pages covering the prompt
(safe pages shared with the donor via ``incref``, the mutable tail
deep-copied into entry-owned pages by the ENGINE before registration —
see the safe-sharing rule in ``core.paging``), a device-side snapshot of
the slot's residual state (policy selection state, prelude caches,
``t``) and the admission logits.

``lookup`` walks the trie over the new prompt's pages (hash first,
then exact token comparison — hashes only prune):

* **full hit** — an entry with EXACTLY the same token sequence: the
  engine splices the snapshot + shared pages and samples the first token
  from the stored logits. Zero forward passes; greedy output is
  bit-identical to a fresh admission (same deterministic prefill state).
* **partial hit** — the longest shared full-page prefix of any entry:
  the engine shares/copies those pages, truncates the snapshot through
  ``CachePolicy.splice_prefix`` (sound, not bit-exact — see its
  contract) and streams only the suffix. ``keep`` is capped one token
  short of the prompt so the suffix extend always produces the logits
  the first sample needs.

Eviction is LRU over entries (``evict_lru``): dropping an entry decrefs
its pages — pages still shared with live slots stay resident until
those slots finish. The engine evicts under allocation pressure and
defers admission when the pool is still too full (a free slot without
free pages waits — concurrency is bounded by pages, not by
``n_slots x n_cache`` private rows).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.paging import PageSpec


@dataclasses.dataclass
class PoolStats:
    """Observability snapshot of one serve() run (host data only)."""

    page_tokens: int
    page_rows: int
    n_pages: int                  # allocatable physical pages
    pages_in_use: int
    pages_free: int
    shared_pages: int             # pages with refcount > 1
    peak_pages_in_use: int
    bytes_per_page: int           # across all layers' pool leaves
    bytes_saved: int              # sum (refcount-1) * bytes_per_page
    peak_bytes_saved: int
    prefix_lookups: int
    prefix_hits: int              # exact full hits (zero forward passes)
    prefix_partial_hits: int
    prefix_evictions: int
    prefix_entries: int
    deferred_admissions: int      # admissions delayed by page pressure

    @property
    def prefix_hit_rate(self) -> float:
        if not self.prefix_lookups:
            return 0.0
        return (self.prefix_hits + self.prefix_partial_hits) \
            / self.prefix_lookups

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["prefix_hit_rate"] = self.prefix_hit_rate
        return d


@dataclasses.dataclass(eq=False)
class PrefixEntry:
    """One cached prompt prefix (see module docstring). ``eq=False``:
    entries are identity-keyed — the trie's membership tests must never
    compare token arrays elementwise."""

    tokens: np.ndarray            # (Lc,) int32 — the full prompt
    pages: List[int]              # ceil(Lc/P) pages: n_safe shared + owned
    n_safe: int                   # leading pages shared with the donor
    sub: Any                      # device residual snapshot (B=1 leaves)
    logits: Any                   # (1, V) admission logits (device)
    last_used: int = 0            # LRU tick
    uid: int = -1                 # donor session uid (debug)


class _TrieNode:
    __slots__ = ("children", "page_tokens", "through", "terminal")

    def __init__(self, page_tokens: Optional[np.ndarray] = None):
        self.children: Dict[int, _TrieNode] = {}   # page hash -> child
        self.page_tokens = page_tokens             # (P,) verification copy
        self.through: List[PrefixEntry] = []       # entries via this node
        self.terminal: List[PrefixEntry] = []      # entries ending here


def _page_hash(page: np.ndarray) -> int:
    return hash(page.tobytes())


class PagePool:
    """Refcounted physical-page allocator + radix prefix cache."""

    def __init__(self, spec: PageSpec, *, bytes_per_page: int = 0,
                 prefix_cache: bool = True):
        self.spec = spec
        self.bytes_per_page = int(bytes_per_page)
        self.prefix_cache = prefix_cache
        self._free: List[int] = list(range(spec.n_pages - 1, -1, -1))
        self._ref = np.zeros((spec.n_pages,), np.int64)
        self._root = _TrieNode()
        self._entries: List[PrefixEntry] = []
        self._tick = 0
        # -- counters (PoolStats) --
        self.peak_in_use = 0
        self.peak_bytes_saved = 0
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_partial_hits = 0
        self.prefix_evictions = 0
        self.deferred_admissions = 0

    # -- allocator -----------------------------------------------------
    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.spec.n_pages - len(self._free)

    @property
    def shared_pages(self) -> int:
        return int((self._ref > 1).sum())

    def alloc(self, n: int) -> Optional[List[int]]:
        """``n`` fresh pages at refcount 1, or None (all-or-nothing)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            assert self._ref[p] == 0, f"page {p} on free list with refs"
            self._ref[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        self.peak_bytes_saved = max(self.peak_bytes_saved,
                                    self.bytes_saved())
        return pages

    def incref(self, pages) -> None:
        for p in pages:
            assert self._ref[p] > 0, f"incref of free page {p}"
            self._ref[p] += 1
        self.peak_bytes_saved = max(self.peak_bytes_saved,
                                    self.bytes_saved())

    def decref(self, pages) -> None:
        for p in pages:
            assert self._ref[p] > 0, f"double free of page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def bytes_saved(self) -> int:
        """Bytes the sharing currently saves vs private copies."""
        extra = int(np.maximum(self._ref - 1, 0).sum())
        return extra * self.bytes_per_page

    # -- radix prefix cache --------------------------------------------
    def _pages_of(self, tokens: np.ndarray):
        P = self.spec.page_tokens
        tokens = np.asarray(tokens, np.int32)
        for i in range(len(tokens) // P):
            yield tokens[i * P:(i + 1) * P]

    def register(self, tokens, pages: List[int], n_safe: int, sub, logits,
                 uid: int = -1) -> Optional[PrefixEntry]:
        """Insert a finished admission. ``pages`` must already carry this
        entry's references (engine increfs the shared safe prefix and owns
        the copied tail); the entry releases them when evicted."""
        if not self.prefix_cache:
            return None
        tokens = np.asarray(tokens, np.int32)
        assert len(pages) == -(-len(tokens) // self.spec.page_tokens)
        self._tick += 1
        entry = PrefixEntry(tokens=tokens, pages=list(pages),
                            n_safe=int(n_safe), sub=sub, logits=logits,
                            last_used=self._tick, uid=uid)
        node = self._root
        for page in self._pages_of(tokens):
            h = _page_hash(page)
            child = node.children.get(h)
            if child is None or not np.array_equal(child.page_tokens, page):
                # hash collision with different tokens: extremely unlikely;
                # chain by rehashing the pair index deterministically
                while child is not None and \
                        not np.array_equal(child.page_tokens, page):
                    h = hash((h, 1))
                    child = node.children.get(h)
                if child is None:
                    child = _TrieNode(page.copy())
                    node.children[h] = child
            node = child
            node.through.append(entry)
        node.terminal.append(entry)
        self._entries.append(entry)
        return entry

    def lookup(self, tokens) -> Tuple[Optional[str],
                                      Optional[PrefixEntry], int]:
        """Longest cached prefix of ``tokens``.

        Returns (kind, entry, keep): kind "full" (exact token match —
        splice everything, zero forwards), "partial" (share the first
        ``keep`` tokens, ``keep`` a positive multiple of page_tokens and
        < len(tokens)), or (None, None, 0).
        """
        if not self.prefix_cache:
            return None, None, 0
        self.prefix_lookups += 1
        tokens = np.asarray(tokens, np.int32)
        P = self.spec.page_tokens
        node = self._root
        depth = 0
        best: Optional[PrefixEntry] = None
        best_depth = 0
        for page in self._pages_of(tokens):
            h = _page_hash(page)
            child = node.children.get(h)
            while child is not None and \
                    not np.array_equal(child.page_tokens, page):
                h = hash((h, 1))
                child = node.children.get(h)
            if child is None:
                break
            node = child
            depth += 1
            if node.through:
                best = node.through[-1]
                best_depth = depth
        # exact full hit: an entry terminating at the deepest node whose
        # total token sequence equals the prompt
        for entry in node.terminal:
            if len(entry.tokens) == len(tokens) and \
                    np.array_equal(entry.tokens, tokens):
                self.prefix_hits += 1
                self._tick += 1
                entry.last_used = self._tick
                return "full", entry, len(tokens)
        if best is None:
            return None, None, 0
        # partial: keep one token short of the prompt so the suffix
        # extend still produces the first-sample logits
        keep = min(best_depth * P, ((len(tokens) - 1) // P) * P)
        if keep <= 0:
            return None, None, 0
        self.prefix_partial_hits += 1
        self._tick += 1
        best.last_used = self._tick
        return "partial", best, keep

    def evict_lru(self, protect: Optional[PrefixEntry] = None) -> bool:
        """Drop the least-recently-used entry (decref its pages). True if
        an entry was evicted. ``protect`` shields one entry (the hit an
        in-flight admission is about to splice from). Pages still shared
        with live slots remain allocated until those slots release them."""
        victims = [e for e in self._entries if e is not protect]
        if not victims:
            return False
        entry = min(victims, key=lambda e: e.last_used)
        self._remove(entry)
        self.prefix_evictions += 1
        return True

    def _remove(self, entry: PrefixEntry) -> None:
        self._entries.remove(entry)
        node = self._root
        path = []
        for page in self._pages_of(entry.tokens):
            h = _page_hash(page)
            child = node.children.get(h)
            while child is not None and \
                    not np.array_equal(child.page_tokens, page):
                h = hash((h, 1))
                child = node.children.get(h)
            if child is None:
                break
            path.append((node, h, child))
            node = child
            if entry in node.through:
                node.through.remove(entry)
        if entry in node.terminal:
            node.terminal.remove(entry)
        # prune childless, entry-less suffix of the path
        for parent, h, child in reversed(path):
            if not child.children and not child.through and \
                    not child.terminal:
                del parent.children[h]
        self.decref(entry.pages)
        entry.sub = entry.logits = None

    def clear_prefix_cache(self) -> None:
        while self._entries:
            self._remove(self._entries[-1])

    # -- observability -------------------------------------------------
    def stats(self) -> PoolStats:
        return PoolStats(
            page_tokens=self.spec.page_tokens,
            page_rows=self.spec.page_rows,
            n_pages=self.spec.n_pages,
            pages_in_use=self.pages_in_use,
            pages_free=self.pages_free,
            shared_pages=self.shared_pages,
            peak_pages_in_use=self.peak_in_use,
            bytes_per_page=self.bytes_per_page,
            bytes_saved=self.bytes_saved(),
            peak_bytes_saved=self.peak_bytes_saved,
            prefix_lookups=self.prefix_lookups,
            prefix_hits=self.prefix_hits,
            prefix_partial_hits=self.prefix_partial_hits,
            prefix_evictions=self.prefix_evictions,
            prefix_entries=len(self._entries),
            deferred_admissions=self.deferred_admissions)
