"""Token sampling: greedy / temperature / top-k / nucleus."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0      # 0 -> greedy
    top_k: int = 0                # 0 -> disabled
    top_p: float = 1.0            # 1 -> disabled


def sample(key, logits: jax.Array, sc: SamplerConfig) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if sc.temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    logits = logits / sc.temperature
    if sc.top_k:
        kth = jax.lax.top_k(logits, sc.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, _NEG, logits)
    if sc.top_p < 1.0:
        sorted_l = jnp.sort(logits, -1)[..., ::-1]
        probs = jax.nn.softmax(sorted_l, -1)
        csum = jnp.cumsum(probs, -1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(csum < sc.top_p, -1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx, -1)
        logits = jnp.where(logits < cutoff, _NEG, logits)
    return jax.random.categorical(key, logits, -1).astype(jnp.int32)
