"""Per-slot vectorized token sampling: greedy / temperature / top-k / top-p.

The sampler is a pure function of ``(keys, logits, temp, top_k, top_p)``
where every parameter is a length-``B`` vector — one entry per serving slot
— so a greedy request and a temperature-0.9/top-p-0.9 request can share one
decode batch and the whole thing traces into the engine's single jitted
decode step (one dispatch and one (B,)-int host transfer per token; no
eager host-side sampling in the hot loop).

Per-request determinism rides on :func:`request_key`: slot keys are derived
as ``fold_in(fold_in(base_key, uid), step)`` — a pure function of the serve
seed, the request id and the request's own sample counter — so sampled
outputs are independent of co-scheduled requests, slot assignment and
admission order (the ``serve == serve`` invariant tests/test_session.py
checks), extending the greedy bit-identity contract to ``temperature > 0``.

Row semantics (all applied per slot):

* ``temp <= 0``  -> argmax (greedy); the categorical draw for that row is
  discarded via ``jnp.where``, so greedy rows cost nothing extra at trace
  level and stay bit-identical to ``jnp.argmax``;
* ``top_k == 0`` -> top-k filtering disabled for that row;
* ``top_p >= 1`` -> nucleus filtering disabled for that row.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class SamplerParams:
    """Per-request sampling spec (a Turn carries one of these)."""

    temperature: float = 0.0      # <= 0 -> greedy
    top_k: int = 0                # 0 -> disabled
    top_p: float = 1.0            # >= 1 -> disabled


# Back-compat alias: the pre-session API called the (identical) global
# sampling spec SamplerConfig.
SamplerConfig = SamplerParams


def request_key(base_key, uid, step):
    """Deterministic per-request sampling key: fold the request uid and the
    request's own sample counter into the serve-level base key. uid/step may
    be traced scalars (the engine vmaps this over the slot axis inside the
    jitted decode step)."""
    return jax.random.fold_in(jax.random.fold_in(base_key, uid), step)


def slot_keys(base_key, uid: jax.Array, step: jax.Array) -> jax.Array:
    """(B,) batch of :func:`request_key` — one key per serving slot."""
    return jax.vmap(lambda u, s: request_key(base_key, u, s))(uid, step)


def top_k_mask(logits: jax.Array, k: jax.Array) -> jax.Array:
    """Per-row top-k keep mask. logits: (B, V); k: (B,) int32, 0 = keep all.

    Keeps the k highest logits of each row (ties at the k-th value are all
    kept — with continuous logits that is exactly k entries).
    """
    V = logits.shape[-1]
    sorted_desc = jnp.sort(logits, -1)[..., ::-1]
    kk = jnp.where(k > 0, jnp.clip(k, 1, V), V)
    kth = jnp.take_along_axis(sorted_desc, (kk - 1)[:, None], -1)  # (B, 1)
    return logits >= kth


def top_p_mask(logits: jax.Array, p: jax.Array) -> jax.Array:
    """Per-row nucleus keep mask. logits: (B, V); p: (B,), >= 1 = keep all.

    Keeps the smallest set of rows' logits whose softmax mass reaches ``p``
    — the set always contains the row argmax, so a sample exists even for
    tiny ``p``.
    """
    sorted_desc = jnp.sort(logits, -1)[..., ::-1]
    probs = jax.nn.softmax(sorted_desc, -1)
    csum = jnp.cumsum(probs, -1)
    # smallest prefix with cumulative prob >= p (index of its last element)
    cutoff_idx = jnp.sum(csum < jnp.clip(p, 0.0, 1.0)[:, None], -1,
                         keepdims=True)
    cutoff = jnp.take_along_axis(sorted_desc, cutoff_idx, -1)      # (B, 1)
    return logits >= cutoff


def sample(keys: jax.Array, logits: jax.Array, temp: jax.Array,
           top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Per-slot vectorized sampling. keys: (B,) PRNG keys; logits: (B, V);
    temp/top_k/top_p: (B,) per-slot parameters (scalars broadcast).
    Returns (B,) int32 tokens.
    """
    B, V = logits.shape
    temp = jnp.broadcast_to(jnp.asarray(temp, jnp.float32), (B,))
    top_k = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (B,))
    top_p = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (B,))

    greedy_tok = jnp.argmax(logits, -1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temp, 1e-6)[:, None]
    # top-k is scale-invariant; top-p is defined over the TEMPERED dist
    keep = top_k_mask(scaled, top_k) & top_p_mask(scaled, top_p)
    masked = jnp.where(keep, scaled, _NEG)
    sampled = jax.vmap(
        lambda k, l: jax.random.categorical(k, l, -1))(keys, masked)
    return jnp.where(temp <= 0.0, greedy_tok,
                     sampled.astype(jnp.int32)).astype(jnp.int32)
