"""The repo's standing suppressions: intentional, reasoned rule exceptions.

Every entry here is a contract exception we WANT — it stays visible in the
report (marked suppressed) but never fails CI. Adding to this list requires
a reason string; an empty reason asserts at import time.
"""
from __future__ import annotations

from typing import List

from repro.analysis.findings import Suppression

SUPPRESSIONS: List[Suppression] = [
    Suppression(
        rule="no-cache-materialization",
        target="extend[",
        match="dynamic_slice",
        reason="slice_slot: extend/admission extracts ONE slot's caches to "
               "run the chunk delta-forward at B=1. Runs once per admitted "
               "chunk (never per decode token) and is intrinsically "
               "O(slot context) — the same order as writing the chunk's KV "
               "into that cache, which the admission must do anyway."),
    Suppression(
        rule="no-cache-materialization",
        target="extend[mla",
        match="mla.py",
        reason="MLA extend decompresses the latent cache into full K/V "
               "(w_uk/w_uv expansion + rope concat) so the chunk's new "
               "tokens can attend over the whole prior context. Extend is "
               "a prefill-class op (once per admitted chunk / turn, never "
               "per decode token) — see the mla.py extend docstring; the "
               "per-token decode path stays absorbed (latent matmul form)."),
    Suppression(
        rule="no-cache-materialization",
        target="extend[mla",
        match="attention.py",
        reason="flash_attention pads the MLA-decompressed K/V up to a "
               "block_k multiple before blocking. Same prefill-class "
               "extend op as the mla.py decompression; the pad is a no-op "
               "when the context is already block-aligned."),
    Suppression(
        rule="dtype-discipline",
        target="extend[mla",
        match="attention.py",
        reason="flash_attention's f32 block accumulator: each K/V block is "
               "upcast for the logits/PV matmuls inside the scan step. "
               "Bounded by block_k rows per step at serving shapes — it "
               "only reaches cache size here because the analysis cache "
               "(384 rows) fits in a single block."),
    Suppression(
        rule="no-cache-materialization",
        target="extend_paged[mla",
        match="mla.py",
        reason="Paged extend gathers the slot view and runs the UNCHANGED "
               "contiguous extend over it, so it inherits the same MLA "
               "latent-decompression (see the extend[mla entry above). "
               "Admission-class: once per admitted chunk / turn, O(slot "
               "context) — never pool-sized, never per decode token."),
    Suppression(
        rule="no-cache-materialization",
        target="extend_paged[mla",
        match="attention.py",
        reason="Same flash_attention block_k pad as the contiguous "
               "extend[mla entry — the paged extend reuses the contiguous "
               "math over the gathered slot view, once per admitted chunk."),
    Suppression(
        rule="dtype-discipline",
        target="extend_paged[mla",
        match="attention.py",
        reason="Same flash_attention f32 block accumulator as the "
               "contiguous extend[mla entry — block_k-bounded at serving "
               "shapes; the paged extend runs the identical contiguous "
               "kernel over the gathered slot view."),
]
