"""The repo's standing suppressions: intentional, reasoned rule exceptions.

Every entry here is a contract exception we WANT — it stays visible in the
report (marked suppressed) but never fails CI. Adding to this list requires
a reason string; an empty reason asserts at import time.
"""
from __future__ import annotations

from typing import List

from repro.analysis.findings import Suppression

SUPPRESSIONS: List[Suppression] = [
    Suppression(
        rule="no-cache-materialization",
        target="extend[",
        match="dynamic_slice",
        reason="slice_slot: extend/admission extracts ONE slot's caches to "
               "run the chunk delta-forward at B=1. Runs once per admitted "
               "chunk (never per decode token) and is intrinsically "
               "O(slot context) — the same order as writing the chunk's KV "
               "into that cache, which the admission must do anyway."),
    Suppression(
        rule="no-cache-materialization",
        target="extend[mla",
        match="mla.py",
        reason="MLA extend decompresses the latent cache into full K/V "
               "(w_uk/w_uv expansion + rope concat) so the chunk's new "
               "tokens can attend over the whole prior context. Extend is "
               "a prefill-class op (once per admitted chunk / turn, never "
               "per decode token) — see the mla.py extend docstring; the "
               "per-token decode path stays absorbed (latent matmul form)."),
    Suppression(
        rule="no-cache-materialization",
        target="extend[mla",
        match="attention.py",
        reason="flash_attention pads the MLA-decompressed K/V up to a "
               "block_k multiple before blocking. Same prefill-class "
               "extend op as the mla.py decompression; the pad is a no-op "
               "when the context is already block-aligned."),
    Suppression(
        rule="dtype-discipline",
        target="extend[mla",
        match="attention.py",
        reason="flash_attention's f32 block accumulator: each K/V block is "
               "upcast for the logits/PV matmuls inside the scan step. "
               "Bounded by block_k rows per step at serving shapes — it "
               "only reaches cache size here because the analysis cache "
               "(384 rows) fits in a single block."),
]
