"""Static hot-path analysis: jaxpr lint rules, Pallas kernel checks, and
engine-level donation / sharding / compile-count audits.

Entry points:

* ``python -m repro.analysis --fail-on warning`` — the CI gate;
* :func:`repro.analysis.runner.run_analysis` — programmatic runs;
* :mod:`repro.analysis.walker` — the reusable jaxpr walker (tests import
  ``all_eqns``/``walk`` from here instead of rolling their own).
"""
from repro.analysis.findings import (Finding, Report, Severity,  # noqa: F401
                                     Suppression)
from repro.analysis.rules import (RULES, Rule, RuleContext,  # noqa: F401
                                  get_rule, register_rule,
                                  run_jaxpr_rules)
from repro.analysis import pallas_checks  # noqa: F401  (registers rules)
from repro.analysis.walker import (EqnSite, all_eqns, find_eqns,  # noqa: F401
                                   subjaxprs, walk)
