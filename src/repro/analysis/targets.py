"""Canned analysis targets: the hot-path jaxprs the rules run over.

One *target* is a traced jaxpr of a real engine-path function at
representative serving shapes, for one (architecture, cache-policy) pair:

* ``decode``        — ``model.decode_step`` (the fused serve step body);
* ``decode_masked`` — the chunk-interleaved variant (``decode_step`` +
  ``mask_step_slots``), the step that runs while an admission is in flight;
* ``decode_kernel`` — decode with the Pallas span executor forced on
  (``use_kernel=True``), so the kernel-path jaxpr (and its ``pallas_call``)
  is linted even on CPU hosts;
* ``extend``        — ``model.extend_slot`` with a traced ``n_tokens``
  valid-length mask: BOTH the multi-turn delta forward and the
  chunked-admission chunk feed trace through this one path;
* ``admit``         — ``model.prefill_into_slot`` (bucketed, masked). The
  admission prefill legitimately materializes the cache once per prompt,
  so this target runs only the callback/dtype/pallas rules — the
  materialization rule is a per-STEP contract.
* ``decode_paged`` / ``decode_paged_masked`` — the same decode step over
  the paged-pool state (``serving.paged``): the per-slot materialization
  threshold still applies, so the page-table translation must keep span
  gathers at O(budget) — a pool-sized (or even slot-sized) gather per
  step fails;
* ``extend_paged`` / ``admit_paged`` — the paged admission family at the
  POOL threshold: gathering one slot's contiguous view is admission-class
  and allowed, a pool-sized gather/copy per call is the fenced regression.

Shapes are the reduced-config serving shapes: tracing needs no weights on
device beyond the tiny reduced init, and every jaxpr is built with
``jax.make_jaxpr`` — nothing executes, so the whole suite runs identically
on CPU CI and TPU hosts.

Architectures: ``gqa`` (granite-3-8b reduced — the grouped-query attention
family) and ``mla`` (deepseek reduced with a pure-MLA pattern, the
latent-cache family — the same substitution ``tests/test_session.py`` uses
to reach the MLA extend path).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.rules import RuleContext
from repro.configs.base import LycheeConfig, ModelConfig, get_config
from repro.core.paging import resolve_page_spec
from repro.models import model as MD

ARCHS = ("gqa", "mla")
POLICIES = ("lychee", "quest", "clusterkv", "streaming", "dense")
SPAN_POLICIES = ("lychee", "quest", "clusterkv", "streaming")

# serving shapes for the canned targets: 2 slots over a 384-row cache with
# a 64-token retrieval budget — big enough that a budgeted span gather
# (C * span_len rows) stays strictly below one cache leaf, so the
# materialization rule separates O(budget) work from O(context) work
N_CACHE = 384
N_SLOTS = 2
BUDGET = 64

# rules that make sense per target kind (None = all registered rules)
_ADMIT_RULES = ("no-host-callback", "dtype-discipline",
                "pallas-grid-divisibility", "pallas-dma-pairing",
                "pallas-vmem-budget")


@dataclasses.dataclass
class JaxprTarget:
    name: str
    closed_jaxpr: object
    ctx: RuleContext
    rules: Optional[Tuple[str, ...]] = None   # None = every registered rule


def _lychee(policy: str, use_kernel=None) -> LycheeConfig:
    return LycheeConfig(
        policy=policy, enabled=policy != "dense", budget=BUDGET, sink=4,
        buffer_size=16, max_coarse=8, top_kg=4, full_attn_layers=0,
        quest_page=8, ckv_tokens_per_cluster=8, use_kernel=use_kernel)


def arch_config(arch: str, policy: str = "lychee",
                use_kernel=None) -> ModelConfig:
    if arch == "gqa":
        cfg = get_config("granite-3-8b", reduced=True)
    elif arch == "mla":
        # the pure-MLA latent-cache pattern (tests/test_session.py idiom):
        # swaps the MoE FFN out so the extend path is reachable too
        cfg = get_config("deepseek-v3-671b", reduced=True).replace(
            pattern=("mla",))
    else:
        raise KeyError(f"unknown arch {arch!r}; have {ARCHS}")
    return cfg.replace(lychee=_lychee(policy, use_kernel))


@functools.lru_cache(maxsize=None)
def arch_params(arch: str):
    """Reduced-config params, shared across every policy of one arch
    (policy choice never changes the weight pytree)."""
    cfg = arch_config(arch)
    return MD.init_model(jax.random.key(0), cfg)


@functools.lru_cache(maxsize=None)
def state_shapes(arch: str, policy: str):
    """ShapeDtypeStruct pytree of the N_SLOTS-slot decode state."""
    cfg = arch_config(arch, policy)
    params = arch_params(arch)
    tokens = jax.ShapeDtypeStruct((N_SLOTS, 32), jnp.int32)
    return jax.eval_shape(
        lambda p, tk: MD.prefill(p, tk, cfg, N_CACHE)[1], params, tokens)


def cache_leaf_elems(state) -> int:
    """Element count of ONE per-group KV-cache leaf (B, Hkv, N, d) — the
    "cache-sized" threshold. Scanned group leaves carry a leading groups
    dim (STATE_BATCH_AXIS), which is dropped: a materialization inside the
    scan body sees the per-group shape."""
    best = 0
    for cache in state["groups"]:
        if not isinstance(cache, dict):
            continue
        for name in ("k", "v", "latent"):
            leaf = cache.get(name)
            if leaf is None:
                continue
            n = 1
            for d in leaf.shape[1:]:          # drop the groups dim
                n *= d
            best = max(best, n) if best == 0 else min(best, n)
    return best


def cache_dtype(state):
    for cache in state["groups"]:
        if isinstance(cache, dict):
            for name in ("k", "v", "latent",
                         "pool_k", "pool_v", "pool_latent"):
                if name in cache:
                    return cache[name].dtype
    return None


def pool_leaf_elems(pstate) -> int:
    """Element count of ONE per-group paged-pool leaf (Hkv, pool_rows, d) —
    the "pool-sized" threshold of the paged targets. Paged extend/admit
    legitimately gather ONE slot's contiguous view (an admission-class
    cost, strictly smaller); a pool-sized materialization would be a
    whole-pool copy per call, the regression the paged layout exists to
    avoid."""
    best = 0
    for cache in pstate["groups"]:
        if not isinstance(cache, dict):
            continue
        for name in ("pool_k", "pool_v", "pool_latent"):
            leaf = cache.get(name)
            if leaf is None:
                continue
            n = 1
            for d in leaf.shape[1:]:          # drop the groups dim
                n *= d
            best = max(best, n) if best == 0 else min(best, n)
    return best


def _ctx(name: str, state, vmem_limit_bytes: int) -> RuleContext:
    return RuleContext(target=name, cache_elems=cache_leaf_elems(state),
                       cache_dtype=cache_dtype(state),
                       vmem_limit_bytes=vmem_limit_bytes)


def build_jaxpr_targets(archs=ARCHS, policies=POLICIES,
                        vmem_limit_bytes: int = 16 * 2 ** 20
                        ) -> List[JaxprTarget]:
    targets: List[JaxprTarget] = []
    tok = jax.ShapeDtypeStruct((N_SLOTS,), jnp.int32)
    keep = jax.ShapeDtypeStruct((N_SLOTS,), jnp.bool_)
    delta = jax.ShapeDtypeStruct((1, 24), jnp.int32)
    prompt = jax.ShapeDtypeStruct((1, 32), jnp.int32)
    scalar_i = jax.ShapeDtypeStruct((), jnp.int32)

    for arch in archs:
        params = arch_params(arch)
        for policy in policies:
            cfg = arch_config(arch, policy)
            state = state_shapes(arch, policy)
            ctx = functools.partial(_ctx, state=state,
                                    vmem_limit_bytes=vmem_limit_bytes)
            tag = f"{arch}/{policy}"

            jx = jax.make_jaxpr(
                lambda p, tk, st, cfg=cfg: MD.decode_step(p, tk, st, cfg)
            )(params, tok, state)
            targets.append(JaxprTarget(f"decode[{tag}]", jx,
                                       ctx(f"decode[{tag}]")))

            def _masked(p, tk, st, kp, cfg=cfg):
                logits, ns = MD.decode_step(p, tk, st, cfg)
                return logits, MD.mask_step_slots(st, ns, kp)
            jx = jax.make_jaxpr(_masked)(params, tok, state, keep)
            targets.append(JaxprTarget(f"decode_masked[{tag}]", jx,
                                       ctx(f"decode_masked[{tag}]")))

            if policy in SPAN_POLICIES:
                cfg_k = arch_config(arch, policy, use_kernel=True)
                jx = jax.make_jaxpr(
                    lambda p, tk, st, cfg=cfg_k: MD.decode_step(
                        p, tk, st, cfg))(params, tok, state)
                targets.append(JaxprTarget(f"decode_kernel[{tag}]", jx,
                                           ctx(f"decode_kernel[{tag}]")))

            if MD.can_extend(cfg):
                jx = jax.make_jaxpr(
                    lambda p, tk, n, st, s, cfg=cfg: MD.extend_slot(
                        p, tk, cfg, st, s, n_tokens=n)
                )(params, delta, scalar_i, state, scalar_i)
                targets.append(JaxprTarget(f"extend[{tag}]", jx,
                                           ctx(f"extend[{tag}]")))

                jx = jax.make_jaxpr(
                    lambda p, tk, n, st, s, cfg=cfg: MD.prefill_into_slot(
                        p, tk, cfg, N_CACHE, st, s, n_tokens=n)
                )(params, prompt, scalar_i, state, scalar_i)
                targets.append(JaxprTarget(f"admit[{tag}]", jx,
                                           ctx(f"admit[{tag}]"),
                                           rules=_ADMIT_RULES))

            # ---- paged KV pool targets (dense falls back to contiguous
            # by design — can_page — so only the span policies appear) ----
            cfg_p = cfg.replace(serving=cfg.serving.replace(paged=True))
            if MD.can_page(cfg_p):
                spec = resolve_page_spec(N_CACHE, cfg_p.lychee,
                                         n_slots=N_SLOTS)
                cfg_p = cfg_p.replace(serving=cfg_p.serving.replace(
                    page_tokens=spec.page_tokens))
                pstate = MD.paged_state_struct(state, spec)
                # decode contract is the CONTIGUOUS per-slot threshold: one
                # paged step must not materialize even one slot's cache,
                # let alone the pool (the scalar-prefetched translation
                # keeps span gathers at O(budget))
                jx = jax.make_jaxpr(
                    lambda p, tk, st, cfg=cfg_p: MD.decode_step(
                        p, tk, st, cfg))(params, tok, pstate)
                targets.append(JaxprTarget(f"decode_paged[{tag}]", jx,
                                           ctx(f"decode_paged[{tag}]")))

                def _masked_p(p, tk, st, kp, cfg=cfg_p):
                    logits, ns = MD.decode_step(p, tk, st, cfg)
                    return logits, MD.mask_step_slots(st, ns, kp)
                jx = jax.make_jaxpr(_masked_p)(params, tok, pstate, keep)
                targets.append(
                    JaxprTarget(f"decode_paged_masked[{tag}]", jx,
                                ctx(f"decode_paged_masked[{tag}]")))

                # extend/admit contract is the POOL threshold: gathering
                # one slot's contiguous view is admission-class and
                # allowed, a pool-sized gather/copy per call is not
                pctx = RuleContext(
                    target="", cache_elems=pool_leaf_elems(pstate),
                    cache_dtype=cache_dtype(pstate),
                    vmem_limit_bytes=vmem_limit_bytes)
                jx = jax.make_jaxpr(
                    lambda p, tk, n, st, s, cfg=cfg_p, sp=spec:
                    MD.extend_slot_paged(p, tk, cfg, st, s, sp, n_tokens=n)
                )(params, delta, scalar_i, pstate, scalar_i)
                targets.append(JaxprTarget(
                    f"extend_paged[{tag}]", jx,
                    dataclasses.replace(pctx,
                                        target=f"extend_paged[{tag}]")))

                row = jax.ShapeDtypeStruct((spec.max_pages,), jnp.int32)
                jx = jax.make_jaxpr(
                    lambda p, tk, n, st, s, r, cfg=cfg_p, sp=spec:
                    MD.prefill_into_slot_paged(p, tk, cfg, N_CACHE, st, s,
                                               r, sp, n_tokens=n)
                )(params, prompt, scalar_i, pstate, scalar_i, row)
                targets.append(JaxprTarget(
                    f"admit_paged[{tag}]", jx,
                    dataclasses.replace(pctx,
                                        target=f"admit_paged[{tag}]")))
    return targets


def build_kernel_targets(vmem_limit_bytes: int = 16 * 2 ** 20
                         ) -> List[JaxprTarget]:
    """The raw Pallas kernels at representative shapes — linted directly so
    kernel regressions surface even for call sites no jaxpr target reaches.
    ``interpret=False`` keeps the real Mosaic parameterization in the
    traced ``pallas_call`` (tracing never lowers, so no TPU is needed)."""
    from repro.kernels.chunk_pool import chunk_pool
    from repro.kernels.hier_score import hier_score
    from repro.kernels.sparse_attention import sparse_chunk_attention

    B, H, G, d, N, C, M = 2, 2, 4, 32, N_CACHE, 12, 24
    mk = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.float32)
    mi = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    targets = []

    jx = jax.make_jaxpr(functools.partial(
        sparse_chunk_attention, max_chunk=16, interpret=False))(
        mk((B, H, G, d)), mk((B, H, N, d)), mk((B, H, N, d)),
        mi((B, H, C)), mi((B, H, C)))
    ctx = RuleContext(target="kernel[sparse_attention]",
                      cache_elems=B * H * N * d,
                      vmem_limit_bytes=vmem_limit_bytes)
    targets.append(JaxprTarget("kernel[sparse_attention]", jx, ctx))

    jx = jax.make_jaxpr(functools.partial(
        chunk_pool, max_chunk=16, interpret=False))(
        mk((H, N, d)), mi((M,)), mi((M,)))
    ctx = RuleContext(target="kernel[chunk_pool]", cache_elems=0,
                      vmem_limit_bytes=vmem_limit_bytes)
    targets.append(JaxprTarget("kernel[chunk_pool]", jx, ctx))

    jx = jax.make_jaxpr(functools.partial(hier_score, interpret=False))(
        mk((H, d)), mk((H, M, d)), mk((H, M)),
        jax.ShapeDtypeStruct((H, M), jnp.bool_))
    ctx = RuleContext(target="kernel[hier_score]", cache_elems=0,
                      vmem_limit_bytes=vmem_limit_bytes)
    targets.append(JaxprTarget("kernel[hier_score]", jx, ctx))
    return targets
