"""Static checks over every ``pallas_call`` found in a traced jaxpr.

Three classes of kernel bug are decidable at trace time (no TPU needed —
``jax.make_jaxpr`` embeds the kernel jaxpr and grid mapping in the
``pallas_call`` eqn params):

* **grid/block divisibility** — a BlockSpec whose block shape does not
  divide the operand shape silently over-reads garbage rows on the final
  grid step (the kernels here pre-pad spans/tiles so every shipped grid is
  exact; a new variant that forgets to pad trips this);
* **DMA start/wait pairing** — every ``make_async_copy().start()`` must
  have a matching ``wait()`` somewhere in the kernel; unbalanced counts
  mean either a race (compute reads before the copy lands) or a hang
  (wait on a semaphore never signalled);
* **VMEM budget** — the per-tile footprint (VMEM block windows + VMEM
  scratch) must fit the configurable per-core budget (~16 MB on current
  TPUs); an oversized scratch request fails at compile time on hardware,
  which CI on CPU hosts would never see without this check.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.analysis.findings import Finding, Severity
from repro.analysis.rules import RuleContext, register_rule
from repro.analysis.walker import find_eqns, walk

_DMA_START = ("dma_start",)
_DMA_WAIT = ("dma_wait",)
# kernel operand spaces that do NOT occupy per-tile VMEM windows
_NON_VMEM_SPACES = ("any", "smem", "semaphore_mem", "hbm")


def _kernel_name(eqn) -> str:
    nsi = eqn.params.get("name_and_src_info")
    if nsi is not None:
        return str(nsi).split(" for ")[0] or "pallas_call"
    return eqn.params.get("name", "pallas_call")


def _block_shape(bm):
    bs = getattr(bm, "block_shape", None)
    if bs is None:
        return None
    return [d if isinstance(d, int) else None for d in bs]


def _array_shape(bm):
    sd = getattr(bm, "array_shape_dtype", None)
    return getattr(sd, "shape", None), getattr(sd, "dtype", None)


@register_rule(
    "pallas-grid-divisibility", Severity.WARNING,
    "every BlockSpec block shape divides its operand shape (no silent "
    "partial final tile)")
def pallas_grid_divisibility(closed_jaxpr, ctx: RuleContext) -> List[Finding]:
    out = []
    for site in find_eqns(closed_jaxpr, ("pallas_call",)):
        eqn = site.eqn
        gm = eqn.params.get("grid_mapping")
        if gm is None:
            continue
        kname = _kernel_name(eqn)
        if any(not isinstance(g, int) or g <= 0
               for g in getattr(gm, "grid", ())):
            out.append(Finding(
                rule="pallas-grid-divisibility", severity=Severity.WARNING,
                target=ctx.target, location=kname,
                message=f"kernel '{kname}': non-static/empty grid "
                        f"{gm.grid}"))
            continue
        for bm in getattr(gm, "block_mappings", ()):
            bs = _block_shape(bm)
            ashape, _ = _array_shape(bm)
            if bs is None or ashape is None or len(bs) != len(ashape):
                continue
            for dim, (b, a) in enumerate(zip(bs, ashape)):
                if b is None or b <= 0:
                    continue
                if a % b:
                    out.append(Finding(
                        rule="pallas-grid-divisibility",
                        severity=Severity.WARNING, target=ctx.target,
                        location=kname,
                        message=f"kernel '{kname}': block dim {dim} "
                                f"({b}) does not divide operand dim "
                                f"({a}) — final tile over-reads"))
    return out


@register_rule(
    "pallas-dma-pairing", Severity.ERROR,
    "every async-copy start has a matching wait in the kernel body "
    "(unbalanced counts = race or hang)")
def pallas_dma_pairing(closed_jaxpr, ctx: RuleContext) -> List[Finding]:
    out = []
    for site in find_eqns(closed_jaxpr, ("pallas_call",)):
        eqn = site.eqn
        kjaxpr = eqn.params.get("jaxpr")
        if kjaxpr is None:
            continue
        kname = _kernel_name(eqn)
        starts = sum(1 for s in walk(kjaxpr)
                     if s.eqn.primitive.name in _DMA_START)
        waits = sum(1 for s in walk(kjaxpr)
                    if s.eqn.primitive.name in _DMA_WAIT)
        if starts != waits:
            out.append(Finding(
                rule="pallas-dma-pairing", severity=Severity.ERROR,
                target=ctx.target, location=kname,
                message=f"kernel '{kname}': {starts} dma_start vs "
                        f"{waits} dma_wait — every started copy must be "
                        f"awaited (and vice versa)"))
    return out


@register_rule(
    "pallas-vmem-budget", Severity.WARNING,
    "per-tile VMEM footprint (block windows + scratch) fits the per-core "
    "budget")
def pallas_vmem_budget(closed_jaxpr, ctx: RuleContext) -> List[Finding]:
    out = []
    for site in find_eqns(closed_jaxpr, ("pallas_call",)):
        eqn = site.eqn
        kjaxpr = eqn.params.get("jaxpr")
        if kjaxpr is None:
            continue
        kname = _kernel_name(eqn)
        raw = getattr(kjaxpr, "jaxpr", kjaxpr)
        total = 0
        parts = []
        for var in raw.invars:
            aval = getattr(var, "aval", None)
            space = str(getattr(aval, "memory_space", None) or "vmem")
            shape = getattr(aval, "shape", None)
            dtype = getattr(aval, "dtype", None)
            if shape is None or dtype is None:
                continue
            if any(s in space for s in _NON_VMEM_SPACES):
                continue
            try:
                nbytes = int(jnp.dtype(dtype).itemsize)
            except TypeError:       # semaphores and friends
                continue
            for d in shape:
                nbytes *= int(d)
            total += nbytes
            parts.append(f"{tuple(shape)}:{nbytes}")
        if total > ctx.vmem_limit_bytes:
            out.append(Finding(
                rule="pallas-vmem-budget", severity=Severity.WARNING,
                target=ctx.target, location=kname,
                message=f"kernel '{kname}': per-tile VMEM estimate "
                        f"{total / 2**20:.2f} MiB exceeds budget "
                        f"{ctx.vmem_limit_bytes / 2**20:.2f} MiB "
                        f"({', '.join(parts[:6])})"))
    return out
