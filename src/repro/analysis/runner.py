"""Orchestrates every static pass into one :class:`Report`.

Passes (each individually skippable via ``skip``):

* ``jaxpr``    — the registered jaxpr rules over every canned hot-path
  target (decode / masked decode / kernel decode / extend / admission,
  per arch x policy);
* ``kernels``  — the same Pallas rules over the raw kernels at
  representative shapes;
* ``donation`` — engine buffer-donation audit (lowering-level aliasing);
* ``sharding`` — state-leaf layout-rule coverage + replicated-leaf audit;
* ``compiles`` — the O(buckets) bucketing contract via jit cache sizes.

A pass that crashes is recorded in ``report.errors`` (which also fails the
run) instead of killing the other passes — an analyzer that dies on rule 3
must not silently skip rules 4-7.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from repro.analysis import targets as TG
from repro.analysis.findings import Report
from repro.analysis.rules import RULES, run_jaxpr_rules
from repro.analysis.suppressions import SUPPRESSIONS

PASSES = ("jaxpr", "kernels", "donation", "sharding", "compiles")
AUDIT_RULES = ("donation", "sharding-audit", "compile-count")


def run_analysis(archs: Sequence[str] = TG.ARCHS,
                 policies: Sequence[str] = TG.POLICIES,
                 rules: Optional[Sequence[str]] = None,
                 skip: Sequence[str] = (),
                 vmem_limit_bytes: int = 16 * 2 ** 20,
                 suppressions=None,
                 verbose: bool = False) -> Report:
    report = Report()
    report.rules = sorted(RULES) + [r for r in AUDIT_RULES
                                    if r not in (skip or ())]
    unknown = set(skip) - set(PASSES)
    if unknown:
        report.errors.append(f"unknown --skip pass(es): {sorted(unknown)}; "
                             f"have {PASSES}")

    def note(msg):
        if verbose:
            print(f"[analysis] {msg}", flush=True)

    if "jaxpr" not in skip:
        try:
            jtargets = TG.build_jaxpr_targets(
                tuple(archs), tuple(policies),
                vmem_limit_bytes=vmem_limit_bytes)
        except Exception as e:
            jtargets = []
            report.errors.append(f"jaxpr target construction failed: {e!r}")
        for t in jtargets:
            note(f"lint {t.name}")
            report.targets.append(t.name)
            try:
                report.extend(run_jaxpr_rules(
                    t.closed_jaxpr, t.ctx,
                    rules=_select(rules, t.rules)))
            except Exception as e:
                report.errors.append(f"jaxpr rules failed on {t.name}: "
                                     f"{e!r}")

    if "kernels" not in skip:
        try:
            ktargets = TG.build_kernel_targets(
                vmem_limit_bytes=vmem_limit_bytes)
        except Exception as e:
            ktargets = []
            report.errors.append(f"kernel target construction failed: "
                                 f"{e!r}")
        for t in ktargets:
            note(f"lint {t.name}")
            report.targets.append(t.name)
            try:
                report.extend(run_jaxpr_rules(
                    t.closed_jaxpr, t.ctx,
                    rules=_select(rules, t.rules)))
            except Exception as e:
                report.errors.append(f"kernel rules failed on {t.name}: "
                                     f"{e!r}")

    if "donation" not in skip and _want(rules, "donation"):
        from repro.analysis.donation import audit_engine_donation
        from repro.serving import Engine
        for arch in archs:
            name = f"engine[{arch}/lychee]"
            note(f"donation audit {name}")
            report.targets.append(name)
            try:
                engine = Engine(TG.arch_config(arch), TG.arch_params(arch),
                                n_cache=TG.N_CACHE)
                report.extend(audit_engine_donation(engine, target=name))
            except Exception as e:
                report.errors.append(f"donation audit failed on {name}: "
                                     f"{e!r}")

    if "sharding" not in skip and _want(rules, "sharding-audit"):
        from repro.analysis.shardcheck import audit_state_sharding
        for arch in archs:
            for policy in policies:
                name = f"state[{arch}/{policy}]"
                note(f"sharding audit {name}")
                report.targets.append(name)
                try:
                    shapes = TG.state_shapes(arch, policy)
                    report.extend(audit_state_sharding(
                        shapes, target=name,
                        cache_elems=TG.cache_leaf_elems(shapes)))
                except Exception as e:
                    report.errors.append(f"sharding audit failed on "
                                         f"{name}: {e!r}")

    if "compiles" not in skip and _want(rules, "compile-count"):
        from repro.analysis.compiles import audit_compile_counts
        name = "compiles[gqa/lychee]"
        note(f"compile-count audit {name}")
        report.targets.append(name)
        try:
            report.extend(audit_compile_counts(target=name))
        except Exception as e:
            report.errors.append(f"compile-count audit failed: {e!r}")

    report.apply_suppressions(
        SUPPRESSIONS if suppressions is None else suppressions)
    return report


def _select(cli_rules: Optional[Sequence[str]],
            target_rules: Optional[Tuple[str, ...]]):
    """Intersect the CLI rule selection with a target's own rule scope."""
    if cli_rules is None:
        return target_rules
    if target_rules is None:
        return list(cli_rules)
    return [r for r in cli_rules if r in target_rules]


def _want(cli_rules: Optional[Sequence[str]], rule: str) -> bool:
    return cli_rules is None or rule in cli_rules
