"""Named jaxpr lint rules over the decode/extend/admission hot paths.

Each rule is a function ``(closed_jaxpr, ctx) -> [Finding]`` registered with
a name, default severity and a one-line contract statement. Rules operate on
:mod:`repro.analysis.walker` equation sites, so one traced jaxpr is walked
once per rule with no model re-execution.

The size contract: ``ctx.cache_elems`` is the element count of ONE KV-cache
leaf ``(B, Hkv, N, d)`` of the analyzed state — "cache-sized" means an array
at least that big. Anything cache-sized materialized per decode step turns
the O(budget) sparse path back into an O(context) one, which is exactly the
class of regression (the pre-PR-3 per-token ``jnp.pad``) these rules fence.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp

from repro.analysis.findings import Finding, Severity
from repro.analysis.walker import (EqnSite, aval_size, describe_eqn,
                                   eqn_location, max_out_size, walk)


@dataclasses.dataclass
class RuleContext:
    """What a jaxpr rule needs to know about the target under analysis."""

    target: str                   # e.g. "decode[gqa/lychee]"
    cache_elems: int = 0          # elements of one (B,Hkv,N,d) cache leaf
    cache_dtype: object = None    # the bulk cache dtype (e.g. bfloat16)
    vmem_limit_bytes: int = 16 * 2 ** 20   # per-core VMEM budget (TPU ~16MB)


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    severity: Severity
    doc: str
    fn: Callable[[object, RuleContext], List[Finding]]

    def run(self, closed_jaxpr, ctx: RuleContext) -> List[Finding]:
        return self.fn(closed_jaxpr, ctx)


RULES: Dict[str, Rule] = {}


def register_rule(name: str, severity: Severity, doc: str):
    def deco(fn):
        RULES[name] = Rule(name, severity, doc, fn)
        return fn
    return deco


def get_rule(name: str) -> Rule:
    if name not in RULES:
        raise KeyError(f"unknown rule {name!r}; have {sorted(RULES)}")
    return RULES[name]


def run_jaxpr_rules(closed_jaxpr, ctx: RuleContext,
                    rules: Optional[List[str]] = None) -> List[Finding]:
    """Run every (selected) registered jaxpr rule over one traced jaxpr."""
    out: List[Finding] = []
    for name, rule in RULES.items():
        if rules is not None and name not in rules:
            continue
        out.extend(rule.run(closed_jaxpr, ctx))
    return out


def _finding(rule: str, sev: Severity, ctx: RuleContext, site: EqnSite,
             msg: str) -> Finding:
    return Finding(rule=rule, severity=sev, target=ctx.target,
                   message=f"{msg}: {describe_eqn(site.eqn)}",
                   location=eqn_location(site.eqn))


# ---------------------------------------------------------------------------
# Rule 1: no cache-sized materialization on the decode hot path
# ---------------------------------------------------------------------------
# pad/concatenate/copy re-create the whole cache; a cache-sized gather is a
# token-scatter design leaking back in; a cache-sized dynamic_slice is a
# whole-cache read-out. The per-step cache APPEND is dynamic_update_slice
# (aliasable in-place by XLA) and deliberately not listed.
_MATERIALIZE_PRIMS = ("pad", "concatenate", "copy", "gather", "dynamic_slice")


@register_rule(
    "no-cache-materialization", Severity.ERROR,
    "no pad/concatenate/copy/gather/dynamic_slice result as large as the "
    "KV cache inside a jitted decode/extend/admission step")
def no_cache_materialization(closed_jaxpr, ctx: RuleContext) -> List[Finding]:
    if not ctx.cache_elems:
        return []
    out = []
    for site in walk(closed_jaxpr):
        if site.eqn.primitive.name not in _MATERIALIZE_PRIMS:
            continue
        if site.in_pallas:
            # kernel bodies address refs/scratch; the wrapper-level pad of
            # the (B,H,C) span table is what reaches here, never the cache
            continue
        n = max_out_size(site.eqn)
        if n >= ctx.cache_elems:
            out.append(_finding(
                "no-cache-materialization", Severity.ERROR, ctx, site,
                f"{site.eqn.primitive.name} materializes a cache-sized "
                f"({n} elems >= {ctx.cache_elems}) array per step"))
    return out


# ---------------------------------------------------------------------------
# Rule 2: no host syncs / callbacks inside the fused decode step
# ---------------------------------------------------------------------------
# Any of these forces a device->host round trip (or a host-side Python
# callback) per decode token, serializing the dispatch pipeline the engine
# worked to keep at one launch per token.
_HOST_SYNC_PRIMS = (
    "pure_callback", "io_callback", "python_callback", "callback",
    "debug_callback", "debug_print", "infeed", "outfeed",
    "host_local_array_to_global_array", "global_array_to_host_local_array",
)


@register_rule(
    "no-host-callback", Severity.ERROR,
    "no host callbacks / infeed / debug prints traced into the fused "
    "decode step (one device dispatch per token, no host syncs)")
def no_host_callback(closed_jaxpr, ctx: RuleContext) -> List[Finding]:
    out = []
    for site in walk(closed_jaxpr):
        if site.eqn.primitive.name in _HOST_SYNC_PRIMS:
            out.append(_finding(
                "no-host-callback", Severity.ERROR, ctx, site,
                f"host-sync primitive '{site.eqn.primitive.name}' on the "
                f"hot path"))
    return out


# ---------------------------------------------------------------------------
# Rule 3: dtype discipline for bulk tensors
# ---------------------------------------------------------------------------
@register_rule(
    "dtype-discipline", Severity.WARNING,
    "no silent fp32 (or wider) upcast of cache-sized bulk tensors outside "
    "kernel accumulators — bf16 KV halves the dominant decode collective")
def dtype_discipline(closed_jaxpr, ctx: RuleContext) -> List[Finding]:
    if not ctx.cache_elems or ctx.cache_dtype is None:
        return []
    if jnp.dtype(ctx.cache_dtype).itemsize >= 4:
        return []                  # f32 cache: nothing to upcast from
    out = []
    for site in walk(closed_jaxpr):
        eqn = site.eqn
        if eqn.primitive.name != "convert_element_type":
            continue
        if site.in_pallas:
            continue               # in-kernel f32 accumulators are the norm
        new_dtype = eqn.params.get("new_dtype")
        if new_dtype is None or jnp.dtype(new_dtype).itemsize < 4:
            continue
        src = eqn.invars[0]
        src_dt = getattr(getattr(src, "aval", None), "dtype", None)
        if src_dt is None or jnp.dtype(src_dt).itemsize >= 4:
            continue
        if not jnp.issubdtype(jnp.dtype(new_dtype), jnp.floating):
            continue
        n = aval_size(src)
        if n >= ctx.cache_elems:
            out.append(_finding(
                "dtype-discipline", Severity.WARNING, ctx, site,
                f"bulk {src_dt} -> {jnp.dtype(new_dtype).name} upcast of "
                f"{n} elems (>= cache size {ctx.cache_elems}) outside a "
                f"kernel accumulator"))
    return out
