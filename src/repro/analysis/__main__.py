"""``python -m repro.analysis`` — the static hot-path analyzer CLI.

Runs every registered rule and audit over the canned decode / extend /
chunked-admission targets, writes JSON / markdown artifacts, and exits
nonzero when any non-suppressed finding at or above ``--fail-on`` (or any
analyzer error) is present.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import runner as RN
from repro.analysis import targets as TG
from repro.analysis.findings import Severity
from repro.analysis.rules import RULES


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static hot-path analyzer: jaxpr lint + Pallas checks "
                    "+ donation/sharding/compile audits")
    p.add_argument("--fail-on", default="warning",
                   choices=[s.name.lower() for s in Severity],
                   help="minimum severity that fails the run "
                        "(default: warning)")
    p.add_argument("--json", metavar="PATH", default=None,
                   help="write the JSON report here")
    p.add_argument("--markdown", metavar="PATH", default=None,
                   help="write the markdown report here")
    p.add_argument("--archs", nargs="+", default=list(TG.ARCHS),
                   choices=list(TG.ARCHS))
    p.add_argument("--policies", nargs="+", default=list(TG.POLICIES),
                   choices=list(TG.POLICIES))
    p.add_argument("--rules", nargs="+", default=None,
                   metavar="RULE",
                   help=f"run only these rules (default: all). Known: "
                        f"{sorted(RULES) + list(RN.AUDIT_RULES)}")
    p.add_argument("--skip", nargs="+", default=[], metavar="PASS",
                   help=f"skip whole passes; one of {RN.PASSES}")
    p.add_argument("--vmem-limit-mb", type=float, default=16.0,
                   help="per-core VMEM budget for the Pallas scratch check "
                        "(default: 16)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule registry and exit")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="print per-target progress")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for name, rule in sorted(RULES.items()):
            print(f"{name:28s} [{rule.severity.name.lower():7s}] "
                  f"{rule.doc}")
        for name in RN.AUDIT_RULES:
            print(f"{name:28s} [audit  ] standalone audit pass")
        return 0

    known = set(RULES) | set(RN.AUDIT_RULES)
    if args.rules:
        bad = set(args.rules) - known
        if bad:
            print(f"unknown rule(s) {sorted(bad)}; known: {sorted(known)}",
                  file=sys.stderr)
            return 2

    fail_on = Severity.parse(args.fail_on)
    report = RN.run_analysis(
        archs=args.archs, policies=args.policies, rules=args.rules,
        skip=args.skip,
        vmem_limit_bytes=int(args.vmem_limit_mb * 2 ** 20),
        verbose=args.verbose)

    if args.json:
        with open(args.json, "w") as f:
            f.write(report.to_json(fail_on))
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(report.to_markdown(fail_on))

    c = report.counts()
    for f in report.findings:
        print(f)
    for e in report.errors:
        print(f"analyzer-error: {e}", file=sys.stderr)
    active = report.active(fail_on)
    print(f"repro.analysis: {len(report.targets)} targets, "
          f"{len(report.rules)} rules — {c['error']} error / "
          f"{c['warning']} warning / {c['note']} note / "
          f"{c['suppressed']} suppressed; fail-on={fail_on.name.lower()} "
          f"-> {'FAIL' if active or report.errors else 'OK'}")
    return 1 if (active or report.errors) else 0


if __name__ == "__main__":
    sys.exit(main())
