"""Finding / severity / report containers for the static hot-path analyzer.

A :class:`Finding` is one rule violation at one location inside one analysis
*target* (a traced jaxpr, a compiled engine function, a state pytree, ...).
Findings carry the rule name, a severity, and a free-form location string so
``--fail-on`` gating, JSON artifacts and the markdown report all read off the
same objects.

Suppressions are *explicit and reasoned*: a :class:`Suppression` matches
(rule, target, substring) and MUST carry a reason string — a matched finding
is kept in the report (marked suppressed) but never counts toward the exit
code, so every intentional contract exception stays visible.
"""
from __future__ import annotations

import dataclasses
import enum
import json
from typing import Dict, List, Sequence


class Severity(enum.IntEnum):
    """Ordered so ``--fail-on warning`` means ``severity >= WARNING``."""

    NOTE = 0
    WARNING = 1
    ERROR = 2

    @classmethod
    def parse(cls, name: str) -> "Severity":
        try:
            return cls[name.upper()]
        except KeyError:
            raise ValueError(
                f"unknown severity {name!r}; use one of "
                f"{[s.name.lower() for s in cls]}") from None


@dataclasses.dataclass
class Finding:
    rule: str                     # registry name, e.g. "no-cache-materialization"
    severity: Severity
    target: str                   # e.g. "decode[gqa/lychee]"
    message: str                  # what violated the contract
    location: str = ""            # source line / eqn summary / leaf path
    suppressed: bool = False
    suppress_reason: str = ""

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["severity"] = self.severity.name.lower()
        return d

    def __str__(self) -> str:
        sup = f" [suppressed: {self.suppress_reason}]" if self.suppressed \
            else ""
        loc = f" @ {self.location}" if self.location else ""
        return (f"{self.severity.name.lower():7s} {self.rule} "
                f"({self.target}): {self.message}{loc}{sup}")


@dataclasses.dataclass(frozen=True)
class Suppression:
    """An intentional, documented exception to a rule.

    ``rule`` matches exactly; ``target``/``match`` are substring matches
    against ``Finding.target`` and ``Finding.message + location`` (empty =
    match everything). ``reason`` is mandatory — a suppression without a
    why is a lie to the next reader.
    """

    rule: str
    reason: str
    target: str = ""
    match: str = ""

    def __post_init__(self):
        assert self.reason.strip(), "suppressions must carry a reason"

    def matches(self, f: Finding) -> bool:
        return (f.rule == self.rule
                and self.target in f.target
                and self.match in (f.message + " " + f.location))


@dataclasses.dataclass
class Report:
    """The analyzer's output: findings + the rule/target coverage that
    produced them (so "zero findings" is distinguishable from "didn't
    run")."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    targets: List[str] = dataclasses.field(default_factory=list)
    rules: List[str] = dataclasses.field(default_factory=list)
    errors: List[str] = dataclasses.field(default_factory=list)

    def extend(self, findings: Sequence[Finding]) -> None:
        self.findings.extend(findings)

    def apply_suppressions(self, sups: Sequence[Suppression]) -> None:
        for f in self.findings:
            if f.suppressed:
                continue
            for s in sups:
                if s.matches(f):
                    f.suppressed = True
                    f.suppress_reason = s.reason
                    break

    def active(self, fail_on: Severity = Severity.WARNING) -> List[Finding]:
        """Findings that count toward the exit code."""
        return [f for f in self.findings
                if not f.suppressed and f.severity >= fail_on]

    def counts(self) -> Dict[str, int]:
        out = {s.name.lower(): 0 for s in Severity}
        out["suppressed"] = 0
        for f in self.findings:
            if f.suppressed:
                out["suppressed"] += 1
            else:
                out[f.severity.name.lower()] += 1
        return out

    # ------------------------------------------------------------------
    def to_json(self, fail_on: Severity = Severity.WARNING) -> str:
        return json.dumps({
            "counts": self.counts(),
            "fail_on": fail_on.name.lower(),
            "failed": bool(self.active(fail_on)) or bool(self.errors),
            "targets": self.targets,
            "rules": self.rules,
            "errors": self.errors,
            "findings": [f.to_dict() for f in self.findings],
        }, indent=2)

    def to_markdown(self, fail_on: Severity = Severity.WARNING) -> str:
        c = self.counts()
        lines = ["# Static hot-path analysis", ""]
        lines.append(
            f"**{c['error']} error / {c['warning']} warning / "
            f"{c['note']} note / {c['suppressed']} suppressed** over "
            f"{len(self.targets)} targets x {len(self.rules)} rules "
            f"(fail-on: {fail_on.name.lower()})")
        lines.append("")
        if self.errors:
            lines.append("## Analyzer errors")
            lines += [f"- `{e}`" for e in self.errors] + [""]
        live = [f for f in self.findings if not f.suppressed]
        if live:
            lines.append("## Findings")
            lines.append("| severity | rule | target | message | location |")
            lines.append("|---|---|---|---|---|")
            for f in sorted(live, key=lambda f: -f.severity):
                lines.append(
                    f"| {f.severity.name.lower()} | `{f.rule}` | "
                    f"{f.target} | {f.message} | `{f.location}` |")
            lines.append("")
        sup = [f for f in self.findings if f.suppressed]
        if sup:
            lines.append("## Suppressed (intentional, reasoned)")
            for f in sup:
                lines.append(f"- `{f.rule}` ({f.target}): {f.message} — "
                             f"*{f.suppress_reason}*")
            lines.append("")
        if not live and not sup and not self.errors:
            lines.append("No findings: every checked contract holds.")
        lines.append("### Targets")
        lines += [f"- `{t}`" for t in self.targets]
        return "\n".join(lines) + "\n"
