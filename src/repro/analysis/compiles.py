"""Compile-count audit: PR 5's O(buckets) bucketing contract, machine-checked.

The serving engine promises that admission and extend compile once per
pow2 prompt-length *bucket*, never once per distinct prompt length — the
difference between a handful of XLA compiles at serve start and an
unbounded compile stall every time a new prompt length shows up.

The audit replays two canned traces on a reduced-config engine (real
execution, tiny weights, CPU-fast) and reads the jit caches back through
``_cache_size()``:

* six prompts across three pow2 buckets -> ``_prefill_slot_b`` must hold
  exactly ``n_buckets`` entries, and a verbatim replay must add zero;
* two long chunked admissions (chunk 16, tails both bucketing to 16) ->
  ``_extend_slot_nu`` must hold at most 2 shapes (full chunk + one tail
  bucket).
"""
from __future__ import annotations

import copy
from typing import List

import jax
import numpy as np

from repro.analysis.findings import Finding, Severity
from repro.configs.base import LycheeConfig, get_config
from repro.models import model as MD
from repro.serving import Engine, Request

N_CACHE = 192


def _cfg(chunk: int):
    ly = LycheeConfig(policy="lychee", enabled=True, budget=64, sink=4,
                      buffer_size=16, max_coarse=8, top_kg=4,
                      full_attn_layers=0)
    cfg = get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=ly)
    return cfg.replace(serving=cfg.serving.replace(prefill_chunk=chunk))


def audit_compile_counts(*, target: str = "compiles[gqa/lychee]"
                         ) -> List[Finding]:
    out: List[Finding] = []
    cfg = _cfg(chunk=512)
    params = MD.init_model(jax.random.key(0), cfg)
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(9)
    lens = [20, 28, 40, 52, 60, 100]
    trace = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, size=(s,)).astype(np.int32), max_new=2)
        for i, s in enumerate(lens)]
    engine.serve(copy.deepcopy(trace), n_slots=2)
    n_buckets = len({engine._pad_shape(s, engine.usable) for s in lens})
    got = engine._prefill_slot_b._cache_size()
    if got > n_buckets:
        out.append(Finding(
            rule="compile-count", severity=Severity.ERROR, target=target,
            location="_prefill_slot_b",
            message=f"admission compiled {got} shapes for "
                    f"{len(lens)} prompts spanning {n_buckets} pow2 "
                    f"buckets — bucketing no longer bounds compiles"))
    engine.serve(copy.deepcopy(trace), n_slots=2)
    got2 = engine._prefill_slot_b._cache_size()
    if got2 > got:
        out.append(Finding(
            rule="compile-count", severity=Severity.ERROR, target=target,
            location="_prefill_slot_b",
            message=f"replaying an identical trace added "
                    f"{got2 - got} admission compiles — shapes are not "
                    f"cache-stable across serves"))

    cfg_c = _cfg(chunk=16)
    chunked = Engine(cfg_c, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(13)
    for i, s in enumerate((70, 86)):       # tails 6 and 6 -> one 16-bucket
        chunked.serve([Request(uid=i, prompt=rng.integers(
            0, cfg_c.vocab, size=(s,)).astype(np.int32), max_new=2)],
            n_slots=1)
    got = chunked._extend_slot_nu._cache_size()
    if got > 2:
        out.append(Finding(
            rule="compile-count", severity=Severity.ERROR, target=target,
            location="_extend_slot_nu",
            message=f"chunked admission compiled {got} extend shapes; the "
                    f"contract is <= 2 (full-chunk shape + one pow2 tail "
                    f"bucket)"))
    return out
