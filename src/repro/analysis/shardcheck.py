"""Sharding audit: every decode-state leaf must match a layout rule.

``repro.sharding.rules`` maps state-leaf names to PartitionSpecs
(``_STATE_LAYOUTS``). A leaf that no rule covers silently falls back to
replication — fine for a scalar clock, catastrophic for a KV cache leaf
(every device holds the full context). This audit builds the state shape
tree for each (arch, policy), resolves specs against an abstract 2x2
data-by-model mesh (no devices needed), and flags:

* **unruled leaves** — a leaf name absent from ``_STATE_LAYOUTS`` (new
  policy state that nobody thought about sharding);
* **large replicated leaves** — a cache-sized leaf whose resolved spec
  has no sharded dimension.
"""
from __future__ import annotations

from typing import List

import jax

from repro.analysis.findings import Finding, Severity
from repro.sharding import rules as SH


def _abstract_mesh():
    try:
        return jax.sharding.AbstractMesh((2, 2), ("data", "model"))
    except TypeError:        # pragma: no cover - older AbstractMesh API
        return jax.sharding.AbstractMesh(
            (("data", 2), ("model", 2)))


def _is_replicated(spec) -> bool:
    return all(ax is None for ax in tuple(spec))


def audit_state_sharding(state_shapes, *, target: str,
                         cache_elems: int) -> List[Finding]:
    """``state_shapes`` is a ShapeDtypeStruct pytree of the decode state."""
    out: List[Finding] = []
    mesh = _abstract_mesh()
    try:
        specs = SH.decode_state_specs(state_shapes, mesh,
                                      ("data",), ("model",))
    except Exception as e:
        out.append(Finding(
            rule="sharding-audit", severity=Severity.ERROR, target=target,
            location="decode_state_specs",
            message=f"decode_state_specs failed on this state tree: {e!r}"))
        return out

    leaves, _ = jax.tree_util.tree_flatten_with_path(state_shapes)
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    if len(spec_leaves) != len(leaves):
        out.append(Finding(
            rule="sharding-audit", severity=Severity.ERROR, target=target,
            location="decode_state_specs",
            message=f"spec tree has {len(spec_leaves)} leaves but state has "
                    f"{len(leaves)} — trees diverged"))
        return out

    for (path, leaf), spec in zip(leaves, spec_leaves):
        name = SH._path_name(path)
        pretty = jax.tree_util.keystr(path)
        if name not in SH._STATE_LAYOUTS and name != "n":
            out.append(Finding(
                rule="sharding-audit", severity=Severity.WARNING,
                target=target, location=pretty,
                message=f"state leaf '{name}' ({pretty}, shape "
                        f"{tuple(leaf.shape)}) has no layout rule in "
                        f"sharding/rules.py — it will be replicated on "
                        f"every device"))
            continue
        n = 1
        for d in leaf.shape:
            n *= int(d)
        if cache_elems and n >= cache_elems and _is_replicated(spec):
            out.append(Finding(
                rule="sharding-audit", severity=Severity.WARNING,
                target=target, location=pretty,
                message=f"cache-sized leaf '{name}' ({pretty}, "
                        f"{n} elems) resolves to a fully replicated spec "
                        f"— every device holds the whole array"))
    return out
