"""Donation audit: every engine jit that threads slot state must donate it.

A decode step that does NOT donate its state argument forces XLA to keep
two full copies of every KV cache alive across the dispatch — at serving
shapes that is a double-buffered multi-GiB allocation per device, the exact
failure mode the engine's ``donate_argnums`` exist to prevent.

The audit lowers each state-threading jit of a real :class:`~repro.serving.
engine.Engine` (lowering only — nothing executes, so it runs on CPU CI) and
inspects the buffer-donation aliasing jax records in the stablehlo module
(``tf.aliasing_output`` input attributes): zero aliased inputs means the
state is not donated at all (ERROR); fewer aliased inputs than state leaves
means some buffers silently fell out of the aliasing (WARNING). For the
leanest step function the compiled executable's ``memory_analysis()`` is
additionally checked: the aliased bytes must cover the KV cache leaves.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding, Severity

_ALIAS_ATTR = "tf.aliasing_output"


def _count_aliased(lowered) -> int:
    return lowered.as_text().count(_ALIAS_ATTR)


def _state_leaf_stats(state) -> tuple:
    leaves = [l for l in jax.tree.leaves(state) if hasattr(l, "nbytes")]
    return len(leaves), int(sum(l.nbytes for l in leaves))


def _cache_bytes(state) -> int:
    total = 0
    for cache in state["groups"]:
        if isinstance(cache, dict):
            for name in ("k", "v", "latent"):
                if name in cache:
                    total += int(cache[name].nbytes)
    return total


def audit_engine_donation(engine, *, target: str, n_slots: int = 2,
                          compile_check: bool = True) -> List[Finding]:
    """Audit every state-threading jit of ``engine``. ``target`` labels the
    findings (e.g. "engine[gqa/lychee]")."""
    out: List[Finding] = []
    state = engine._zero_state(n_slots)
    n_leaves, state_bytes = _state_leaf_stats(state)
    p = engine.params
    tok = jnp.zeros((n_slots,), jnp.int32)
    keep = np.ones((n_slots,), bool)
    cap = jnp.zeros((n_slots,), jnp.int32)
    base = jax.random.key(0)
    uid = jnp.zeros((n_slots,), jnp.int32)
    step = jnp.zeros((n_slots,), jnp.int32)
    temp = jnp.zeros((n_slots,), jnp.float32)
    top_k = jnp.zeros((n_slots,), jnp.int32)
    top_p = jnp.ones((n_slots,), jnp.float32)
    prompt = jnp.zeros((1, 32), jnp.int32)
    n_valid = jnp.int32(24)
    slot = jnp.int32(0)

    # (attr, args) for every jit that takes the batched slot state and
    # returns an updated one — each must donate the state buffers
    cases = [
        ("_step", (p, tok, state)),
        ("_step_greedy", (p, tok, state)),
        ("_step_sampled", (p, tok, state, base, uid, step, temp, top_k,
                           top_p)),
        ("_step_greedy_m", (p, tok, state, keep)),
        ("_step_sampled_m", (p, tok, state, keep, base, uid, step, temp,
                             top_k, top_p)),
        # SLO degraded-budget variants (cap: per-slot retrieval budgets)
        ("_step_greedy_d", (p, tok, state, cap)),
        ("_step_sampled_d", (p, tok, state, cap, base, uid, step, temp,
                             top_k, top_p)),
        ("_step_greedy_md", (p, tok, state, keep, cap)),
        ("_step_sampled_md", (p, tok, state, keep, cap, base, uid, step,
                              temp, top_k, top_p)),
        ("_prefill_slot", (p, prompt, state, slot)),
        ("_extend_slot", (p, prompt, state, slot)),
    ]
    if getattr(engine, "can_pad", False):
        cases += [
            ("_prefill_slot_b", (p, prompt, n_valid, state, slot)),
            ("_prefill_slot_nb", (p, prompt, n_valid, state, slot)),
            ("_extend_slot_u", (p, prompt, n_valid, state, slot)),
            ("_extend_slot_nu", (p, prompt, n_valid, state, slot)),
            ("_rebuild_slot", (p, prompt, n_valid, state, slot)),
        ]

    for attr, args in cases:
        fn = getattr(engine, attr, None)
        if fn is None:
            continue
        try:
            lowered = fn.lower(*args)
        except Exception as e:       # pragma: no cover - trace failure
            out.append(Finding(
                rule="donation", severity=Severity.ERROR, target=target,
                location=attr,
                message=f"could not lower engine jit '{attr}': {e!r}"))
            continue
        n_aliased = _count_aliased(lowered)
        if n_aliased == 0:
            out.append(Finding(
                rule="donation", severity=Severity.ERROR, target=target,
                location=attr,
                message=f"engine jit '{attr}' threads the slot state but "
                        f"donates NO buffers ({n_leaves} state leaves, "
                        f"{state_bytes / 2**20:.1f} MiB live twice per "
                        f"dispatch)"))
        elif n_aliased < n_leaves:
            out.append(Finding(
                rule="donation", severity=Severity.WARNING, target=target,
                location=attr,
                message=f"engine jit '{attr}' aliases only {n_aliased} of "
                        f"{n_leaves} state buffers — the rest are "
                        f"double-buffered across the dispatch"))

    if compile_check:
        try:
            compiled = engine._step_greedy.lower(p, tok, state).compile()
            ma = compiled.memory_analysis()
            aliased = int(getattr(ma, "alias_size_in_bytes", 0))
            need = _cache_bytes(state)
            if aliased < need:
                out.append(Finding(
                    rule="donation", severity=Severity.WARNING,
                    target=target, location="_step_greedy",
                    message=f"compiled decode step aliases "
                            f"{aliased / 2**20:.1f} MiB < KV cache "
                            f"{need / 2**20:.1f} MiB — cache is "
                            f"double-buffered"))
        except Exception as e:
            out.append(Finding(
                rule="donation", severity=Severity.NOTE, target=target,
                location="_step_greedy",
                message=f"memory_analysis unavailable ({e!r}); "
                        f"lowering-level aliasing checks still ran"))
    return out
