"""Reusable jaxpr walker — THE one implementation (generalized from the
ad-hoc ``_all_eqns``/``_subjaxprs`` pair that used to live in
``tests/test_decode_fused.py``; that test now imports from here).

Walks every equation of a (closed) jaxpr including all nested sub-jaxprs
(pjit bodies, scan/while/cond branches, custom_* calls, pallas_call
kernels), and attaches the *path* of enclosing primitives so rules can
report "gather inside scan inside pjit" and distinguish a convert in a
Pallas kernel body from one on the XLA hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence, Tuple

import jax

try:                                    # jax >= 0.4.16
    from jax.extend.core import ClosedJaxpr as _ClosedJaxpr
except ImportError:                     # pragma: no cover - older jax
    from jax.core import ClosedJaxpr as _ClosedJaxpr


@dataclasses.dataclass(frozen=True)
class EqnSite:
    """One equation + where it sits: the chain of enclosing primitive names
    (outermost first). ``in_pallas`` marks eqns inside a pallas_call kernel
    body — their memory model (VMEM scratch, f32 accumulators) is exempt
    from several XLA-hot-path rules."""

    eqn: object
    path: Tuple[str, ...]

    @property
    def in_pallas(self) -> bool:
        return "pallas_call" in self.path


def subjaxprs(val) -> Iterator[object]:
    """Yield every (raw) jaxpr reachable from one eqn-param value."""
    if isinstance(val, _ClosedJaxpr):
        yield val.jaxpr
    elif hasattr(val, "eqns"):          # raw Jaxpr
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from subjaxprs(v)


def all_eqns(jaxpr) -> Iterator[object]:
    """Every eqn of ``jaxpr`` (a raw Jaxpr) and all nested sub-jaxprs.
    The drop-in replacement for the old test-local ``_all_eqns``."""
    for site in walk(jaxpr):
        yield site.eqn


def walk(jaxpr, path: Tuple[str, ...] = ()) -> Iterator[EqnSite]:
    """``all_eqns`` with enclosing-primitive paths (outermost first)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)      # accept ClosedJaxpr too
    for eqn in jaxpr.eqns:
        yield EqnSite(eqn, path)
        sub_path = path + (eqn.primitive.name,)
        for val in eqn.params.values():
            for sub in subjaxprs(val):
                yield from walk(sub, sub_path)


def find_eqns(jaxpr, names: Sequence[str]) -> Iterator[EqnSite]:
    names = set(names)
    for site in walk(jaxpr):
        if site.eqn.primitive.name in names:
            yield site


def aval_size(var) -> int:
    """Element count of a var's aval (0 when shapeless/abstract-token)."""
    aval = getattr(var, "aval", None)
    if aval is None or not hasattr(aval, "shape"):
        return 0
    n = 1
    for d in aval.shape:
        if not isinstance(d, int):      # dynamic dim: treat as unsized
            return 0
        n *= d
    return n


def aval_bytes(var) -> int:
    aval = getattr(var, "aval", None)
    if aval is None or not hasattr(aval, "dtype"):
        return 0
    return aval_size(var) * aval.dtype.itemsize


def max_out_size(eqn) -> int:
    return max((aval_size(v) for v in eqn.outvars), default=0)


def eqn_location(eqn) -> str:
    """Best-effort source location of an eqn (file:line of the deepest
    user frame), falling back to a compact eqn summary."""
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            fname = frame.file_name.rsplit("/", 1)[-1]
            return f"{fname}:{frame.start_line}"
    except Exception:
        pass
    return eqn.primitive.name


def describe_eqn(eqn, max_len: int = 120) -> str:
    s = str(eqn).replace("\n", " ")
    return s if len(s) <= max_len else s[:max_len - 3] + "..."
