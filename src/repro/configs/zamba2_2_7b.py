"""Zamba2-2.7B [arXiv:2411.15242].

54 Mamba2 blocks with a single *shared* attention+MLP transformer block
interleaved every 6th position (weights shared across all invocations).
d_model 2560, 32 heads, d_ff 10240, ssm_state 64, vocab 32000.

Hybrid: LycheeCluster manages the shared attention block's KV caches; the
Mamba2 state is O(1) natively.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        arch_type="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab=32_000,
        head_dim=80,
        prelude=("mamba",) * 5 + ("shared_attn",),
        pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
        ssm_state=64,
        ssm_heads=80,            # (2*2560)/64 headdim -> 80 heads of 64
        ssm_expand=2,
        conv_width=4,
        shared_attn_every=6,
        lychee=LycheeConfig(full_attn_layers=1),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab=512, prelude=(), pattern=("mamba", "shared_attn"),
        ssm_state=16, ssm_heads=8, lychee=LycheeConfig(
            budget=128, sink=4, buffer_size=16, max_coarse=8,
            full_attn_layers=0),
    )


register("zamba2-2.7b", full, reduced)
