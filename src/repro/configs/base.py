"""Model configuration system.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` that
builds a :class:`ModelConfig` with the exact published shape, plus a
``reduced()`` variant (≤2 layers, d_model ≤ 512, ≤4 experts) used by the CPU
smoke tests. Configs are registered by id and selectable via ``--arch``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by repro.models.model
# ---------------------------------------------------------------------------
# "attn"        : global causal self-attention + gated MLP
# "attn_local"  : sliding-window causal self-attention + gated MLP
# "mla"         : DeepSeek multi-head latent attention + dense MLP
# "mla_moe"     : MLA + MoE FFN
# "swa_moe"     : sliding-window attention + MoE FFN
# "mamba"       : Mamba2 SSM block
# "shared_attn" : zamba2-style shared transformer block (weights shared
#                 across groups; passed as scan closure constants)
# "mlstm"/"slstm": xLSTM blocks
# "enc_attn"    : bidirectional encoder attention + MLP (whisper encoder)
# "dec_cross"   : decoder self-attn + cross-attn + MLP (whisper decoder)


@dataclasses.dataclass(frozen=True)
class LycheeConfig:
    """Hyper-parameters of the paper's technique (§4, App. A) plus the
    cache-management policy selection (``core/policy.py`` registry)."""

    enabled: bool = True          # False forces the "dense" policy
    policy: str = "lychee"        # cache policy: lychee | quest | clusterkv
                                  # | streaming | dense (core.policy registry)
    min_chunk: int = 8            # minimum chunk length before delimiter search
    max_chunk: int = 16           # forced split threshold
    buffer_size: int = 128        # decode-time recent-token buffer
    sink: int = 16                # attention-sink tokens always kept
    budget: int = 1024            # retrieved token budget
    avg_chunks_per_cluster: int = 2
    max_coarse: int = 64          # P <= 64 coarse units
    kmeans_iters: int = 10
    top_kg: int = 8               # coarse units kept
    full_attn_layers: int = 2     # first N layers keep full attention
    child_cap: int = 8            # static max fine clusters per coarse unit
    chunk_cap: int = 6            # CC: static max member chunks per fine
                                  # cluster (capacity-planning source of truth)
    pooling: str = "mean"         # "mean" | "max" (Table 3 ablation)
    use_kernel: Optional[bool] = None
                                  # Pallas sparse-attention span executor.
                                  # None (default) = backend-aware: the
                                  # single-dispatch compiled kernel on TPU,
                                  # the pure-jnp oracle elsewhere. True
                                  # forces the kernel (interpret mode off-
                                  # TPU — how tests validate it); False
                                  # forces the jnp path everywhere.

    # --- baseline-policy knobs (core/policy.py) ----------------------------
    quest_page: int = 16          # Quest: fixed page size
    ckv_tokens_per_cluster: int = 32   # ClusterKV: cluster granularity
    ckv_cap_factor: int = 4       # ClusterKV: member-list cap multiplier

    def replace(self, **kw) -> "LycheeConfig":
        return dataclasses.replace(self, **kw)

    def top_kc(self, budget: Optional[int] = None) -> int:
        """Fine clusters kept so that selected tokens ≈ budget."""
        b = self.budget if budget is None else budget
        # each cluster holds ~avg_chunks_per_cluster chunks of <= max_chunk
        per_cluster = self.avg_chunks_per_cluster * self.max_chunk
        return max(1, b // per_cluster)


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Latency-SLO scheduling + overload-degradation knobs of the serving
    engine (``serving.engine`` / ``serving.scheduler``).

    With ``enabled`` the scheduler replaces blind FIFO by deadline-ordered
    admission over (priority, arrival + TTFT target) and the engine runs a
    three-stage degradation ladder under overload (queue depth past
    ``queue_high``, projected head TTFT past ``ttft_target_s``, or paged-
    pool free fraction under ``pool_low_frac``):

    1. **budget shrink** (``degrade_budget``, opt-in — bit-exactness of the
       affected slots is deliberately traded and recorded per-turn on
       ``Turn.degraded``): active slots of priority > 0 decode with their
       retrieval budget capped at ``min_budget_frac`` of the configured
       budget. Per-slot (the decode step is per-slot vmapped), so
       co-scheduled non-degraded slots stay bit-identical to the unloaded
       oracle.
    2. **preemption** (``preempt``): a fresh turn-0 admission still in its
       chunked-prefill phase (no token emitted yet) yields its slot at a
       chunk boundary to a strictly-higher-priority arrival; the preempted
       session re-queues and replays identically (its sample keys depend
       only on (seed, uid, step)).
    3. **shed** (``shed``): queued sessions of priority > 0 whose projected
       TTFT exceeds ``shed_grace`` x their target are rejected with an
       explicit :class:`~repro.serving.scheduler.ShedResult` instead of
       queuing unboundedly. Priority 0 is never shed.

    ``max_pending`` bounds the scheduler queue even without SLO scheduling:
    exceeding it raises :class:`~repro.serving.scheduler.QueueFullError`
    when ``enabled`` is False, and sheds the worst queued session when True.
    """

    enabled: bool = False
    ttft_target_s: float = 0.0    # per-session default TTFT target; 0 = off
    tpot_target_ms: float = 0.0   # decode-rate target (observability only)
    max_pending: int = 0          # queue bound; 0 = unbounded
    queue_high: int = 0           # overload when pending > this; 0 = auto
                                  # (2 x n_slots)
    pool_low_frac: float = 0.0    # paged: overload when free pages drop
                                  # under this fraction (0 = off)
    degrade_budget: bool = False  # stage 1 (opt-in: trades bit-exactness)
    min_budget_frac: float = 0.25  # degraded budget floor (frac of budget)
    preempt: bool = True          # stage 2: chunk-boundary admission yield
    shed: bool = True             # stage 3: reject hopeless queued sessions
    shed_grace: float = 4.0       # shed when projected TTFT > grace*target

    def replace(self, **kw) -> "SLOConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Serving-engine admission knobs (chunked prefill + shape bucketing).

    ``prefill_chunk`` splits every admission/extend prompt into fixed-size
    chunks fed through the delta-forward path with one batched decode step
    interleaved between chunks, so live decode slots never stall longer
    than one chunk forward (``0`` restores monolithic admission). Chunked
    admission requires an extend path through every decode block
    (``models.model.can_extend``); SSM hybrids / MoE-FFN / enc-dec archs
    fall back to monolithic prefill automatically.

    ``chunk_state`` picks how a chunk-admitted slot's cache-policy
    selection state is produced:

    * ``"rebuild"`` (default) — KV streams in chunk by chunk, then ONE
      monolithic build over the cached keys reproduces exactly the state a
      monolithic admission would have built: chunked greedy outputs are
      token-identical to monolithic admission for every policy at any
      retrieval budget.
    * ``"stream"`` — each chunk extends the state through the policy's
      streaming path (``CachePolicy.extend``: lychee lazy-grafts, quest
      tail pages, clusterkv centroid assignment). No end-of-admission
      build at all; the state follows the same trajectory per-token decode
      would have (quest is exactly the monolithic state; the k-means
      policies match the monolithic-build oracle whenever the budget
      covers the active set).

    ``bucket_prompts`` pads prompts/deltas to power-of-two length buckets
    with a valid-length mask, so admission and ``generate`` compile
    O(log max_len) shapes instead of one per distinct prompt length.

    ``paged`` swaps the per-slot contiguous KV caches of the serving
    engine for one global paged pool with per-slot page tables
    (``core.paging`` / ``serving.pagepool``): pages are refcounted and
    shared across slots through a radix prefix cache, admission of a
    cached prefix splices shared pages instead of re-prefilling, and a
    finished slot returns its private pages to the pool. Greedy outputs
    are bit-identical to the contiguous layout (halo-page design — see
    ``core.paging``). Requires ``models.model.can_page``; unsupported
    architectures and the dense policy fall back to contiguous silently.

    ``page_tokens`` fixes the logical page size (0 = auto: smallest
    multiple of the span granularity that divides ``n_cache`` and keeps
    halo overhead low, see ``core.paging.resolve_page_spec``).
    ``pool_pages`` sizes the global pool in pages (0 = auto:
    ``n_slots`` full sequences — the contiguous layout's footprint).
    ``prefix_cache=False`` keeps the paged pool but disables cross-
    request prefix sharing.
    """

    prefill_chunk: int = 512      # admission chunk size; 0 = monolithic
    chunk_state: str = "rebuild"  # "rebuild" | "stream" (see above)
    bucket_prompts: bool = True   # pow2 prompt-length bucketing + n_tokens
    min_bucket: int = 16          # smallest pad bucket
    paged: bool = False           # global paged KV pool + page tables
    page_tokens: int = 0          # logical page size; 0 = auto
    pool_pages: int = 0           # pool capacity in pages; 0 = auto
    prefix_cache: bool = True     # radix prefix cache (paged mode only)
    slo: SLOConfig = dataclasses.field(default_factory=SLOConfig)

    def replace(self, **kw) -> "ServingConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # --- block layout -----------------------------------------------------
    prelude: Tuple[str, ...] = ()          # unrolled leading blocks
    pattern: Tuple[str, ...] = ("attn",)   # scanned group pattern
    n_groups: int = 0                      # groups scanned; 0 -> derive

    # --- attention flavour --------------------------------------------------
    window: int = 0                # sliding-window size for *_local / swa
    attn_softcap: float = 0.0      # gemma2 logit soft-capping
    final_softcap: float = 0.0
    rope_theta: float = 10_000.0
    qk_norm: bool = False

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- MLA (deepseek) -----------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2 / zamba) ----------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    conv_width: int = 4

    # --- hybrid (zamba2) ----------------------------------------------------
    shared_attn_every: int = 0     # a shared attn block every N blocks

    # --- enc-dec (whisper) ---------------------------------------------------
    n_enc_layers: int = 0
    n_audio_frames: int = 1500     # stub frontend output length

    # --- vlm ---------------------------------------------------------------
    n_patches: int = 0             # stub vision frontend output length

    # --- train-time extras --------------------------------------------------
    mtp_depth: int = 0             # deepseek multi-token prediction heads
    tie_embeddings: bool = False
    lr_schedule: str = "cosine"    # minicpm -> "wsd"

    # --- numerics / distribution -------------------------------------------
    dtype: str = "bfloat16"
    fsdp: bool = False             # additionally shard params over data axis
    remat: bool = True
    opt_state_dtype: str = "float32"   # bf16 for the very large archs

    lychee: LycheeConfig = dataclasses.field(default_factory=LycheeConfig)
    serving: ServingConfig = dataclasses.field(default_factory=ServingConfig)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def groups(self) -> int:
        if self.n_groups:
            return self.n_groups
        body = self.n_layers - len(self.prelude)
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {body} body layers not divisible by pattern "
            f"{self.pattern}")
        return body // len(self.pattern)

    @property
    def uses_attention(self) -> bool:
        kinds = set(self.prelude) | set(self.pattern)
        return bool(kinds - {"mamba", "mlstm", "slstm"})

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def validate(self) -> "ModelConfig":
        assert self.n_layers == len(self.prelude) + self.groups * len(self.pattern)
        if self.n_experts:
            assert self.top_k > 0
        return self

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_REDUCED: Dict[str, Callable[[], ModelConfig]] = {}

ARCH_IDS = [
    "deepseek-v3-671b", "xlstm-125m", "zamba2-2.7b", "gemma2-27b",
    "mixtral-8x22b", "gemma3-12b", "minicpm-2b", "internvl2-2b",
    "granite-3-8b", "whisper-small",
]
# the paper's own evaluation model, included as an extra config
EXTRA_IDS = ["llama31-8b"]


def register(name: str, full: Callable[[], ModelConfig],
             reduced: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _REDUCED[name] = reduced


def _ensure_loaded() -> None:
    for arch in ARCH_IDS + EXTRA_IDS:
        mod = arch.replace("-", "_").replace(".", "_")
        if arch not in _REGISTRY:
            importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]().validate()


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(ARCH_IDS)
