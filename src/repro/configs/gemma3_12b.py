"""Gemma3-12B [hf:google/gemma-3-1b-pt family].

48 layers in a 5:1 local:global pattern (window 1024 local layers), d_model
3840, 16 heads (head_dim 256), GQA kv=8, d_ff 15360, vocab 262144, 128k
context, qk-norm.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        arch_type="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        d_ff=15360,
        vocab=262_144,
        head_dim=256,
        prelude=("attn_local",) * 5 + ("attn",),
        pattern=("attn_local",) * 5 + ("attn",),
        window=1024,
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        fsdp=True,
        lychee=LycheeConfig(),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512, window=64, prelude=(), pattern=("attn_local", "attn"),
        fsdp=False,
        lychee=LycheeConfig(budget=128, sink=4, buffer_size=16,
                            max_coarse=8, full_attn_layers=0),
    )


register("gemma3-12b", full, reduced)
