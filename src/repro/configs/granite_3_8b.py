"""Granite-3 8B [hf:ibm-granite/granite-3.0 family].

40 layers, d_model 4096, 32 heads (head_dim 128), GQA kv=8, d_ff 12800,
vocab 49155.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        arch_type="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12800,
        vocab=49_155,
        head_dim=128,
        prelude=("attn", "attn"),
        pattern=("attn",),
        rope_theta=10_000_000.0,
        tie_embeddings=True,
        lychee=LycheeConfig(),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512, prelude=(),
        lychee=LycheeConfig(budget=128, sink=4, buffer_size=16,
                            max_coarse=8, full_attn_layers=0),
    )


register("granite-3-8b", full, reduced)
