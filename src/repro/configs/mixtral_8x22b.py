"""Mixtral-8x22B [arXiv:2401.04088].

56 layers, d_model 6144, 48 heads (head_dim 128), GQA kv=8, MoE with 8
experts (d_ff 16384) top-2, sliding-window attention, vocab 32768.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        arch_type="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=32_768,
        head_dim=128,
        prelude=("swa_moe", "swa_moe"),
        pattern=("swa_moe",),
        window=4096,
        n_experts=8,
        top_k=2,
        d_ff_expert=16384,
        rope_theta=1_000_000.0,
        fsdp=True,
        opt_state_dtype="bfloat16",
        lychee=LycheeConfig(),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, d_ff_expert=512, vocab=512, window=64, n_experts=4, prelude=(),
        top_k=2, fsdp=False, opt_state_dtype="float32",
        lychee=LycheeConfig(budget=128, sink=4, buffer_size=16,
                            max_coarse=8, full_attn_layers=0),
    )


register("mixtral-8x22b", full, reduced)
