"""Gemma2-27B [arXiv:2408.00118].

46 layers alternating local (window 4096) / global attention, d_model 4608,
32 heads (head_dim 128), GQA kv=16, d_ff 36864, vocab 256000, attention logit
softcap 50, final logit softcap 30.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        arch_type="dense",
        n_layers=46,
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        d_ff=36864,
        vocab=256_000,
        head_dim=128,
        prelude=("attn_local", "attn"),
        pattern=("attn_local", "attn"),
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        tie_embeddings=True,
        fsdp=True,
        lychee=LycheeConfig(),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512, window=64, fsdp=False, prelude=(),
        lychee=LycheeConfig(budget=128, sink=4, buffer_size=16,
                            max_coarse=8, full_attn_layers=0),
    )


register("gemma2-27b", full, reduced)
