"""DeepSeek-V3 671B [arXiv:2412.19437].

61 layers, d_model 7168, 128 heads, MLA (kv_lora 512, q_lora 1536, decoupled
RoPE 64), first 3 layers dense FFN (18432), remaining 58 MoE with 1 shared +
256 routed experts (d_ff 2048) top-8, MTP depth 1, vocab 129280.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,        # MLA: per-head keys reconstructed from latent
        d_ff=18432,            # dense prelude FFN width
        vocab=129_280,
        head_dim=128,
        prelude=("mla",) * 3,
        pattern=("mla_moe",),
        n_experts=256,
        top_k=8,
        n_shared_experts=1,
        d_ff_expert=2048,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        mtp_depth=1,
        fsdp=True,
        opt_state_dtype="bfloat16",   # fp32 Adam for 671B exceeds 512x16GB
        lychee=LycheeConfig(),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=8, head_dim=32,
        d_ff=512, vocab=512, prelude=("mla",), pattern=("mla_moe",),
        n_experts=4, top_k=2, d_ff_expert=128,
        q_lora_rank=64, kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
        v_head_dim=32, fsdp=False, opt_state_dtype="float32",
        lychee=LycheeConfig(budget=128, sink=4, buffer_size=16,
                            max_coarse=8, full_attn_layers=0),
    )


register("deepseek-v3-671b", full, reduced)
