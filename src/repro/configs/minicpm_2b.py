"""MiniCPM-2B [arXiv:2404.06395].

Llama-like: 40 layers, d_model 2304, 36 heads (head_dim 64), MHA kv=36,
d_ff 5760, vocab 122753. Trained with the WSD (warmup-stable-decay) schedule,
which the training substrate implements.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        arch_type="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        vocab=122_753,
        head_dim=64,
        prelude=("attn", "attn"),
        pattern=("attn",),
        lr_schedule="wsd",
        tie_embeddings=True,
        lychee=LycheeConfig(),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab=512, prelude=(),
        lychee=LycheeConfig(budget=128, sink=4, buffer_size=16,
                            max_coarse=8, full_attn_layers=0),
    )


register("minicpm-2b", full, reduced)
