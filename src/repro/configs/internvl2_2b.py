"""InternVL2-2B [arXiv:2404.16821].

InternLM2-1.8B language backbone: 24 layers, d_model 2048, 16 heads
(head_dim 128), GQA kv=8, d_ff 8192, vocab 92553. The InternViT vision
encoder + MLP projector is a STUB per the assignment carve-out:
``input_specs`` feeds precomputed patch embeddings (n_patches × d_model)
that are prepended to the token embeddings.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        arch_type="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab=92_553,
        head_dim=128,
        prelude=("attn", "attn"),
        pattern=("attn",),
        n_patches=256,           # one 448x448 tile -> 256 projected patches
        lychee=LycheeConfig(),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512, n_patches=16, prelude=(),
        lychee=LycheeConfig(budget=128, sink=4, buffer_size=16,
                            max_coarse=8, full_attn_layers=0),
    )


register("internvl2-2b", full, reduced)
