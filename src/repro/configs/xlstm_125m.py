"""xLSTM-125M [arXiv:2405.04517].

12 blocks alternating mLSTM / sLSTM (the xLSTM[1:1] small configuration),
d_model 768, 4 heads, vocab 50304. Attention-free: LycheeCluster is
inapplicable (no KV cache) — see DESIGN.md §Arch-applicability.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        arch_type="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,                     # xLSTM blocks carry their own projections
        vocab=50_304,
        head_dim=192,
        pattern=("mlstm", "slstm"),
        ssm_expand=2,
        lychee=LycheeConfig(enabled=False),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        vocab=512,
    )


register("xlstm-125m", full, reduced)
