"""Whisper-small [arXiv:2212.04356].

Encoder-decoder: 12 encoder + 12 decoder layers, d_model 768, 12 heads
(head_dim 64), MHA, d_ff 3072, vocab 51865. The mel-spectrogram + conv
frontend is a STUB per the assignment carve-out: ``input_specs`` feeds
precomputed frame embeddings (n_audio_frames × d_model) to the encoder.
LycheeCluster manages the decoder's self-attention cache.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        arch_type="audio",
        n_layers=12,               # decoder layers
        n_enc_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab=51_865,
        head_dim=64,
        prelude=("dec_cross",),
        pattern=("dec_cross",),
        n_audio_frames=1500,
        lychee=LycheeConfig(full_attn_layers=1),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, n_enc_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        head_dim=64, d_ff=512, vocab=512, n_audio_frames=64,
        lychee=LycheeConfig(budget=128, sink=4, buffer_size=16,
                            max_coarse=8, full_attn_layers=0),
    )


register("whisper-small", full, reduced)
