from repro.configs.base import (ARCH_IDS, EXTRA_IDS, LycheeConfig,
                                ModelConfig, get_config, list_archs, register)

__all__ = ["ARCH_IDS", "EXTRA_IDS", "LycheeConfig", "ModelConfig",
           "get_config", "list_archs", "register"]
