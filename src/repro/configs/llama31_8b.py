"""Llama-3.1-8B — the paper's own evaluation model (Team, 2024).

Included beyond the assigned pool so the paper's experiments (LongBench V2 /
RULER settings) have their native config. 32 layers, d_model 4096, 32 heads
(head_dim 128), GQA kv=8, d_ff 14336, vocab 128256.
"""
from repro.configs.base import LycheeConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama31-8b",
        arch_type="dense",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=128_256,
        head_dim=128,
        prelude=("attn", "attn"),   # paper keeps first 2 layers full
        pattern=("attn",),
        rope_theta=500_000.0,
        lychee=LycheeConfig(),
    )


def reduced() -> ModelConfig:
    return full().replace(
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=512, prelude=(),
        lychee=LycheeConfig(budget=128, sink=4, buffer_size=16,
                            max_coarse=8, full_attn_layers=0),
    )


register("llama31-8b", full, reduced)
