"""End-to-end behaviour tests for the paper's system (deliverable c).

Key invariants validated here:

* Budget-sufficiency degeneration (paper App. F.1): when the retrieval
  budget covers the whole context, LycheeCluster's decode output matches
  full attention (retrieval returns everything; exact attention).
* Triangle-inequality upper bound (Eqn. 2): UB(q, u) >= q·v for every
  member v of u, at every index level, including after lazy updates.
* Structure-aware chunking: boundary alignment, min/max constraints,
  fixed-size degradation on delimiter-free input.
* Lazy update (Algorithm 1 step 4): monotonic radius, coverage of the
  grafted chunk, buffer cadence.
* Retrieval recall ordering: Lychee recall >= random selection at equal
  budget on clustered data (the mechanism behind Table 3).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LycheeConfig
from repro.core import (build_index, chunk_sequence, fixed_chunking,
                        full_decode_attention, retrieve, retrieve_dense,
                        sparse_decode_attention, synthetic_delimiter_table,
                        ub_scores)
from repro.core.attention import assemble_spans
from repro.core.retrieval import retrieve_spans
from repro.core.update import lazy_update, maybe_lazy_update
from repro.kernels.ref import sparse_chunk_attention_ref


def _mk_index(rng, N=256, H=2, d=32, cfg=None, clustered=False):
    cfg = cfg or LycheeConfig(min_chunk=8, max_chunk=16, max_coarse=8,
                              sink=4, buffer_size=16, budget=96)
    if clustered:
        # well-separated directions in contiguous runs — the paper's "strong
        # local coherence" premise (§4.1): nearby tokens share semantics
        n_modes = 8
        modes = rng.standard_normal((n_modes, d)) * 4.0
        ids = np.repeat(rng.integers(0, n_modes, size=N // 24 + 1), 24)[:N]
        keys = modes[ids] + rng.standard_normal((N, d)) * 0.3
        keys = np.broadcast_to(keys, (H, N, d)).copy()
    else:
        keys = rng.standard_normal((H, N, d))
    keys = jnp.asarray(keys, jnp.float32)
    table = jnp.asarray(synthetic_delimiter_table(97))
    tokens = jnp.asarray(rng.integers(0, 97, size=(N,)), jnp.int32)
    layout = chunk_sequence(tokens, table, cfg)
    index = build_index(keys, layout, cfg)
    return keys, layout, index, cfg


# ---------------------------------------------------------------------------
# Eqn. 2 upper bound
# ---------------------------------------------------------------------------
def test_ub_bounds_members_fine_level():
    rng = np.random.default_rng(0)
    keys, layout, index, cfg = _mk_index(rng)
    q = jnp.asarray(rng.standard_normal((2, 32)), jnp.float32)
    for h in range(2):
        ub = ub_scores(q[h], index.fine_centroid[h], index.fine_radius[h],
                       index.fine_valid[h])
        # every chunk's true score must be <= its cluster's UB
        L = index.fine_centroid.shape[1]
        ck = np.asarray(index.chunk_key[h])
        for l in range(L):
            if not bool(index.fine_valid[h, l]):
                continue
            members = np.asarray(index.fine_chunks[h, l])
            members = members[members >= 0]
            for m in members:
                true = float(np.dot(np.asarray(q[h]), ck[m]))
                assert true <= float(ub[l]) + 1e-4


def test_ub_bounds_members_coarse_level():
    rng = np.random.default_rng(1)
    keys, layout, index, cfg = _mk_index(rng)
    q = jnp.asarray(rng.standard_normal((32,)), jnp.float32)
    h = 0
    ub_g = ub_scores(q, index.coarse_centroid[h], index.coarse_radius[h],
                     index.coarse_valid[h])
    P = index.coarse_centroid.shape[1]
    for p in range(P):
        if not bool(index.coarse_valid[h, p]):
            continue
        kids = np.asarray(index.coarse_children[h, p])
        kids = kids[kids >= 0]
        for l in kids:
            mu_l = np.asarray(index.fine_centroid[h, l])
            true = float(np.dot(np.asarray(q), mu_l))
            assert true <= float(ub_g[p]) + 1e-4


# ---------------------------------------------------------------------------
# Chunking
# ---------------------------------------------------------------------------
def test_chunking_partitions_sequence():
    rng = np.random.default_rng(2)
    cfg = LycheeConfig()
    table = jnp.asarray(synthetic_delimiter_table(1000))
    tokens = jnp.asarray(rng.integers(0, 1000, size=(512,)), jnp.int32)
    lay = chunk_sequence(tokens, table, cfg)
    starts = np.asarray(lay.start)
    lens = np.asarray(lay.length)
    valid = np.asarray(lay.valid)
    # contiguous, ordered, complete cover of [0, 512)
    pos = 0
    for s, ln, v in zip(starts, lens, valid):
        if not v:
            continue
        assert s == pos
        assert 1 <= ln <= cfg.max_chunk
        pos += ln
    assert pos == 512
    # all but the last valid chunk respect min_chunk
    nz = np.where(valid)[0]
    assert (lens[nz[:-1]] >= cfg.min_chunk).all()


def test_chunking_splits_at_strongest_delimiter():
    cfg = LycheeConfig(min_chunk=4, max_chunk=8)
    # token 5 = strength-4 delimiter; all else 0
    table = np.zeros(10, np.int32)
    table[5] = 4
    tokens = np.zeros(32, np.int64)
    tokens[6] = 5          # inside the look-ahead window of chunk 0
    lay = chunk_sequence(jnp.asarray(tokens, jnp.int32),
                         jnp.asarray(table), cfg)
    # chunk 0 must end right AFTER position 6 (length 7)
    assert int(lay.length[0]) == 7


def test_chunking_degrades_to_fixed_without_delimiters():
    cfg = LycheeConfig(min_chunk=8, max_chunk=16)
    table = jnp.zeros(100, jnp.int32)
    tokens = jnp.asarray(np.arange(160) % 100, jnp.int32)
    lay = chunk_sequence(tokens, table, cfg)
    lens = np.asarray(lay.length)[np.asarray(lay.valid)]
    assert (lens == 16).all()


def test_fixed_chunking_matches_page_layout():
    cfg = LycheeConfig()
    lay = fixed_chunking(128, 16, cfg)
    assert int(lay.count) == 8
    assert (np.asarray(lay.length)[:8] == 16).all()


# ---------------------------------------------------------------------------
# Budget-sufficient degeneration to full attention (App. F.1)
# ---------------------------------------------------------------------------
def test_budget_sufficient_equals_full_attention():
    rng = np.random.default_rng(3)
    N, H, G, d = 192, 2, 2, 32
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, max_coarse=64,
                       top_kg=64, sink=16, buffer_size=32, budget=100000)
    keys, layout, index, _ = _mk_index(rng, N=N, H=H, d=d, cfg=cfg)
    v_cache = jnp.asarray(rng.standard_normal((H, N, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((H * G, d)), jnp.float32)
    t = N

    probe = q.reshape(H, G, d).mean(1)
    ret = retrieve(index, probe, cfg)
    out = sparse_decode_attention(q, keys, v_cache, ret.token_idx,
                                  ret.token_mask, t, cfg, scale=d ** -0.5)
    want = full_decode_attention(q, keys, v_cache, t, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_span_path_budget_sufficient_equals_full_attention():
    """The TPU-native span pipeline (retrieve_spans -> assemble_spans ->
    chunk attention) must also degenerate to full attention."""
    rng = np.random.default_rng(4)
    N, H, G, d = 192, 2, 2, 32
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, max_coarse=64,
                       top_kg=64, sink=16, buffer_size=32, budget=100000)
    keys, layout, index, _ = _mk_index(rng, N=N, H=H, d=d, cfg=cfg)
    v_cache = jnp.asarray(rng.standard_normal((H, N, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((H * G, d)), jnp.float32)
    t = N
    probe = q.reshape(H, G, d).mean(1)
    s, ln, _ = retrieve_spans(index, probe, cfg)
    starts, lens = assemble_spans(s, ln, t, cfg)
    out = sparse_chunk_attention_ref(
        q.reshape(1, H, G, d), keys[None], v_cache[None],
        starts[None], lens[None], max_chunk=cfg.max_chunk, scale=d ** -0.5)
    want = full_decode_attention(q, keys, v_cache, t, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out).reshape(H * G, d),
                               np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Lazy update (Algorithm 1 step 4)
# ---------------------------------------------------------------------------
def test_lazy_update_monotonic_radius_and_coverage():
    rng = np.random.default_rng(5)
    keys, layout, index, cfg = _mk_index(rng)
    H, M, d = index.chunk_key.shape
    new_key = jnp.asarray(rng.standard_normal((H, d)), jnp.float32)
    new_key = new_key / jnp.linalg.norm(new_key, axis=-1, keepdims=True)
    upd = lazy_update(index, new_key, 256, 16, cfg)
    # radii never shrink
    assert (np.asarray(upd.fine_radius) >=
            np.asarray(index.fine_radius) - 1e-6).all()
    assert (np.asarray(upd.coarse_radius) >=
            np.asarray(index.coarse_radius) - 1e-6).all()
    # the grafted chunk is covered: ||new - mu|| <= r for its cluster
    sim = jnp.einsum("hld,hd->hl", index.fine_centroid, new_key)
    sim = jnp.where(index.fine_valid, sim, -1e30)
    fid = np.asarray(jnp.argmax(sim, -1))
    for h in range(H):
        mu = np.asarray(upd.fine_centroid[h, fid[h]])
        r = float(upd.fine_radius[h, fid[h]])
        assert np.linalg.norm(np.asarray(new_key[h]) - mu) <= r + 1e-5
    # chunk appended
    assert int(upd.chunk_count) == int(index.chunk_count) + 1
    assert bool(upd.chunk_valid[int(index.chunk_count)])


def test_maybe_lazy_update_cadence():
    rng = np.random.default_rng(6)
    keys, layout, index, cfg = _mk_index(rng)
    keys_big = jnp.asarray(rng.standard_normal((2, 512, 32)), jnp.float32)
    # not due: t not a multiple of max_chunk
    upd = maybe_lazy_update(index, keys_big, 257, cfg)
    assert int(upd.chunk_count) == int(index.chunk_count)
    # due
    upd = maybe_lazy_update(index, keys_big, 272, cfg)
    assert int(upd.chunk_count) == int(index.chunk_count) + 1


# ---------------------------------------------------------------------------
# Retrieval quality ordering (mechanism behind Tab. 3 / Fig. 2)
# ---------------------------------------------------------------------------
def _recall(token_idx, token_mask, truth_idx):
    got = set(np.asarray(token_idx)[np.asarray(token_mask)].tolist())
    return len(got & set(truth_idx.tolist())) / len(truth_idx)


def test_retrieval_recall_beats_random_on_clustered_keys():
    rng = np.random.default_rng(7)
    N, H, d = 512, 1, 32
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, max_coarse=16,
                       top_kg=4, sink=0, buffer_size=0, budget=128)
    keys, layout, index, _ = _mk_index(rng, N=N, H=H, d=d, cfg=cfg,
                                       clustered=True)
    # query aligned with one random key -> ground truth = top-k by dot
    q = keys[0, rng.integers(0, N)] + 0.1 * rng.standard_normal(32)
    q = jnp.asarray(q, jnp.float32)[None]
    scores = np.asarray(keys[0] @ q[0])
    truth = np.argsort(-scores)[:64]

    ret = retrieve(index, q, cfg)
    r_lychee = _recall(ret.token_idx[0], ret.token_mask[0], truth)
    # random baseline at the SAME actual token count
    n_got = len(set(np.asarray(ret.token_idx[0])[
        np.asarray(ret.token_mask[0])].tolist()))
    rand_idx = rng.choice(N, size=min(n_got, N), replace=False)
    r_rand = len(set(rand_idx.tolist()) & set(truth.tolist())) / 64
    assert r_lychee > r_rand, (r_lychee, r_rand)
    assert r_lychee > 0.5


def test_hierarchical_close_to_dense_retrieval():
    """Coarse pruning (top-kg) should rarely lose what dense fine-scoring
    finds — on clustered data the sets overlap heavily."""
    rng = np.random.default_rng(8)
    N, H, d = 512, 1, 32
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, max_coarse=16,
                       top_kg=6, sink=0, buffer_size=0, budget=128)
    keys, layout, index, _ = _mk_index(rng, N=N, H=H, d=d, cfg=cfg,
                                       clustered=True)
    q = jnp.asarray(rng.standard_normal((1, d)), jnp.float32)
    hier = retrieve(index, q, cfg)
    dense = retrieve_dense(index, q, cfg)
    h_set = set(np.asarray(hier.fine_ids[0])[
        np.asarray(hier.fine_mask[0])].tolist())
    d_set = set(np.asarray(dense.fine_ids[0])[
        np.asarray(dense.fine_mask[0])].tolist())
    if d_set:
        overlap = len(h_set & d_set) / len(d_set)
        assert overlap >= 0.75, (h_set, d_set)


# ---------------------------------------------------------------------------
# Context-sharded flash combine == oracle (the shard_map decode path)
# ---------------------------------------------------------------------------
def test_partial_attention_shard_combine_matches_oracle():
    """Emulate the §Perf-iteration-1d shard_map: run _span_attend_partial
    per context shard and flash-combine; must equal the single-pass
    oracle exactly."""
    from repro.core.attention import _span_attend_partial
    rng = np.random.default_rng(11)
    B, H, G, d, N, C, mc = 2, 2, 2, 32, 256, 9, 16
    n_shards = 4
    q = jnp.asarray(rng.standard_normal((B, H, G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, N, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, N, d)), jnp.float32)
    starts = jnp.asarray(rng.integers(0, N - mc, size=(B, H, C)), jnp.int32)
    lens = jnp.asarray(rng.integers(0, mc + 1, size=(B, H, C)), jnp.int32)

    sn = N // n_shards
    ms, ls, accs = [], [], []
    for s_i in range(n_shards):
        lo = s_i * sn
        m, l, acc = _span_attend_partial(
            q, k[:, :, lo:lo + sn], v[:, :, lo:lo + sn], starts, lens,
            lo, lo + sn, max_chunk=mc, scale=d ** -0.5, softcap=0.0)
        ms.append(m), ls.append(l), accs.append(acc)
    m_g = jnp.max(jnp.stack(ms), 0)
    l_g = sum(l * jnp.exp(m - m_g) for m, l in zip(ms, ls))
    acc_g = sum(a * jnp.exp(m - m_g) for m, a in zip(ms, accs))
    got = acc_g / jnp.maximum(l_g, 1e-30)

    want = sparse_chunk_attention_ref(q, k, v, starts, lens, max_chunk=mc,
                                      scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_full_decode_ctxsharded_combine_matches_oracle():
    """§Perf iteration 4: dense decode flash-combine — emulate the shard
    partials and verify the combine equals single-pass full attention."""
    rng = np.random.default_rng(12)
    B, Hkv, G, d, N = 2, 3, 2, 16, 96
    t = 77
    q = jnp.asarray(rng.standard_normal((B, Hkv * G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, d)), jnp.float32)
    n_shards, sn = 4, N // 4
    _NEG = -1e30
    ms, ls, accs = [], [], []
    qg = q.reshape(B, Hkv, G, d)
    for s_i in range(n_shards):
        lo = s_i * sn
        pos = lo + np.arange(sn)
        mask = jnp.asarray(pos < t)
        logits = jnp.einsum("bhgd,bhnd->bhgn", qg, k[:, :, lo:lo + sn]
                            ) * (d ** -0.5)
        logits = jnp.where(mask[None, None, None], logits, _NEG)
        m = jnp.max(logits, -1, keepdims=True)
        p = jnp.where(mask[None, None, None], jnp.exp(logits - m), 0.0)
        ms.append(m), ls.append(jnp.sum(p, -1, keepdims=True))
        accs.append(jnp.einsum("bhgn,bhnd->bhgd", p, v[:, :, lo:lo + sn]))
    m_g = jnp.max(jnp.stack(ms), 0)
    l_g = sum(l * jnp.exp(m - m_g) for m, l in zip(ms, ls))
    acc_g = sum(a * jnp.exp(m - m_g) for m, a in zip(ms, accs))
    got = (acc_g / jnp.maximum(l_g, 1e-30)).reshape(B, Hkv * G, d)

    want = jax.vmap(lambda qq, kk, vv: full_decode_attention(
        qq, kk, vv, t, d ** -0.5))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
