"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED config (≤2 layers,
d_model ≤ 512, ≤4 experts) and runs, on CPU:
  * one training forward/backward step — finite loss, grads for every param;
  * prefill + a few decode steps with LycheeCluster enabled (where the
    technique applies) — correct output shapes, no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import model as MD

B, S = 2, 64


def _batch(cfg, rng):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(B, S)), jnp.int32)}
    if cfg.n_patches:
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_audio_frames, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, rng):
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params = MD.init_model(jax.random.key(0), cfg)
    batch = _batch(cfg, rng)

    def loss_fn(p):
        loss, metrics = MD.train_forward(p, batch, cfg)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves, arch
    for g in leaves:
        assert np.isfinite(np.asarray(g)).all(), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch, rng):
    cfg = get_config(arch, reduced=True).replace(dtype="float32")
    params = MD.init_model(jax.random.key(1), cfg)
    batch = _batch(cfg, rng)
    n_cache = S + (cfg.n_patches or 0) + 16

    logits, state = jax.jit(
        lambda p, tk: MD.prefill(p, tk, cfg, n_cache, extras=batch)
    )(params, batch["tokens"])
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch

    step = jax.jit(lambda p, tok, st: MD.decode_step(p, tok, st, cfg))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(4):
        logits, state = step(params, tok, state)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all(), arch
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    # per-slot position counters: one entry per batch slot, all advanced
    assert state["t"].shape == (B,)
    assert (np.asarray(state["t"]) == S + (cfg.n_patches or 0) + 4).all()


def test_chunked_ssd_grads_finite_at_long_seq():
    """Regression: the intra-chunk causal mask must be applied INSIDE the
    exp — masking after overflows (inf) once |cum log-decay| > 88, i.e. at
    seq >= ~128, and NaNs gradients through the dead where-branch. Caught
    by examples/train_lm.py at seq 256 (smoke S=64 cannot see it)."""
    from repro.models.mamba2 import chunked_ssd
    rng = np.random.default_rng(0)
    b, S, H, P, N = 1, 384, 2, 8, 8
    x = jnp.asarray(rng.standard_normal((b, S, H, P)), jnp.float32)
    Bc = jnp.asarray(rng.standard_normal((b, S, H, N)), jnp.float32)
    Cc = jnp.asarray(rng.standard_normal((b, S, H, N)), jnp.float32)
    loga = -jnp.abs(jnp.asarray(rng.standard_normal((b, S, H)),
                                jnp.float32))     # strong decay
    gate = jnp.ones((b, S, H), jnp.float32)

    def loss(x):
        y, _ = chunked_ssd(x, Bc, Cc, loga, gate, chunk=256)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(x)
    assert np.isfinite(np.asarray(g)).all()
