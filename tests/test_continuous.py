"""Continuous-batching lifecycle tests.

The invariants that make streaming admission safe:

* a finished slot recycled mid-stream serves its new request correctly
  (more requests than slots; every request completes);
* an admitted request's greedy output is identical to the same request
  served alone — co-scheduled requests cannot perturb each other (decode is
  per-slot vmapped, prefill is per-request at natural length);
* continuous and static admission produce identical greedy tokens (the
  throughput benchmark's fairness precondition);
* per-slot index reset (``model.reset_slot`` / ``core.reset_index``) leaves
  the OTHER slots' retrieval (``fine_ids``) bit-identical;
* ``Engine.generate`` pads completed slots with ``eos_id`` instead of
  recording garbage lock-step samples.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LycheeConfig, get_config
from repro.core.retrieval import retrieve
from repro.core.update import reset_index
from repro.models import model as MD
from repro.serving import Engine, Request, Scheduler, make_trace

N_CACHE = 128


def _small_cfg():
    ly = LycheeConfig(budget=64, sink=4, buffer_size=16, max_coarse=8,
                      top_kg=4, full_attn_layers=0)
    return get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=ly)


@pytest.fixture(scope="module")
def setup():
    cfg = _small_cfg()
    params = MD.init_model(jax.random.key(0), cfg)
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    return cfg, params, engine


def _trace(cfg, n=5, seed=0):
    return make_trace(np.random.default_rng(seed), n, cfg.vocab,
                      prompt_lens=(24, 48, 64), gen_lens=(4, 10))


def test_recycled_slot_matches_request_served_alone(setup):
    cfg, params, engine = setup
    trace = _trace(cfg, n=5)
    res = engine.serve(copy.deepcopy(trace), n_slots=2, mode="continuous")
    # more requests than slots -> slots were recycled mid-stream
    assert len(res.requests) == 5
    assert res.mode == "continuous"
    for req in trace:
        got = res.requests[req.uid]
        assert len(got.tokens) == req.max_new
        alone = engine.generate(req.prompt[None], req.max_new)
        assert got.tokens == alone.tokens[0].tolist(), \
            f"req {req.uid} diverged from solo serving"


def test_continuous_equals_static_greedy(setup):
    cfg, params, engine = setup
    trace = _trace(cfg, n=6, seed=1)
    rc = engine.serve(copy.deepcopy(trace), n_slots=2, mode="continuous")
    rs = engine.serve(copy.deepcopy(trace), n_slots=2, mode="static")
    assert set(rc.requests) == set(rs.requests) == {r.uid for r in trace}
    for uid in rc.requests:
        assert rc.requests[uid].tokens == rs.requests[uid].tokens
    # continuous never takes MORE lock-step decode rounds than static
    assert rc.n_steps <= rs.n_steps


def test_reset_slot_keeps_other_slots_retrieval_bit_identical(setup):
    cfg, params, engine = setup
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg.vocab, size=(2, 64)).astype(np.int32)
    _, state = MD.prefill(params, jnp.asarray(prompts), cfg, N_CACHE)

    def fine_ids_of(st):
        """Retrieval over slot 1's index in the FIRST scanned group layer."""
        index = jax.tree.map(
            lambda l: l[0, 0],
            MD.slice_slot(st, 1)["groups"][0]["policy_state"])
        probe = jnp.asarray(np.random.default_rng(3).standard_normal(
            (index.chunk_key.shape[0], index.chunk_key.shape[-1])),
            jnp.float32)
        return np.asarray(retrieve(index, probe, cfg.lychee).fine_ids)

    before = fine_ids_of(state)
    state2 = MD.reset_slot(state, 0)
    after = fine_ids_of(state2)
    np.testing.assert_array_equal(before, after)
    # ... and ALL of slot 1's state leaves survive the reset bit-identically
    for a, b in zip(jax.tree.leaves(MD.slice_slot(state, 1)),
                    jax.tree.leaves(MD.slice_slot(state2, 1))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and the reset slot itself is genuinely empty: all-invalid retrieval
    empty = jax.tree.map(lambda l: l[0, 0],
                         state2["groups"][0]["policy_state"])
    assert int(empty.chunk_count) == 0
    assert not bool(np.asarray(empty.fine_valid).any())
    # reset_index on an unbatched index is the same contract
    ref = reset_index(jax.tree.map(lambda l: l[0, 0],
                                   state["groups"][0]["policy_state"]))
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(empty)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_pads_finished_slots_with_eos(setup):
    cfg, params, engine = setup
    rng = np.random.default_rng(4)
    prompts = rng.integers(0, cfg.vocab, size=(2, 48)).astype(np.int32)
    probe = engine.generate(prompts, 8)
    # use slot 0's second greedy token as the eos -> it finishes early
    eos = int(probe.tokens[0, 1])
    engine2 = Engine(cfg, params, n_cache=N_CACHE, donate_state=False,
                     eos_id=eos)
    res = engine2.generate(prompts, 8)
    for b in range(2):
        row = res.tokens[b].tolist()
        if eos in row:
            stop = row.index(eos)
            assert res.n_generated[b] == stop + 1
            assert all(t == eos for t in row[stop:]), \
                "tokens after completion must be eos-padded"
    # early-break path: when EVERY row is done the loop exits before
    # writing the remaining columns — they must come out eos-padded too
    solo = engine2.generate(prompts[:1], 8)
    row = solo.tokens[0].tolist()
    assert eos in row
    stop = row.index(eos)
    assert solo.n_generated[0] == stop + 1
    assert all(t == eos for t in row[stop:])


def test_scheduler_fifo_and_arrival_gating():
    rng = np.random.default_rng(5)
    reqs = [Request(uid=i, prompt=rng.integers(0, 10, size=(4,))
                    .astype(np.int32), max_new=2, arrival_s=float(i))
            for i in range(3)]
    sched = Scheduler(2)
    sched.submit_all(reqs)
    assert sched.next_ready(0.5) is reqs[0]
    sched.admit(0, 0.5)
    assert sched.next_ready(0.5) is None            # req1 arrives at t=1
    assert sched.next_ready(1.5) is reqs[1]
    sched.admit(1, 1.5)
    assert sched.free_slots() == []
    sched.finish(0, 2.0)
    assert sched.free_slots() == [0]
    assert sched.finished[0].latency_s == pytest.approx(2.0)
    sched.admit(0, 2.5)
    sched.finish(0, 3.0)
    sched.finish(1, 3.0)
    assert sched.all_done
