"""Serving-layer tests: engine generate loop, samplers, checkpoint
round-trip, the Pallas-kernel decode path, and training substrate
(microbatch equivalence, schedules)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LycheeConfig, get_config
from repro.models import model as MD
from repro.serving import Engine, SamplerParams, sample, slot_keys
from repro.training.optimizer import lr_schedule
from repro.training.train_step import make_train_step


def _small_cfg(**lychee_kw):
    ly = LycheeConfig(budget=64, sink=4, buffer_size=16, max_coarse=8,
                      top_kg=4, full_attn_layers=0, **lychee_kw)
    return get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=ly)


def test_engine_generate_shapes_and_determinism():
    cfg = _small_cfg()
    params = MD.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(2, 96)).astype(np.int32)
    engine = Engine(cfg, params, n_cache=160, donate_state=False)
    r1 = engine.generate(prompts, 8)          # greedy
    r2 = engine.generate(prompts, 8)
    assert r1.tokens.shape == (2, 8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)  # greedy determinism
    assert (r1.n_generated == 8).all()
    assert r1.tpot_ms > 0


def test_engine_kernel_path_matches_ref_path():
    """use_kernel=True (Pallas interpret mode) must generate the SAME
    greedy tokens as the jnp reference path."""
    cfg_ref = _small_cfg(use_kernel=False)
    cfg_ker = _small_cfg(use_kernel=True)
    params = MD.init_model(jax.random.key(1), cfg_ref)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, cfg_ref.vocab, size=(1, 96)).astype(np.int32)
    toks = {}
    for name, cfg in [("ref", cfg_ref), ("kernel", cfg_ker)]:
        engine = Engine(cfg, params, n_cache=160, donate_state=False)
        toks[name] = engine.generate(prompts, 6).tokens
    np.testing.assert_array_equal(toks["ref"], toks["kernel"])


def test_sampler_modes():
    B = 4
    keys = slot_keys(jax.random.key(0), jnp.arange(B, dtype=jnp.int32),
                     jnp.zeros((B,), jnp.int32))
    logits = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((B, 50)), jnp.float32)
    greedy = sample(keys, logits, jnp.zeros((B,)), jnp.zeros((B,), jnp.int32),
                    jnp.ones((B,)))
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))
    for sc in (SamplerParams(temperature=1.0, top_k=10),
               SamplerParams(temperature=0.7, top_p=0.9),
               SamplerParams(temperature=1.3, top_k=5, top_p=0.95)):
        t = sample(keys, logits, jnp.full((B,), sc.temperature),
                   jnp.full((B,), sc.top_k, jnp.int32),
                   jnp.full((B,), sc.top_p))
        assert t.shape == (B,)
        assert ((np.asarray(t) >= 0) & (np.asarray(t) < 50)).all()
    # per-slot heterogeneous params in ONE call: greedy rows stay argmax
    mixed = sample(keys, logits, jnp.asarray([0.0, 0.9, 0.0, 1.2]),
                   jnp.asarray([0, 10, 0, 5], jnp.int32),
                   jnp.asarray([1.0, 0.9, 1.0, 0.95]))
    am = np.asarray(jnp.argmax(logits, -1))
    assert np.asarray(mixed)[0] == am[0] and np.asarray(mixed)[2] == am[2]


def test_checkpoint_roundtrip(tmp_path):
    from repro.training.checkpoint import restore, save
    cfg = _small_cfg()
    params = MD.init_model(jax.random.key(2), cfg)
    save(str(tmp_path / "ck"), params, step=7)
    like = jax.tree.map(jnp.zeros_like, params)
    restored, step = restore(str(tmp_path / "ck"), like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_microbatch_gradient_accumulation_equivalence():
    cfg = get_config("minicpm-2b", reduced=True).replace(dtype="float32")
    params = MD.init_model(jax.random.key(3), cfg)
    batch = {"tokens": jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab, (8, 64)), jnp.int32)}
    outs = {}
    for mb in (0, 4):
        step, init = make_train_step(cfg, microbatch=mb)
        p2, _, mets = step(params, init(params), batch)
        outs[mb] = (float(mets["loss"]), float(mets["grad_norm"]), p2)
    assert abs(outs[0][0] - outs[4][0]) < 1e-4
    assert abs(outs[0][1] - outs[4][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[0][2]), jax.tree.leaves(outs[4][2])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)


def test_lr_schedules():
    cos = [float(lr_schedule(s, base_lr=1.0, total_steps=1000, warmup=100))
           for s in (0, 50, 100, 500, 1000)]
    assert cos[0] == 0.0 and cos[1] == pytest.approx(0.5)
    assert cos[2] == pytest.approx(1.0)
    assert cos[-1] < 1e-6
    wsd = [float(lr_schedule(s, base_lr=1.0, total_steps=1000, warmup=100,
                             kind="wsd"))
           for s in (100, 500, 800, 1000)]
    assert wsd[0] == pytest.approx(1.0)
    assert wsd[1] == pytest.approx(1.0)      # stable plateau
    assert wsd[3] < wsd[2] <= 1.0            # decay phase
