"""Session-centric serving API tests.

The invariants that make multi-turn KV/index reuse safe:

* for EVERY registered cache policy, a turn-2 greedy continuation via
  ``extend_slot`` (KV rows + policy state reused, index extended through
  the streaming-update path) is token-identical to re-prefilling the
  concatenated history into a fresh slot AND to ``generate`` over that
  history — the extend-vs-rebuild oracle;
* multi-turn sessions hold their slot across turns, recycle correctly when
  sessions outnumber slots, and interleave with single-turn traffic;
* per-request sampling is deterministic in (seed, uid, step) only: sampled
  outputs are independent of co-scheduled sessions / slot count / admission
  order (the greedy serve==solo invariant extended to temperature > 0);
* mixed greedy/sampled batches run ONE jitted dispatch per token — host-
  side eager sampling happens once per turn (prefill/extend logits), never
  in the decode loop;
* per-turn stop sequences end the turn and are trimmed from the public
  token list (but stay in the device-side history);
* ``on_token`` streams every sampled token;
* open-loop idle waits sleep until the next arrival exactly and are booked
  to ``ServeResult.idle_s``, not to throughput.
"""
import copy

import jax
import numpy as np
import pytest

from repro.configs.base import LycheeConfig, get_config
from repro.core.policy import list_policies
from repro.models import model as MD
from repro.serving import (Engine, Request, SamplerParams, Session, Turn,
                           make_session_trace)

N_CACHE = 192


def _cfg(policy="lychee", **lychee_kw):
    """Total-coverage retrieval config: the budget covers every chunk /
    page / cluster at the test's sequence lengths, so selection differences
    between a rebuilt and an extended policy state cannot change the active
    set — greedy outputs must then be token-identical between the two."""
    kw = dict(policy=policy, enabled=policy != "dense", budget=512, sink=4,
              buffer_size=32, max_coarse=8, top_kg=8, full_attn_layers=0,
              chunk_cap=32, ckv_cap_factor=8)
    kw.update(lychee_kw)
    return get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=LycheeConfig(**kw))


@pytest.fixture(scope="module")
def params():
    return MD.init_model(jax.random.key(0), _cfg())


def _two_turn_session(cfg, uid=0, s1=48, s2=16, gen1=6, gen2=8, seed=3,
                      sampling=None):
    rng = np.random.default_rng(seed)
    return Session(uid=uid, turns=[
        Turn(prompt=rng.integers(0, cfg.vocab, size=(s1,)).astype(np.int32),
             max_new=gen1, sampling=sampling),
        Turn(prompt=rng.integers(0, cfg.vocab, size=(s2,)).astype(np.int32),
             max_new=gen2, sampling=sampling)])


# ---------------------------------------------------------------------------
# Tentpole correctness: extend == re-prefill oracle, per policy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", sorted(list_policies()))
def test_turn2_extend_matches_reprefill_oracle(params, policy):
    cfg = _cfg(policy)
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    assert engine.can_extend

    r_ext = engine.serve([_two_turn_session(cfg)], n_slots=2,
                         reuse="extend")
    r_rep = engine.serve([_two_turn_session(cfg)], n_slots=2,
                         reuse="reprefill")
    s_ext, s_rep = r_ext.requests[0], r_rep.requests[0]
    # turn 1 is the same prefill in both paths
    assert s_ext.turns[0].tokens == s_rep.turns[0].tokens
    # turn 2: streamed-extended state vs rebuilt state — token-identical
    assert s_ext.turns[1].tokens == s_rep.turns[1].tokens, \
        f"[{policy}] extend diverged from re-prefill"

    # ... and both equal generate() over the concatenated device history
    ref = _two_turn_session(cfg)
    hist = np.concatenate([
        ref.turns[0].prompt,
        np.asarray(s_ext.turns[0].sampled, np.int32),
        ref.turns[1].prompt])
    oracle = engine.generate(hist[None], s_ext.turns[1].max_new)
    assert s_ext.turns[1].tokens == oracle.tokens[0].tolist(), \
        f"[{policy}] extend diverged from the generate oracle"


def test_extend_slot_reuses_rows_and_advances_t(params):
    """extend_slot appends the delta at the slot's current t and leaves the
    history rows (and the OTHER slot's whole state) bit-identical."""
    cfg = _cfg()
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, size=(2, 48)).astype(np.int32)
    _, state = MD.prefill(params, jnp.asarray(prompts), cfg, N_CACHE)
    delta = rng.integers(0, cfg.vocab, size=(1, 16)).astype(np.int32)
    _, state2 = MD.extend_slot(params, jnp.asarray(delta), cfg, state, 0)
    assert np.asarray(state2["t"]).tolist() == [48 + 16, 48]
    # slot 1 untouched
    for a, b in zip(jax.tree.leaves(MD.slice_slot(state, 1)),
                    jax.tree.leaves(MD.slice_slot(state2, 1))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # slot 0's history rows untouched, delta rows written
    k_old = np.asarray(state["groups"][0]["k"])[0, 0]    # (Hkv, N, dh)
    k_new = np.asarray(state2["groups"][0]["k"])[0, 0]
    np.testing.assert_array_equal(k_new[:, :48], k_old[:, :48])
    assert np.abs(k_new[:, 48:64]).sum() > 0, "delta rows must be written"


def _arch_cfg(arch, **model_kw):
    ly = LycheeConfig(budget=512, sink=4, buffer_size=32, max_coarse=8,
                      top_kg=8, full_attn_layers=0, chunk_cap=32)
    return get_config(arch, reduced=True).replace(
        dtype="float32", lychee=ly, **model_kw)


@pytest.mark.parametrize("arch,model_kw", [
    ("gemma2-27b", {}),                    # attn_local: ring-buffer extend
    ("deepseek-v3-671b", {"pattern": ("mla",)}),   # latent-cache extend
])
def test_turn2_extend_oracle_other_block_kinds(arch, model_kw):
    """The novel extend paths beyond plain GQA: the sliding-window ring
    buffer (reconstructed ring positions + windowed flash over ring+delta)
    and MLA (per-head K/V rebuilt from cached latents). Dense-FFN configs
    only — MoE capacity is sequence-length dependent (see EXTEND_KINDS)."""
    cfg = _arch_cfg(arch, **model_kw)
    assert MD.can_extend(cfg)
    params = MD.init_model(jax.random.key(2), cfg)
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    r_ext = engine.serve([_two_turn_session(cfg)], n_slots=1,
                         reuse="extend")
    r_rep = engine.serve([_two_turn_session(cfg)], n_slots=1,
                         reuse="reprefill")
    assert [t.tokens for t in r_ext.requests[0].turns] == \
        [t.tokens for t in r_rep.requests[0].turns], \
        f"[{arch}] extend diverged from re-prefill"


def test_moe_arch_falls_back_to_reprefill_and_matches_oracle():
    """MoE FFN capacity depends on the forward's sequence length, so a
    delta-length extend can drop tokens differently than the full-history
    prefill — those archs must NOT advertise extend and must still be
    oracle-correct through the re-prefill fallback."""
    cfg = _arch_cfg("mixtral-8x22b")
    assert not MD.can_extend(cfg)
    params = MD.init_model(jax.random.key(3), cfg)
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    assert not engine.can_extend
    res = engine.serve([_two_turn_session(cfg)], n_slots=1,
                       reuse="extend")          # silent reprefill fallback
    sess = res.requests[0]
    hist = np.concatenate([sess.turns[0].prompt,
                           np.asarray(sess.turns[0].sampled, np.int32),
                           sess.turns[1].prompt])
    oracle = engine.generate(hist[None], sess.turns[1].max_new)
    assert sess.turns[1].tokens == oracle.tokens[0].tolist()


# ---------------------------------------------------------------------------
# Multi-turn lifecycle
# ---------------------------------------------------------------------------
def test_sessions_recycle_slots_and_finish_all_turns(params):
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    trace = make_session_trace(np.random.default_rng(1), 5, cfg.vocab,
                               n_turns=2, first_lens=(24, 48),
                               delta_lens=(8, 16), gen_lens=(3, 6),
                               temperatures=(0.0,))
    res = engine.serve(copy.deepcopy(trace), n_slots=2)
    assert len(res.requests) == 5
    for ref in trace:
        sess = res.requests[ref.uid]
        assert sess.n_turns == 2
        for j, turn in enumerate(sess.turns):
            assert len(turn.tokens) == ref.turns[j].max_new
            assert turn.started_s is not None
            assert turn.ttft_s is not None and turn.ttft_s >= 0
        assert sess.finished_s is not None
        # total_new_tokens counts every turn
    assert res.total_new_tokens == sum(
        t.max_new for s in trace for t in s.turns)


def test_multi_turn_greedy_independent_of_coscheduling(params):
    """A session's greedy turns are identical whether it shares the batch
    with other sessions or runs alone (the serve==solo invariant, now
    across turn boundaries)."""
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    mk = lambda: [_two_turn_session(cfg, uid=0, seed=5),
                  _two_turn_session(cfg, uid=1, seed=6, s1=24, s2=8)]
    both = engine.serve(mk(), n_slots=2)
    solo = engine.serve([mk()[0]], n_slots=1)
    assert [t.tokens for t in both.requests[0].turns] == \
        [t.tokens for t in solo.requests[0].turns]


def test_eos_ends_turn_but_not_session(params):
    cfg = _cfg()
    probe_engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    probe = probe_engine.serve([_two_turn_session(cfg)], n_slots=1)
    eos = probe.requests[0].turns[0].tokens[1]   # 2nd greedy token of turn 1
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False,
                    eos_id=int(eos))
    res = engine.serve([_two_turn_session(cfg)], n_slots=1)
    sess = res.requests[0]
    t1 = sess.turns[0].tokens
    assert t1 == probe.requests[0].turns[0].tokens[:len(t1)]
    assert t1[-1] == eos and len(t1) <= 2 + 1
    assert len(sess.turns[1].tokens) >= 1, "turn 2 must still run"


# ---------------------------------------------------------------------------
# Per-request sampling / RNG
# ---------------------------------------------------------------------------
def _mixed_trace(cfg, n=4, gen=5):
    out = []
    for i in range(n):
        sp = SamplerParams(temperature=0.9 if i % 2 else 0.0, top_k=20,
                           top_p=0.95)
        out.append(Request(
            uid=i, prompt=np.random.default_rng(10 + i).integers(
                0, cfg.vocab, size=(16 + 8 * i,)).astype(np.int32),
            max_new=gen, sampling=sp))
    return out


def test_sampled_outputs_independent_of_coscheduling(params):
    """fold_in(base, uid, step) keys: sampled tokens must not change with
    slot count, admission order, or co-scheduled requests."""
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    trace = _mixed_trace(cfg)
    r2 = engine.serve(copy.deepcopy(trace), n_slots=2, seed=42)
    r3 = engine.serve(copy.deepcopy(trace), n_slots=3, seed=42)
    r1 = engine.serve(copy.deepcopy(trace), n_slots=1, seed=42)
    shuffled = copy.deepcopy(trace)[::-1]
    r4 = engine.serve(shuffled, n_slots=2, seed=42)
    for i in range(len(trace)):
        assert r2.requests[i].tokens == r3.requests[i].tokens
        assert r2.requests[i].tokens == r1.requests[i].tokens
        assert r2.requests[i].tokens == r4.requests[i].tokens
    # different seed -> different samples for the temperature>0 requests
    r5 = engine.serve(copy.deepcopy(trace), n_slots=2, seed=43)
    assert any(r5.requests[i].tokens != r2.requests[i].tokens
               for i in (1, 3)), "seed must drive the sampled requests"
    # greedy rows are seed-independent
    for i in (0, 2):
        assert r5.requests[i].tokens == r2.requests[i].tokens


def test_mixed_batch_single_dispatch_per_token(params):
    """A batch mixing greedy and sampled requests must run exactly ONE
    jitted dispatch per decode token, with host-side sampling only at turn
    starts (prefill/extend logits)."""
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    trace = _mixed_trace(cfg)
    calls = {"sampled": 0, "greedy": 0}
    orig_s, orig_g = engine._step_sampled, engine._step_greedy

    def spy_s(*a, **k):
        calls["sampled"] += 1
        return orig_s(*a, **k)

    def spy_g(*a, **k):
        calls["greedy"] += 1
        return orig_g(*a, **k)

    engine._step_sampled, engine._step_greedy = spy_s, spy_g
    try:
        res = engine.serve(copy.deepcopy(trace), n_slots=2, seed=0)
    finally:
        engine._step_sampled, engine._step_greedy = orig_s, orig_g
    assert calls["greedy"] == 0, "mixed batch must use the fused sampler"
    assert calls["sampled"] == res.n_steps, \
        "exactly one jitted dispatch per lock-step token"
    assert engine.last_host_samples == sum(s.n_turns for s in trace), \
        "host sampling only on per-turn admission logits"


def test_all_greedy_trace_keeps_argmax_fused_step(params):
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    trace = [Request(uid=0, prompt=np.random.default_rng(0).integers(
        0, cfg.vocab, size=(24,)).astype(np.int32), max_new=4)]
    calls = {"sampled": 0}
    orig = engine._step_sampled
    engine._step_sampled = lambda *a, **k: (calls.__setitem__(
        "sampled", calls["sampled"] + 1) or orig(*a, **k))
    try:
        engine.serve(trace, n_slots=1)
    finally:
        engine._step_sampled = orig
    assert calls["sampled"] == 0


# ---------------------------------------------------------------------------
# Stop sequences / streaming / idle accounting
# ---------------------------------------------------------------------------
def test_stop_sequence_trims_output_and_ends_turn(params):
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    probe = engine.serve([_two_turn_session(cfg, gen1=6)], n_slots=1)
    toks = probe.requests[0].turns[0].tokens
    stop = (toks[1], toks[2])
    # expected greedy trajectory under the stop rule (greedy tokens repeat
    # on random weights, so the match may land before position 3)
    exp_sampled = []
    for tk in toks:
        exp_sampled.append(tk)
        if len(exp_sampled) >= 2 and tuple(exp_sampled[-2:]) == stop:
            break
    sess = _two_turn_session(cfg, gen1=6)
    sess.turns[0].stop = (stop,)
    res = engine.serve([sess], n_slots=1)
    turn = res.requests[0].turns[0]
    assert turn.sampled == exp_sampled, "raw history keeps the stop tokens"
    assert turn.tokens == exp_sampled[:-2], \
        "matched stop suffix must be trimmed from the public tokens"
    assert len(res.requests[0].turns[1].tokens) == sess.turns[1].max_new, \
        "turn 2 must still run after a stop match"


def test_on_token_streams_every_sampled_token(params):
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    trace = make_session_trace(np.random.default_rng(2), 3, cfg.vocab,
                               n_turns=2, first_lens=(16, 24),
                               delta_lens=(8,), gen_lens=(3, 5),
                               temperatures=(0.0, 0.7))
    streamed = []
    res = engine.serve(copy.deepcopy(trace), n_slots=2,
                       on_token=lambda uid, tok: streamed.append((uid, tok)))
    expect = [(s.uid, tok) for s in res.requests.values()
              for t in s.turns for tok in t.sampled]
    assert sorted(streamed) == sorted(expect)
    # per-uid order is generation order
    for s in res.requests.values():
        mine = [tok for uid, tok in streamed if uid == s.uid]
        assert mine == [tok for t in s.turns for tok in t.sampled]


def test_open_loop_idle_is_slept_and_excluded_from_throughput(params):
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(4)
    trace = [
        Request(uid=0, prompt=rng.integers(0, cfg.vocab, size=(16,))
                .astype(np.int32), max_new=2, arrival_s=0.0),
        Request(uid=1, prompt=rng.integers(0, cfg.vocab, size=(16,))
                .astype(np.int32), max_new=2, arrival_s=0.6),
    ]
    # warm the jit so request 0 finishes well before request 1 arrives
    engine.serve(copy.deepcopy(trace[:1]), n_slots=1)
    res = engine.serve(copy.deepcopy(trace), n_slots=1)
    assert len(res.requests) == 2
    assert res.idle_s > 0.2, "the gap to arrival #2 must be booked as idle"
    assert res.wall_s > res.idle_s
    busy_tps = res.total_new_tokens / (res.wall_s - res.idle_s)
    assert res.tokens_per_s == pytest.approx(busy_tps, rel=1e-6)


# ---------------------------------------------------------------------------
# Fallback + compat
# ---------------------------------------------------------------------------
def test_ssm_arch_falls_back_to_reprefill():
    cfg = get_config("zamba2-2.7b", reduced=True).replace(dtype="float32")
    assert not MD.can_extend(cfg)
    params = MD.init_model(jax.random.key(1), cfg)
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    assert not engine.can_extend
    rng = np.random.default_rng(0)
    sess = Session(uid=0, turns=[
        Turn(prompt=rng.integers(0, cfg.vocab, size=(24,)).astype(np.int32),
             max_new=3),
        Turn(prompt=rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
             max_new=3)])
    res = engine.serve([sess], n_slots=1, reuse="extend")   # silent fallback
    assert all(len(t.tokens) == 3 for t in res.requests[0].turns)


def test_session_total_len_admission_guard(params):
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    big = Session(uid=0, turns=[
        Turn(prompt=np.zeros((150,), np.int32), max_new=8),
        Turn(prompt=np.zeros((30,), np.int32), max_new=8)])
    with pytest.raises(AssertionError, match="cache too small"):
        engine.serve([big], n_slots=1)


def test_zero_budget_turn_rejected(params):
    """max_new=0 would sample a token the total_len() guard never counted
    (potentially into the reserved cache_slack tail) — refused up front."""
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    bad = Session(uid=0, turns=[
        Turn(prompt=np.zeros((8,), np.int32), max_new=2),
        Turn(prompt=np.zeros((4,), np.int32), max_new=0)])
    with pytest.raises(AssertionError, match="at least one"):
        engine.serve([bad], n_slots=1)
