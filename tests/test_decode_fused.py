"""PR 3 gates: the compiled single-dispatch decode path and its contracts.

* kernel-vs-oracle equivalence on REAL span tables — every span-emitting
  policy's ``select`` + ``assemble_spans`` output (not synthetic spans),
  over padded/partial indexes and ``t`` within one ``max_chunk`` of the
  logical cache boundary (the tail-slack read region);
* engine-level: ``use_kernel=True`` (interpret) greedy == pure-jnp greedy
  for ALL five registered policies, including a run that fills the cache to
  exactly its logical capacity;
* the no-copy contract: the ``sparse_chunk_attention`` jaxpr contains no
  cache-sized pad/concatenate (the pre-slack design copied the whole K/V
  cache every decode step);
* ``lazy_update`` capacity edge (``chunk_count == M``): drop-new semantics
  — the regression for the slot-``M-1`` overwrite corruption;
* ``update_batched`` cadence gate == ungated vmap, bit for bit;
* backend-aware ``interpret`` resolution precedence.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LycheeConfig, get_config
from repro.core import build_index, chunk_sequence, synthetic_delimiter_table
from repro.core.attention import assemble_spans
from repro.core.policy import make_policy
from repro.core.types import cache_slack, usable_rows
from repro.core.update import lazy_update, maybe_lazy_update
from repro.kernels import ops, ref
from repro.kernels.sparse_attention import sparse_chunk_attention
from repro.models import model as MD
from repro.serving import Engine

jax.config.update("jax_enable_x64", False)

SPAN_POLICIES = ("lychee", "quest", "clusterkv", "streaming")
ALL_POLICIES = SPAN_POLICIES + ("dense",)
N_CACHE = 128


def _ly(policy="lychee", **kw):
    base = dict(policy=policy, enabled=policy != "dense", budget=64, sink=4,
                buffer_size=16, max_coarse=8, top_kg=4, full_attn_layers=0,
                quest_page=8, ckv_tokens_per_cluster=8)
    base.update(kw)
    return LycheeConfig(**base)


def _policy_state(pol, keys, tokens, n_cache):
    """Build the policy's selection state the way prefill does."""
    if not pol.stateful:
        return None
    if pol.needs_layout:
        table = jnp.asarray(synthetic_delimiter_table(997))
        layout = chunk_sequence(tokens, table, pol.cfg)
        return pol.build(keys, layout, n_cache)
    return pol.build(keys, None, n_cache)


# ---------------------------------------------------------------------------
# kernel vs oracle on policy-emitted span tables
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", SPAN_POLICIES)
@pytest.mark.parametrize("t_off", [0, 1])      # boundary and boundary-1
def test_kernel_matches_oracle_on_policy_spans(policy, t_off):
    """select -> assemble_spans -> kernel == oracle, with ``t`` within one
    ``max_chunk`` of the usable capacity (span reads land in the reserved
    tail-slack rows)."""
    ly = _ly(policy)
    rng = np.random.default_rng(7 + t_off)
    H, S, d = 2, 96, 32
    keys = jnp.asarray(rng.standard_normal((H, S, d)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, 997, size=(S,)), jnp.int32)
    pol = make_policy(policy, ly)
    state = _policy_state(pol, keys, tokens, N_CACHE)

    rows = N_CACHE
    usable = usable_rows(N_CACHE, ly)
    k = jnp.asarray(rng.standard_normal((1, H, rows, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, H, rows, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, H, 2, d)), jnp.float32)
    probe = q.mean(axis=2)[0]

    # t at/inside the last max_chunk before the usable boundary — the
    # hardest case for the tail-slack contract
    for t in (usable - t_off, usable - pol.span_len + 1, S + 3):
        s, ln = pol.select(state, probe, jnp.int32(t))
        starts, lens = assemble_spans(s, ln, jnp.int32(t), ly,
                                      max_chunk=pol.span_len)
        starts, lens = starts[None], lens[None]               # (1, H, C)
        got = ops.chunk_attention(q, k, v, starts, lens,
                                  max_chunk=pol.span_len, scale=0.17,
                                  interpret=True)
        want = ref.sparse_chunk_attention_ref(q, k, v, starts, lens,
                                              max_chunk=pol.span_len,
                                              scale=0.17)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
        # the slack contract: every live span's DMA stays in bounds
        live = np.asarray(lens)[0] > 0
        assert (np.asarray(starts)[0][live] + pol.span_len <= rows).all()


def test_kernel_matches_oracle_on_padded_partial_index():
    """A short-prompt lychee index padded to cache capacity (partial/invalid
    slots everywhere) must still produce kernel == oracle."""
    ly = _ly("lychee")
    rng = np.random.default_rng(3)
    H, S, d = 2, 24, 32                           # S << N_CACHE: mostly pad
    keys = jnp.asarray(rng.standard_normal((H, S, d)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, 997, size=(S,)), jnp.int32)
    pol = make_policy("lychee", ly)
    state = _policy_state(pol, keys, tokens, N_CACHE)

    k = jnp.asarray(rng.standard_normal((1, H, N_CACHE, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, H, N_CACHE, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, H, 4, d)), jnp.float32)
    s, ln = pol.select(state, q.mean(axis=2)[0], jnp.int32(S))
    starts, lens = assemble_spans(s, ln, jnp.int32(S), ly)
    starts, lens = starts[None], lens[None]
    got = ops.chunk_attention(q, k, v, starts, lens, scale=0.2,
                              interpret=True)
    want = ref.sparse_chunk_attention_ref(q, k, v, starts, lens, scale=0.2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# engine-level: kernel path == jnp path for ALL five policies
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_engine_kernel_matches_ref_per_policy(policy):
    cfg_ref = get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=_ly(policy, use_kernel=False))
    cfg_ker = cfg_ref.replace(lychee=_ly(policy, use_kernel=True))
    params = MD.init_model(jax.random.key(2), cfg_ref)
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, cfg_ref.vocab, size=(1, 64)).astype(np.int32)
    toks = {}
    for name, cfg in [("ref", cfg_ref), ("kernel", cfg_ker)]:
        engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
        toks[name] = engine.generate(prompts, 5).tokens
    np.testing.assert_array_equal(toks["ref"], toks["kernel"])


def test_engine_kernel_fills_cache_to_usable_capacity():
    """prompt + max_new == usable_rows exactly: the last decode steps place
    the recent-window spans flush against the usable boundary, so their
    DMAs read into the reserved tail rows. Greedy tokens must match the
    jnp path."""
    n_cache = 112
    cfg_ref = get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=_ly("lychee", use_kernel=False))
    cfg_ker = cfg_ref.replace(lychee=_ly("lychee", use_kernel=True))
    assert usable_rows(n_cache, cfg_ref.lychee) == 96
    params = MD.init_model(jax.random.key(3), cfg_ref)
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg_ref.vocab, size=(1, 88)).astype(np.int32)
    toks = {}
    for name, cfg in [("ref", cfg_ref), ("kernel", cfg_ker)]:
        engine = Engine(cfg, params, n_cache=n_cache, donate_state=False)
        toks[name] = engine.generate(prompts, 8).tokens     # 88 + 8 == 96
    np.testing.assert_array_equal(toks["ref"], toks["kernel"])


# ---------------------------------------------------------------------------
# tail-slack layout contract
# ---------------------------------------------------------------------------
def test_reserved_tail_rows_stay_zero_and_capacity_is_enforced():
    ly = _ly("lychee")
    cfg = get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=ly)
    assert cache_slack(ly) == 16
    usable = usable_rows(N_CACHE, ly)
    assert usable == N_CACHE - 16
    params = MD.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)

    # decode right up to the usable boundary: the reserved tail must stay
    # zero (it is the kernel's DMA-overrun region) and row counts must be
    # unchanged by the slack design (shard splits stay even)
    prompts = rng.integers(0, cfg.vocab, size=(1, usable - 3)).astype(
        np.int32)
    logits, state = MD.prefill(params, jnp.asarray(prompts), cfg, N_CACHE)
    for _ in range(3):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, state = MD.decode_step(params, tok, state, cfg)
    assert int(state["t"][0]) == usable
    k_leaf = np.asarray(state["groups"][0]["k"])
    assert k_leaf.shape[-2] == N_CACHE
    assert not k_leaf[..., usable:, :].any()        # reserved tail: zero
    assert k_leaf[..., usable - 1, :].any()         # last usable row: written

    # the engine enforces the usable capacity at admission
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    short = prompts[:, :32]
    with pytest.raises(AssertionError, match="reserved"):
        engine.generate(short, N_CACHE - 32 + 1)
    assert engine.usable == usable


# ---------------------------------------------------------------------------
# no-copy contract: jaxpr of the kernel wrapper never pads the cache
# ---------------------------------------------------------------------------
# The ad-hoc ``_all_eqns``/``_subjaxprs`` walker that used to live here is
# now THE shared implementation in ``repro.analysis.walker``; this test runs
# the registered ``no-cache-materialization`` rule over the same trace.
def test_sparse_attention_jaxpr_has_no_cache_copy():
    from repro.analysis import RuleContext, get_rule

    B, H, G, d, N, C = 2, 2, 4, 32, 128 + 16, 10
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, N, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, N, d)), jnp.float32)
    starts = jnp.zeros((B, H, C), jnp.int32)
    lens = jnp.zeros((B, H, C), jnp.int32)
    fn = functools.partial(sparse_chunk_attention, max_chunk=16,
                           interpret=True)
    jaxpr = jax.make_jaxpr(fn)(q, k, v, starts, lens)
    ctx = RuleContext(target="sparse_chunk_attention",
                      cache_elems=B * H * N * d)
    offenders = get_rule("no-cache-materialization").run(jaxpr, ctx)
    assert not offenders, (
        "cache-sized copy in the decode hot path:\n"
        + "\n".join(str(f) for f in offenders))


# ---------------------------------------------------------------------------
# lazy_update capacity edge (chunk_count == M): drop-new, never corrupt
# ---------------------------------------------------------------------------
def _full_index(ly, rng, H=2, S=64, d=16, n_cache=64):
    """A real index grafted until chunk_count == M."""
    keys = jnp.asarray(rng.standard_normal((H, S, d)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, 997, size=(S,)), jnp.int32)
    table = jnp.asarray(synthetic_delimiter_table(997))
    layout = chunk_sequence(tokens, table, ly)
    idx = build_index(keys, layout, ly)
    M = idx.chunk_start.shape[0]
    step = 0
    while int(idx.chunk_count) < M:
        nk = jnp.asarray(rng.standard_normal((H, d)), jnp.float32)
        idx = lazy_update(idx, nk, 40 + step, ly.max_chunk, ly)
        step += 1
    return idx, keys, M


def test_lazy_update_at_capacity_drops_new_chunk():
    ly = _ly("lychee")
    rng = np.random.default_rng(11)
    idx, keys, M = _full_index(ly, rng)
    assert int(idx.chunk_count) == M

    before = jax.tree.map(np.asarray, idx)
    nk = jnp.asarray(rng.standard_normal(idx.chunk_key.shape[::2]),
                     jnp.float32)
    after = lazy_update(idx, nk, 999, ly.max_chunk, ly)
    # drop-new: EVERY leaf unchanged — in particular slot M-1's
    # chunk_start/chunk_len, which the old code kept overwriting while
    # stale member lists still pointed at it
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # member lists -> chunk table stays consistent: every referenced slot's
    # span is the one it was registered with
    assert int(after.chunk_count) == M
    assert (np.asarray(after.chunk_start)[:M] ==
            before.chunk_start[:M]).all()


def test_maybe_lazy_update_not_due_when_full():
    ly = _ly("lychee")
    rng = np.random.default_rng(12)
    idx, keys, M = _full_index(ly, rng)
    t = ly.max_chunk * 6                          # on-cadence
    out = maybe_lazy_update(idx, keys, t, ly)
    for a, b in zip(jax.tree.leaves(idx), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# update_batched cadence gate == ungated vmap
# ---------------------------------------------------------------------------
def test_lychee_update_batched_matches_ungated_vmap():
    ly = _ly("lychee")
    rng = np.random.default_rng(5)
    pol = make_policy("lychee", ly)
    H, S, d, B = 2, 64, 16, 3
    keys = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, 997, size=(B, S)), jnp.int32)
    table = jnp.asarray(synthetic_delimiter_table(997))
    layout = jax.vmap(lambda tk: chunk_sequence(tk, table, ly))(tokens)
    state = pol.build_batched(keys, layout, N_CACHE)

    mc = ly.max_chunk
    for t in ([mc * 2, mc * 3 + 1, mc * 4],       # one slot due
              [mc + 1, mc + 2, mc + 3]):          # no slot due -> gate skips
        tt = jnp.asarray(t, jnp.int32)
        got = pol.update_batched(state, keys, tt)
        want = jax.vmap(lambda s, k, tb: maybe_lazy_update(s, k, tb, ly))(
            state, keys, tt)
        # same math; tolerance only absorbs XLA fusion differences between
        # the cond-wrapped and bare vmap compilations (~1e-9 on f32)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# backend-aware interpret resolution
# ---------------------------------------------------------------------------
def test_interpret_resolution_precedence():
    on_tpu = jax.default_backend() == "tpu"
    assert ops.resolve_interpret(None) == (not on_tpu)    # backend default
    assert ops.resolve_interpret(True) is True            # explicit wins
    assert ops.resolve_interpret(False) is False
    old = ops.INTERPRET
    try:
        ops.INTERPRET = False                             # module override
        assert ops.resolve_interpret(None) is False
        assert ops.resolve_interpret(True) is True        # explicit beats it
    finally:
        ops.INTERPRET = old
