"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes and dtypes per the deliverable spec.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _spans(rng, C, N, max_chunk, frac_empty=0.2):
    starts = rng.integers(0, max(1, N - max_chunk), size=C).astype(np.int32)
    lens = rng.integers(1, max_chunk + 1, size=C).astype(np.int32)
    empty = rng.random(C) < frac_empty
    lens[empty] = 0
    return jnp.asarray(starts), jnp.asarray(lens)


@pytest.mark.parametrize("H,N,d,M", [(1, 64, 32, 8), (2, 256, 64, 24),
                                     (4, 512, 128, 64), (3, 130, 80, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("pooling", ["mean", "max"])
def test_chunk_pool(H, N, d, M, dtype, pooling):
    rng = np.random.default_rng(42 + M)
    keys = jnp.asarray(rng.standard_normal((H, N, d)), dtype)
    starts, lens = _spans(rng, M, N, 16)
    got = ops.pool_chunk_keys(keys, starts, lens, pooling=pooling)
    want = ref.chunk_pool_ref(keys, starts, lens, pooling=pooling)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("H,L,d", [(1, 16, 32), (2, 128, 64), (4, 300, 128),
                                   (8, 64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hier_score(H, L, d, dtype):
    rng = np.random.default_rng(7)
    probe = jnp.asarray(rng.standard_normal((H, d)), dtype)
    cent = jnp.asarray(rng.standard_normal((H, L, d)), dtype)
    rad = jnp.asarray(rng.random((H, L)), dtype)
    valid = jnp.asarray(rng.random((H, L)) > 0.3)
    got = ops.score_upper_bound(probe, cent, rad, valid)
    want = ref.hier_score_ref(probe, cent, rad, valid)
    tol = 1e-4 if dtype == jnp.float32 else 0.5
    v = np.asarray(valid)
    np.testing.assert_allclose(np.asarray(got)[v], np.asarray(want)[v],
                               atol=tol, rtol=tol)
    assert (np.asarray(got)[~v] <= -1e29).all()


@pytest.mark.parametrize("B,Hkv,G,dk,dv,N,C",
                         [(1, 1, 1, 32, 32, 128, 4),
                          (2, 2, 4, 64, 64, 256, 12),
                          (1, 4, 2, 128, 128, 512, 33),
                          (2, 1, 8, 128, 64, 300, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("softcap", [0.0, 30.0])
def test_sparse_attention(B, Hkv, G, dk, dv, N, C, dtype, softcap):
    rng = np.random.default_rng(C * 7 + B)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, dk)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, dk)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, dv)), dtype)
    starts = jnp.stack([jnp.stack([_spans(rng, C, N, 16)[0]
                                   for _ in range(Hkv)])
                        for _ in range(B)])
    lens = jnp.stack([jnp.stack([_spans(rng, C, N, 16)[1]
                                 for _ in range(Hkv)])
                      for _ in range(B)])
    scale = 1.0 / np.sqrt(dk)
    got = ops.chunk_attention(q, k, v, starts, lens, scale=scale,
                              softcap=softcap)
    want = ref.sparse_chunk_attention_ref(q, k, v, starts, lens, scale=scale,
                                          softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_sparse_attention_all_empty():
    """All spans masked -> output must be zeros, not NaN (every DMA is
    skipped by the ``pl.when`` guard, so scratch is never written)."""
    B, Hkv, G, d, N, C = 1, 1, 2, 32, 64, 4
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, d)), jnp.float32)
    starts = jnp.zeros((B, Hkv, C), jnp.int32)
    lens = jnp.zeros((B, Hkv, C), jnp.int32)
    got = ops.chunk_attention(q, k, v, starts, lens, scale=0.1)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), 0.0, atol=1e-6)


def test_sparse_attention_len0_spans_cost_nothing_and_change_nothing():
    """Interleaving len == 0 padding spans (whose DMAs the kernel skips)
    must give the same result as the same table with them masked by the
    oracle AND as the compacted table without them."""
    B, Hkv, G, d, N, mc = 1, 2, 2, 32, 128, 16
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, d)), jnp.float32)
    live_s = np.array([[0, 32, 64], [16, 48, 96]], np.int32)
    live_l = np.array([[16, 9, 16], [5, 16, 12]], np.int32)
    # interleave empties (start values deliberately junk-but-clippable)
    pad_s = np.array([[0, 7, 0, 32, 0, 64, 125], [0, 16, 3, 48, 0, 96, 1]],
                     np.int32)
    pad_l = np.array([[0, 0, 0, 0, 0, 0, 0], [0, 0, 0, 0, 0, 0, 0]],
                     np.int32)
    pad_s[:, 1::2] = live_s
    pad_l[:, 1::2] = live_l
    a = ops.chunk_attention(q, k, v, jnp.asarray(live_s)[None],
                            jnp.asarray(live_l)[None], scale=0.2)
    b = ops.chunk_attention(q, k, v, jnp.asarray(pad_s)[None],
                            jnp.asarray(pad_l)[None], scale=0.2)
    want = ref.sparse_chunk_attention_ref(
        q, k, v, jnp.asarray(pad_s)[None], jnp.asarray(pad_l)[None],
        scale=0.2)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(b), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_sparse_attention_span_at_buffer_boundary():
    """A live span starting at exactly N - max_chunk (the last legal DMA
    origin — where tail-slack reads land in a real cache) matches the
    oracle."""
    B, Hkv, G, d, N, mc = 1, 1, 2, 32, 96, 16
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((B, Hkv, G, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, N, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, N, d)), jnp.float32)
    starts = jnp.asarray([[[0, N - mc, N - mc]]], jnp.int32)
    lens = jnp.asarray([[[mc, mc, 3]]], jnp.int32)
    got = ops.chunk_attention(q, k, v, starts, lens, scale=0.2)
    want = ref.sparse_chunk_attention_ref(q, k, v, starts, lens, scale=0.2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
