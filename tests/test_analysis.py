"""Gates for ``repro.analysis`` — the static hot-path analyzer.

Every rule must be PROVEN LIVE: for each one there is a deliberately-bad
input (a cache-sized ``jnp.pad``, a non-donated state arg, an unruled
sharded leaf, a mispaired DMA, ...) asserting the rule fires with the right
location — a lint rule nobody has seen fail is indistinguishable from a
rule that never runs. The clean-path test then asserts the shipped decode
paths produce zero non-suppressed findings, and the CLI smoke test runs the
module entry point end to end.
"""
import json

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis import (Finding, Report, RuleContext, Severity,
                            Suppression, all_eqns, get_rule,
                            run_jaxpr_rules, walk)
from repro.analysis import targets as TG
from repro.analysis.suppressions import SUPPRESSIONS
from repro.kernels.pallas_compat import HBM

CACHE = 384 * 64          # the seeded tests' "cache-sized" threshold


def _ctx(**kw):
    kw.setdefault("target", "seeded")
    kw.setdefault("cache_elems", CACHE)
    return RuleContext(**kw)


# ---------------------------------------------------------------------------
# The walker (the old test_decode_fused helpers, now shared)
# ---------------------------------------------------------------------------
def test_walker_reaches_nested_jaxprs():
    def inner(x):
        return jax.lax.scan(lambda c, t: (c + t, c), x.sum(), x)[0]

    jx = jax.make_jaxpr(lambda x: jax.jit(inner)(x) * 2)(jnp.ones((4,)))
    prims = [e.primitive.name for e in all_eqns(jx.jaxpr)]
    assert "scan" in prims, "walker must descend into pjit bodies"
    adds = [s for s in walk(jx) if s.eqn.primitive.name == "add"]
    assert any("scan" in s.path for s in adds), \
        "EqnSite.path must record enclosing primitives"


# ---------------------------------------------------------------------------
# Seeded violations: each rule fires, with the right location
# ---------------------------------------------------------------------------
def test_cache_materialization_fires_on_seeded_pad():
    k = jax.ShapeDtypeStruct((384, 64), jnp.float32)
    jx = jax.make_jaxpr(lambda k: jnp.pad(k, ((0, 8), (0, 0))))(k)
    fs = get_rule("no-cache-materialization").run(jx, _ctx())
    assert len(fs) == 1 and fs[0].severity == Severity.ERROR
    assert "pad" in fs[0].message
    assert "test_analysis.py" in fs[0].location, fs[0].location


def test_cache_materialization_ignores_small_and_disabled():
    k = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    jx = jax.make_jaxpr(lambda k: jnp.pad(k, ((0, 8), (0, 0))))(k)
    assert not get_rule("no-cache-materialization").run(jx, _ctx())
    big = jax.ShapeDtypeStruct((384, 64), jnp.float32)
    jx = jax.make_jaxpr(lambda k: jnp.pad(k, ((0, 8), (0, 0))))(big)
    assert not get_rule("no-cache-materialization").run(
        jx, _ctx(cache_elems=0)), "cache_elems=0 disables the rule"


def test_host_callback_fires_on_debug_print():
    def f(x):
        jax.debug.print("x={}", x.sum())
        return x * 2

    jx = jax.make_jaxpr(f)(jnp.ones((4,)))
    fs = get_rule("no-host-callback").run(jx, _ctx())
    assert len(fs) == 1 and fs[0].severity == Severity.ERROR
    assert "debug_callback" in fs[0].message


def test_dtype_discipline_fires_on_bulk_upcast():
    k = jax.ShapeDtypeStruct((384, 64), jnp.bfloat16)
    jx = jax.make_jaxpr(lambda k: k.astype(jnp.float32))(k)
    ctx = _ctx(cache_dtype=jnp.bfloat16)
    fs = get_rule("dtype-discipline").run(jx, ctx)
    assert len(fs) == 1 and fs[0].severity == Severity.WARNING
    assert "bfloat16" in fs[0].message and "float32" in fs[0].message
    # an f32 cache has nothing to upcast from: rule self-disables
    assert not get_rule("dtype-discipline").run(
        jx, _ctx(cache_dtype=jnp.float32))


# ---------------------------------------------------------------------------
# Seeded Pallas violations (traced only — no TPU, nothing lowers)
# ---------------------------------------------------------------------------
def _bad_dma_jaxpr():
    def bad_kernel(x_hbm, o_ref, scr, sem):
        cp = pltpu.make_async_copy(x_hbm.at[pl.ds(0, 8), :], scr.at[...],
                                   sem)
        cp.start()                 # deliberately never awaited
        o_ref[...] = scr[...]

    fn = pl.pallas_call(
        bad_kernel,
        in_specs=[pl.BlockSpec(memory_space=HBM)],
        out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, 128), jnp.float32),
                        pltpu.SemaphoreType.DMA],
        interpret=False)
    return jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((16, 128), jnp.float32))


def test_dma_pairing_fires_on_unawaited_start():
    fs = get_rule("pallas-dma-pairing").run(_bad_dma_jaxpr(), _ctx())
    assert len(fs) == 1 and fs[0].severity == Severity.ERROR
    assert "1 dma_start vs 0 dma_wait" in fs[0].message
    assert "bad_kernel" in fs[0].location


def _indivisible_jaxpr():
    def k2(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    fn = pl.pallas_call(
        k2, grid=(3,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((20, 128), jnp.float32),
        interpret=False)
    return jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((20, 128), jnp.float32))


def test_grid_divisibility_fires_on_partial_tile():
    fs = get_rule("pallas-grid-divisibility").run(_indivisible_jaxpr(),
                                                  _ctx())
    assert fs and all(f.severity == Severity.WARNING for f in fs)
    assert "does not divide" in fs[0].message


def test_vmem_budget_fires_when_limit_shrinks():
    jx = _indivisible_jaxpr()
    assert not get_rule("pallas-vmem-budget").run(jx, _ctx())
    fs = get_rule("pallas-vmem-budget").run(
        jx, _ctx(vmem_limit_bytes=4096))
    assert fs and "exceeds budget" in fs[0].message


def test_shipped_kernels_pass_pallas_rules():
    for t in TG.build_kernel_targets():
        fs = run_jaxpr_rules(t.closed_jaxpr, t.ctx, rules=t.rules)
        assert not fs, f"{t.name}: {[str(f) for f in fs]}"


# ---------------------------------------------------------------------------
# Donation audit: a non-donating engine is flagged, the shipped one is not
# ---------------------------------------------------------------------------
def test_donation_audit_fires_on_undonated_state():
    from repro.analysis.donation import audit_engine_donation
    from repro.serving import Engine

    cfg, params = TG.arch_config("gqa"), TG.arch_params("gqa")
    bad = Engine(cfg, params, n_cache=TG.N_CACHE, donate_state=False)
    fs = audit_engine_donation(bad, target="seeded", compile_check=False)
    flagged = {f.location for f in fs}
    assert "_step_greedy" in flagged and "_prefill_slot" in flagged
    assert all(f.severity == Severity.ERROR for f in fs)

    good = Engine(cfg, params, n_cache=TG.N_CACHE)
    assert not audit_engine_donation(good, target="clean",
                                     compile_check=False)


# ---------------------------------------------------------------------------
# Sharding audit: unruled + large-replicated leaves
# ---------------------------------------------------------------------------
def test_sharding_audit_fires_on_unruled_leaf():
    from repro.analysis.shardcheck import audit_state_sharding

    state = {"groups": ({"k": jax.ShapeDtypeStruct((2, 2, 2, 384, 64),
                                                   jnp.bfloat16),
                         "rogue": jax.ShapeDtypeStruct((2, 2, 384, 64),
                                                       jnp.bfloat16)},),
             "t": jax.ShapeDtypeStruct((2,), jnp.int32)}
    fs = audit_state_sharding(state, target="seeded", cache_elems=CACHE)
    assert any("rogue" in f.message and "no layout rule" in f.message
               for f in fs), [str(f) for f in fs]


def test_sharding_audit_fires_on_large_replicated_leaf():
    from repro.analysis.shardcheck import audit_state_sharding

    # odd batch/head/ctx dims: every rule falls back to replication,
    # leaving a cache-sized leaf fully replicated
    state = {"k": jax.ShapeDtypeStruct((2, 1, 3, 385, 64), jnp.bfloat16)}
    fs = audit_state_sharding(state, target="seeded",
                              cache_elems=3 * 385 * 64)
    assert any("fully replicated" in f.message for f in fs), \
        [str(f) for f in fs]


def test_sharding_audit_clean_on_shipped_states():
    from repro.analysis.shardcheck import audit_state_sharding

    for arch in TG.ARCHS:
        shapes = TG.state_shapes(arch, "lychee")
        fs = audit_state_sharding(
            shapes, target=f"state[{arch}]",
            cache_elems=TG.cache_leaf_elems(shapes))
        assert not fs, f"{arch}: {[str(f) for f in fs]}"


# ---------------------------------------------------------------------------
# Clean path: the shipped decode jaxprs produce no non-suppressed findings
# ---------------------------------------------------------------------------
def test_shipped_decode_paths_clean():
    report = Report()
    for t in TG.build_jaxpr_targets(("gqa",), ("lychee",)):
        report.targets.append(t.name)
        report.extend(run_jaxpr_rules(t.closed_jaxpr, t.ctx,
                                      rules=t.rules))
    report.apply_suppressions(SUPPRESSIONS)
    assert not report.active(Severity.NOTE), \
        [str(f) for f in report.active(Severity.NOTE)]
    # the extend target's slice_slot finding is suppressed WITH a reason,
    # not absent — intentional exceptions must stay visible
    sup = [f for f in report.findings if f.suppressed]
    assert sup and all(f.suppress_reason for f in sup)


# ---------------------------------------------------------------------------
# Report / suppression / severity machinery
# ---------------------------------------------------------------------------
def test_report_gating_and_serialization():
    r = Report(rules=["r"], targets=["t"])
    r.extend([Finding("r", Severity.WARNING, "t", "warn msg", "loc1"),
              Finding("r", Severity.NOTE, "t", "note msg", "loc2")])
    assert len(r.active(Severity.WARNING)) == 1
    assert len(r.active(Severity.NOTE)) == 2
    assert not r.active(Severity.ERROR)
    r.apply_suppressions([Suppression("r", reason="known", match="warn")])
    assert not r.active(Severity.WARNING)
    blob = json.loads(r.to_json(Severity.WARNING))
    assert blob["failed"] is False
    assert blob["counts"]["suppressed"] == 1
    md = r.to_markdown()
    assert "known" in md and "note msg" in md


def test_suppression_requires_reason():
    with pytest.raises(AssertionError):
        Suppression("r", reason="   ")


def test_severity_parse():
    assert Severity.parse("error") is Severity.ERROR
    with pytest.raises(ValueError):
        Severity.parse("fatal")


# ---------------------------------------------------------------------------
# CLI smoke
# ---------------------------------------------------------------------------
def test_cli_list_rules(capsys):
    from repro.analysis.__main__ import main

    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for name in ("no-cache-materialization", "pallas-dma-pairing",
                 "donation", "sharding-audit", "compile-count"):
        assert name in out


def test_cli_rejects_unknown_rule():
    from repro.analysis.__main__ import main

    assert main(["--rules", "no-such-rule"]) == 2


def test_cli_end_to_end(tmp_path):
    from repro.analysis.__main__ import main

    jpath = tmp_path / "ANALYSIS.json"
    mpath = tmp_path / "ANALYSIS.md"
    rc = main(["--archs", "gqa", "--policies", "dense",
               "--skip", "donation", "sharding", "compiles", "kernels",
               "--json", str(jpath), "--markdown", str(mpath)])
    assert rc == 0
    blob = json.loads(jpath.read_text())
    assert blob["failed"] is False
    assert any(t.startswith("decode[gqa/dense]") for t in blob["targets"])
    assert "Static hot-path analysis" in mpath.read_text()
