"""SLO scheduling, overload degradation and cancellation tests.

Covers the robustness surface added with ``SLOConfig``:

* ``Scheduler.submit`` with ``max_pending`` but no SLO policy raises
  ``QueueFullError`` on an arrived burst — and with the SLO policy the
  same burst sheds lowest-priority-first with ``ShedResult`` records;
* cancellation never leaks: cancelling mid-queue, mid-prefill and
  mid-decode on the PAGED engine returns the pool to the exact
  pre-admission free-page count and leaves the radix prefix cache
  consistent (refcount ledger intact, drain leaves zero pages in use);
* degraded-mode semantics: an overload that shrinks one slot's
  retrieval budget keeps every NON-degraded co-scheduled session
  bit-identical to its unloaded solo oracle, flags exactly the degraded
  turns, and does this for each span policy (lychee / quest /
  clusterkv);
* priority-0 (premium) sessions are never shed and never degraded.
"""
import numpy as np
import pytest

import jax

from repro.configs.base import LycheeConfig, SLOConfig, get_config
from repro.models import model as MD
from repro.serving import (Engine, QueueFullError, Request, Scheduler,
                           Session, Turn)
from repro.serving.sampler import SamplerParams

N_CACHE = 160


def _cfg(policy="lychee", **serving):
    ly = LycheeConfig(budget=64, sink=4, buffer_size=16, max_coarse=8,
                      top_kg=4, full_attn_layers=0, policy=policy)
    cfg = get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=ly)
    if serving:
        cfg = cfg.replace(serving=cfg.serving.replace(**serving))
    return cfg


def _req(uid, rng, vocab, n=16, gen=4, **kw):
    return Request(uid, rng.integers(0, vocab, size=(n,)).astype(np.int32),
                   gen, **kw)


@pytest.fixture(scope="module")
def paged_engine():
    cfg = _cfg(paged=True, prefill_chunk=16,
               slo=SLOConfig(enabled=True, ttft_target_s=5.0,
                             max_pending=16))
    params = MD.init_model(jax.random.key(0), cfg)
    return cfg, Engine(cfg, params, n_cache=N_CACHE, donate_state=False)


# ---------------------------------------------------------------------------
# Scheduler-level queue bound (no engine needed)
# ---------------------------------------------------------------------------

def test_max_pending_without_slo_raises():
    rng = np.random.default_rng(0)
    sched = Scheduler(2, max_pending=3, order="fifo")
    for uid in range(3):
        assert sched.submit(_req(uid, rng, 100), now_s=0.0)
    with pytest.raises(QueueFullError):
        sched.submit(_req(3, rng, 100), now_s=0.0)
    # the bound counts ARRIVED sessions: a future arrival is not a queue
    late = _req(4, rng, 100)
    late.arrival_s = 60.0
    assert sched.submit(late, now_s=0.0)


def test_max_pending_slo_sheds_lowest_priority_first():
    rng = np.random.default_rng(1)
    sched = Scheduler(2, max_pending=3, order="slo", default_ttft_s=1.0)
    keep = [_req(0, rng, 100, priority=0),
            _req(1, rng, 100, priority=1),
            _req(2, rng, 100, priority=1)]
    for s in keep:
        assert sched.submit(s, now_s=0.0)
    # burst: a priority-2 straggler is itself refused...
    low = _req(3, rng, 100, priority=2)
    assert not sched.submit(low, now_s=0.0)
    assert low.outcome == "shed"
    assert sched.shed[3].reason == "queue_overflow"
    # ...while a premium arrival displaces the worst queued session
    prem = _req(4, rng, 100, priority=0)
    assert sched.submit(prem, now_s=0.0)
    shed_uids = set(sched.shed)
    assert 4 not in shed_uids and len(shed_uids) == 2
    assert all(sched.shed_sessions[u].priority > 0 for u in shed_uids)
    assert sched.pending == 3
    # every shed surfaced exactly once, disjoint from the queue
    assert shed_uids.isdisjoint({s.uid for s in sched.queued()})


def test_slo_order_prefers_priority_then_deadline():
    rng = np.random.default_rng(2)
    sched = Scheduler(1, order="slo", default_ttft_s=10.0)
    a = _req(0, rng, 100, priority=1)
    b = _req(1, rng, 100, priority=0)          # premium, later arrival
    a.arrival_s, b.arrival_s = 0.0, 1.0
    sched.submit_all([a, b])
    assert sched.next_ready(2.0) is b
    tight = _req(2, rng, 100, priority=0, ttft_target_s=0.01)
    tight.arrival_s = 1.5
    sched.submit(tight, now_s=2.0)
    assert sched.next_ready(2.0) is tight      # earlier deadline wins


# ---------------------------------------------------------------------------
# Cancellation: paged pools must return to their pre-admission state
# ---------------------------------------------------------------------------

def _pool_ledger_ok(loop):
    pool, spec = loop.pool, loop.spec
    refs = np.zeros((spec.n_pages,), np.int64)
    for pages in loop.slot_pages:
        for p in pages:
            refs[p] += 1
    for entry in pool._entries:
        for p in entry.pages:
            refs[p] += 1
    assert np.array_equal(refs, pool._ref)
    assert pool.pages_free + pool.pages_in_use == spec.n_pages


def test_cancel_mid_queue_paged_no_pages_touched(paged_engine):
    cfg, eng = paged_engine
    rng = np.random.default_rng(3)
    reqs = [_req(uid, rng, cfg.vocab, n=24, gen=4) for uid in range(3)]
    loop = eng.serve_loop(reqs, n_slots=2)
    free0 = loop.pool.pages_free
    reqs[2].cancel()                     # still queued: slots are busy
    loop.run()
    res = loop.result()
    assert set(res.cancelled) == {2}
    assert set(res.requests) == {0, 1}
    assert reqs[2].outcome == "cancelled"
    assert not reqs[2].tokens
    loop.pool.clear_prefix_cache()
    assert loop.pool.pages_free == free0
    _pool_ledger_ok(loop)


def test_cancel_mid_prefill_paged_reclaims_pages(paged_engine):
    cfg, eng = paged_engine
    rng = np.random.default_rng(4)
    # long prompt + chunked admission: cancellation lands mid-prefill
    victim = _req(0, rng, cfg.vocab, n=80, gen=8)
    loop = eng.serve_loop([victim], n_slots=2)
    free0 = loop.pool.pages_free
    loop.step()                          # admission starts, job in flight
    assert 0 in loop.jobs and loop.pool.pages_free < free0
    victim.cancel()
    loop.step()
    assert victim.outcome == "cancelled" and not loop.jobs
    loop.run()
    loop.pool.clear_prefix_cache()
    assert loop.pool.pages_free == free0, "mid-prefill cancel leaked pages"
    _pool_ledger_ok(loop)
    assert loop.result().metrics.cancelled == 1


def test_cancel_mid_decode_paged_reclaims_pages(paged_engine):
    cfg, eng = paged_engine
    rng = np.random.default_rng(5)
    victim = _req(0, rng, cfg.vocab, n=24, gen=64)
    other = _req(1, rng, cfg.vocab, n=24, gen=6)
    loop = eng.serve_loop([victim, other], n_slots=2)
    free0 = loop.pool.pages_free
    while len(victim.turns[0].sampled) < 3:     # decode well underway
        loop.step()
    victim.cancel()
    loop.run()
    res = loop.result()
    assert set(res.cancelled) == {0} and set(res.requests) == {1}
    assert 3 <= len(victim.turns[0].sampled) < 64
    loop.pool.clear_prefix_cache()
    assert loop.pool.pages_free == free0, "mid-decode cancel leaked pages"
    _pool_ledger_ok(loop)
    # the survivor is untouched by its neighbour's cancellation
    alone = eng.generate(other.prompt[None], 6)
    assert res.requests[1].tokens == alone.tokens[0].tolist()


# ---------------------------------------------------------------------------
# Degraded mode: shrunken budgets never perturb non-degraded slots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["lychee", "quest", "clusterkv"])
def test_degraded_slot_keeps_neighbours_bit_identical(policy):
    cfg = _cfg(policy=policy)
    params = MD.init_model(jax.random.key(0), cfg)
    eng = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(6)
    prem = _req(0, rng, cfg.vocab, n=48, gen=6, priority=0)
    std = _req(1, rng, cfg.vocab, n=48, gen=6, priority=1)
    slo = SLOConfig(enabled=True, ttft_target_s=1e-9, queue_high=1,
                    degrade_budget=True, min_budget_frac=0.25,
                    shed=False, preempt=False)
    loop = eng.serve_loop([prem, std], n_slots=2, slo=slo)
    # a perpetually-arrived backlog keeps the loop in overload so the
    # standard-priority slot decodes with a shrunken budget throughout
    backlog = [_req(10 + i, rng, cfg.vocab, n=16, gen=2, priority=2)
               for i in range(4)]
    for s in backlog:
        s.arrival_s = 0.0
    while not (loop.active[0] and loop.active[1]):
        loop.step()
    for s in backlog:
        loop.submit(s)
    while prem.outcome != "finished" or std.outcome != "finished":
        loop.step()
    assert any(t.degraded for t in std.turns), \
        "overload never degraded the standard-priority slot"
    assert not any(t.degraded for t in prem.turns), \
        "premium slot must never be degraded"
    assert loop.metrics.degraded_steps > 0
    assert loop.metrics.degraded_turns >= 1
    # the premium neighbour is bit-identical to its unloaded solo oracle
    alone = eng.generate(prem.prompt[None], 6)
    assert prem.turns[0].sampled == alone.tokens[0].tolist(), \
        f"{policy}: degraded neighbour perturbed a non-degraded slot"
    # the degraded output is a best-effort, full-length generation
    assert len(std.turns[0].sampled) == 6


def test_degrade_disabled_never_caps():
    cfg = _cfg()
    params = MD.init_model(jax.random.key(0), cfg)
    eng = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(7)
    reqs = [_req(uid, rng, cfg.vocab, n=16, gen=3, priority=2)
            for uid in range(5)]
    slo = SLOConfig(enabled=True, ttft_target_s=1e-9, queue_high=1,
                    degrade_budget=False, shed=False, preempt=False)
    res_loop = eng.serve_loop(reqs, n_slots=2, slo=slo)
    res_loop.run()
    res = res_loop.result()
    assert res.metrics.degraded_steps == 0
    assert not any(t.degraded for r in reqs for t in r.turns)
    for r in reqs:
        alone = eng.generate(r.prompt[None], 3)
        assert res.requests[r.uid].tokens == alone.tokens[0].tolist()


# ---------------------------------------------------------------------------
# Shedding surfaces exactly once, on the result, with premium immunity
# ---------------------------------------------------------------------------

def test_overload_shed_spares_premium():
    cfg = _cfg()
    params = MD.init_model(jax.random.key(0), cfg)
    eng = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(8)
    reqs = [_req(uid, rng, cfg.vocab, n=16, gen=2,
                 priority=0 if uid < 2 else 2) for uid in range(8)]
    slo = SLOConfig(enabled=True, ttft_target_s=1e-4, queue_high=1,
                    shed=True, shed_grace=1.0, degrade_budget=False,
                    preempt=False)
    loop = eng.serve_loop(reqs, n_slots=2, slo=slo)
    loop.run()
    res = loop.result()
    assert set(res.requests) | set(res.shed) == set(range(8))
    assert set(res.requests) & set(res.shed) == set()
    assert {0, 1} <= set(res.requests), "premium sessions were shed"
    assert all(r.reason == "slo" for r in res.shed.values())
    assert all(res.shed[u].priority > 0 for u in res.shed)
    assert res.metrics.shed == len(res.shed) > 0


def test_multi_turn_session_cancel_between_turns():
    cfg = _cfg()
    params = MD.init_model(jax.random.key(0), cfg)
    eng = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(9)
    sp = SamplerParams()
    sess = Session(uid=0, turns=[
        Turn(prompt=rng.integers(0, cfg.vocab, size=(16,)).astype(np.int32),
             max_new=3, sampling=sp),
        Turn(prompt=rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32),
             max_new=32, sampling=sp)])
    loop = eng.serve_loop([sess], n_slots=1)
    while len(sess.turns[0].sampled) < 3:
        loop.step()
    sess.cancel()
    loop.run()
    assert sess.outcome == "cancelled"
    assert len(sess.turns[0].sampled) == 3       # turn 0 completed
    assert len(sess.turns[1].sampled) < 32       # turn 1 cut short
    res = loop.result()
    assert set(res.cancelled) == {0} and not res.requests
