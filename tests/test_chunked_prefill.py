"""Chunked-prefill admission + prompt-length bucketing tests (PR 5).

The invariants that make bounded-stall admission safe:

* chunked admission (first chunk prefilled into the slot, remaining chunks
  through the ``extend_slot`` delta-forward, one interleaved decode step
  between chunks) produces greedy outputs TOKEN-IDENTICAL to monolithic
  admission for every registered cache policy — in the default
  ``chunk_state="rebuild"`` mode at ANY retrieval budget (the
  end-of-admission build IS the monolithic build), and in ``"stream"``
  mode under total-coverage retrieval (the PR-4 oracle regime);
* the interleaved decode steps of the busy slots are bit-identical to the
  un-interleaved schedule (the masked step discards mid-admission slots'
  side effects);
* ring-window (gemma2) and MLA latent extend paths chunk correctly; SSM
  hybrids and MoE archs fall back to monolithic natural-length admission;
* per-chunk streaming state extension follows the monolithic build exactly
  where the math is order-free (quest page min/max);
* masked (right-padded) prefill is exact on the valid rows;
* pow2 prompt-length bucketing compiles O(buckets) admission/generate
  shapes, not O(distinct prompt lengths), and ``_zero_state``'s
  ``eval_shape`` is cached per ``n_slots``;
* ``Turn``/``ServeResult`` expose per-turn TPOT and inter-token-gap
  percentiles (the interference benchmark's stall metric).
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LycheeConfig, get_config
from repro.core.policy import list_policies, make_policy
from repro.models import model as MD
from repro.serving import Engine, Request, Session, Turn

N_CACHE = 192


def _cfg(policy="lychee", chunk=16, chunk_state="rebuild", budget=64,
         arch="granite-3-8b", **kw):
    """Deliberately SPARSE retrieval (budget 64 over ~100-token contexts):
    rebuild-mode identity must hold even when selection really selects."""
    ly = LycheeConfig(policy=policy, enabled=policy != "dense",
                      budget=budget, sink=4, buffer_size=16, max_coarse=8,
                      top_kg=4, full_attn_layers=0, **kw)
    cfg = get_config(arch, reduced=True).replace(dtype="float32", lychee=ly)
    return cfg.replace(serving=cfg.serving.replace(
        prefill_chunk=chunk, chunk_state=chunk_state))


@pytest.fixture(scope="module")
def params():
    return MD.init_model(jax.random.key(0), _cfg())


def _trace(cfg, long_s=70, seed=0):
    """One busy decoder admitted first, then a long multi-chunk admission —
    the interference shape: the busy slot decodes THROUGH the admission."""
    rng = np.random.default_rng(seed)
    return [
        Request(uid=0, prompt=rng.integers(0, cfg.vocab, size=(24,))
                .astype(np.int32), max_new=24),
        Request(uid=1, prompt=rng.integers(0, cfg.vocab, size=(long_s,))
                .astype(np.int32), max_new=8),
    ]


def _tokens(res):
    return {uid: [t.tokens for t in s.turns] for uid, s in
            res.requests.items()}


# ---------------------------------------------------------------------------
# Tentpole identity: chunked admission == monolithic admission, per policy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", sorted(list_policies()))
def test_chunked_admission_identical_to_monolithic(params, policy):
    """Default (rebuild) mode at a genuinely sparse budget: the 70-token
    prompt admits as 5 chunks of 16 with decode interleaved, and every
    token of BOTH sessions must match monolithic admission and solo
    ``generate``."""
    chunked = Engine(_cfg(policy, chunk=16), params, n_cache=N_CACHE,
                     donate_state=False)
    mono = Engine(_cfg(policy, chunk=0), params, n_cache=N_CACHE,
                  donate_state=False)
    assert chunked.chunked and not mono.chunked
    rc = chunked.serve(_trace(chunked.cfg), n_slots=2)
    rm = mono.serve(_trace(mono.cfg), n_slots=2)
    assert _tokens(rc) == _tokens(rm), \
        f"[{policy}] chunked admission diverged from monolithic"
    # ... and the long request equals generate() of its prompt alone
    long_req = _trace(chunked.cfg)[1]
    alone = chunked.generate(long_req.prompt[None], long_req.max_new)
    assert rc.requests[1].tokens == alone.tokens[0].tolist(), \
        f"[{policy}] chunked admission diverged from solo generate"


def test_chunked_multiturn_extend_identical(params):
    """A multi-chunk turn-2 delta (40 tokens, chunk 16) streams through
    CachePolicy.extend piecewise — same per-token trajectory as the
    monolithic extend, so outputs match exactly."""
    rng = np.random.default_rng(3)
    cfgc = _cfg(chunk=16)

    def sess():
        r = np.random.default_rng(3)
        return Session(uid=0, turns=[
            Turn(prompt=r.integers(0, cfgc.vocab, size=(48,))
                 .astype(np.int32), max_new=5),
            Turn(prompt=r.integers(0, cfgc.vocab, size=(40,))
                 .astype(np.int32), max_new=6)])

    chunked = Engine(cfgc, params, n_cache=N_CACHE, donate_state=False)
    mono = Engine(_cfg(chunk=0), params, n_cache=N_CACHE,
                  donate_state=False)
    rc = chunked.serve([sess()], n_slots=1)
    rm = mono.serve([sess()], n_slots=1)
    assert _tokens(rc) == _tokens(rm)
    del rng


@pytest.mark.parametrize("arch,model_kw", [
    ("gemma2-27b", {}),                            # ring-window extend
    ("deepseek-v3-671b", {"pattern": ("mla",)}),   # MLA latent extend
])
def test_chunked_admission_other_block_kinds(arch, model_kw):
    ly = LycheeConfig(budget=64, sink=4, buffer_size=16, max_coarse=8,
                      top_kg=4, full_attn_layers=0)
    base = get_config(arch, reduced=True).replace(
        dtype="float32", lychee=ly, **model_kw)
    params = MD.init_model(jax.random.key(2), base)
    cfgs = {c: base.replace(serving=base.serving.replace(prefill_chunk=c))
            for c in (16, 0)}
    toks = {}
    for c, cfg in cfgs.items():
        eng = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
        assert eng.can_extend
        toks[c] = _tokens(eng.serve(_trace(cfg), n_slots=2))
    assert toks[16] == toks[0], f"[{arch}] chunked != monolithic"


@pytest.mark.parametrize("policy", sorted(list_policies()))
def test_stream_mode_matches_oracle_under_total_coverage(params, policy):
    """chunk_state="stream": every chunk extends the policy state through
    its streaming path (lychee lazy-grafts, quest tail pages, clusterkv
    centroid assignment). Under total-coverage retrieval the selection
    cannot differ from the monolithic build, so outputs must match — the
    PR-4 monolithic-build-oracle regime applied per chunk."""
    kw = dict(budget=512, chunk_cap=32, ckv_cap_factor=8)
    stream = Engine(_cfg(policy, chunk=16, chunk_state="stream", **kw),
                    params, n_cache=N_CACHE, donate_state=False)
    mono = Engine(_cfg(policy, chunk=0, **kw), params, n_cache=N_CACHE,
                  donate_state=False)
    rc = stream.serve(_trace(stream.cfg), n_slots=2)
    rm = mono.serve(_trace(mono.cfg), n_slots=2)
    assert _tokens(rc) == _tokens(rm), \
        f"[{policy}] streamed chunk state diverged from monolithic build"


def test_quest_chunkwise_stream_equals_monolithic_build_bitwise():
    """Page min/max extension is order-free, so feeding the keys chunk by
    chunk through ``CachePolicy.extend`` must reproduce the monolithic
    ``build`` state BITWISE — the strongest per-chunk streaming oracle."""
    ly = LycheeConfig(policy="quest", quest_page=8)
    pol = make_policy("quest", ly)
    rng = np.random.default_rng(0)
    H, S, d = 2, 70, 16
    keys = jnp.asarray(rng.standard_normal((H, N_CACHE, d)), jnp.float32)
    ref = pol.build(keys[:, :S], None, N_CACHE, n_tokens=S)
    C = 16
    st = pol.build(keys[:, :C], None, N_CACHE, n_tokens=C)
    pos = C
    while pos < S:
        n = min(C, S - pos)
        st = pol.extend(st, keys, jnp.int32(pos), jnp.int32(n))
        pos += n
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(st)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Fallbacks + degenerate chunk sizes
# ---------------------------------------------------------------------------
def test_ssm_and_moe_fall_back_to_monolithic():
    for arch in ("zamba2-2.7b", "mixtral-8x22b"):
        cfg = get_config(arch, reduced=True).replace(dtype="float32")
        cfg = cfg.replace(serving=cfg.serving.replace(prefill_chunk=16))
        params = MD.init_model(jax.random.key(1), cfg)
        eng = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
        assert not eng.chunked and not eng.can_pad
        res = eng.serve(_trace(cfg, long_s=40), n_slots=2)
        for req in _trace(cfg, long_s=40):
            got = res.requests[req.uid]
            alone = eng.generate(req.prompt[None], req.max_new)
            assert got.tokens == alone.tokens[0].tolist(), \
                f"[{arch}] monolithic fallback diverged from solo"


def test_chunk_size_equals_prompt_len_degenerate(params):
    """chunk == prompt length: a single full chunk (no tail, no rebuild) —
    must equal monolithic admission trivially."""
    chunked = Engine(_cfg(chunk=70), params, n_cache=N_CACHE,
                     donate_state=False)
    mono = Engine(_cfg(chunk=0), params, n_cache=N_CACHE,
                  donate_state=False)
    rc = chunked.serve(_trace(chunked.cfg, long_s=70), n_slots=2)
    rm = mono.serve(_trace(mono.cfg, long_s=70), n_slots=2)
    assert _tokens(rc) == _tokens(rm)


# ---------------------------------------------------------------------------
# Masked (right-padded) prefill exactness — model level
# ---------------------------------------------------------------------------
def test_masked_prefill_matches_natural_prefill(params):
    cfg = _cfg()
    rng = np.random.default_rng(7)
    S, Sp = 52, 64
    prompt = rng.integers(0, cfg.vocab, size=(1, S)).astype(np.int32)
    padded = np.zeros((1, Sp), np.int32)
    padded[:, :S] = prompt
    ref_logits, ref_state = MD.prefill(params, jnp.asarray(prompt), cfg,
                                       N_CACHE)
    got_logits, got_state = MD.prefill(params, jnp.asarray(padded), cfg,
                                       N_CACHE, n_tokens=jnp.int32(S))
    np.testing.assert_allclose(np.asarray(got_logits),
                               np.asarray(ref_logits), atol=1e-5, rtol=1e-5)
    assert np.asarray(got_state["t"]).tolist() == [S]
    # valid cache rows identical; the policy state built on masked keys
    # matches the natural build
    k_ref = np.asarray(ref_state["groups"][0]["k"])[:, :, :, :S]
    k_got = np.asarray(got_state["groups"][0]["k"])[:, :, :, :S]
    np.testing.assert_allclose(k_got, k_ref, atol=1e-6)


# ---------------------------------------------------------------------------
# Compile-count regression: O(buckets), not O(distinct lengths)
# ---------------------------------------------------------------------------
def test_admission_compiles_per_bucket_not_per_length(params):
    cfg = _cfg(chunk=512)          # prompts below the chunk: bucketed 1-piece
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(9)
    lens = [20, 28, 40, 52, 60, 100]       # buckets: 32, 32, 64, 64, 64, 128
    trace = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab, size=(s,)).astype(np.int32), max_new=2)
        for i, s in enumerate(lens)]
    engine.serve(copy.deepcopy(trace), n_slots=2)
    n_buckets = len({engine._pad_shape(s, engine.usable) for s in lens})
    assert n_buckets == 3
    assert engine._prefill_slot_b._cache_size() == n_buckets, \
        "admission must compile once per pow2 bucket"
    # replaying the trace adds no compilations
    engine.serve(copy.deepcopy(trace), n_slots=2)
    assert engine._prefill_slot_b._cache_size() == n_buckets


def test_generate_compiles_per_bucket(params):
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(11)
    for s in (40, 52, 60):                 # one shared 64-bucket
        engine.generate(rng.integers(0, cfg.vocab, size=(1, s))
                        .astype(np.int32), 2)
    assert engine._prefill._cache_size() == 1, \
        "generate must reuse one trace per pad bucket"


def test_chunked_admission_compiles_chunk_plus_tail_bucket(params):
    """A long admission compiles exactly two extend shapes: the full-chunk
    shape and the tail's pow2 bucket."""
    cfg = _cfg(chunk=16)
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(13)
    for i, s in enumerate((70, 86)):       # tails 6 (->16) and 6 (->16)
        engine.serve([Request(uid=i, prompt=rng.integers(
            0, cfg.vocab, size=(s,)).astype(np.int32), max_new=2)],
            n_slots=1)
    # chunk-shape extends (16) + one tail bucket (16, padded) = 1 shape
    assert engine._extend_slot_nu._cache_size() <= 2


def test_zero_state_eval_shape_cached_per_n_slots(params, monkeypatch):
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    calls = {"n": 0}
    orig = jax.eval_shape

    def spy(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(jax, "eval_shape", spy)
    engine._zero_state(2)
    engine._zero_state(2)
    assert calls["n"] == 1, "_zero_state must cache eval_shape per n_slots"
    engine._zero_state(3)
    assert calls["n"] == 2


# ---------------------------------------------------------------------------
# Streaming-smoothness metrics
# ---------------------------------------------------------------------------
def test_turn_tpot_and_itl_metrics(params):
    cfg = _cfg()
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    res = engine.serve(_trace(cfg), n_slots=2)
    for sess in res.requests.values():
        for turn in sess.turns:
            assert len(turn.token_times_s) == len(turn.sampled)
            if len(turn.sampled) >= 2:
                assert turn.tpot_ms is not None and turn.tpot_ms > 0
                assert turn.max_itl_ms >= turn.p99_itl_ms > 0
                assert all(g >= 0 for g in turn.itl_ms)
            else:
                assert turn.tpot_ms is None
    assert res.mean_tpot_ms > 0
    assert res.max_itl_ms >= res.p99_itl_ms > 0
