"""Journey-fuzz tests: randomized engine walks with per-step invariants.

``serving.journeys`` drives a REAL engine through seeded random action
sequences (submit / burst / cancel / sleep / step) and asserts machine-
checkable invariants after every step: slot-table consistency, monotone
per-slot position, token budgets, the paged refcount ledger, terminal
partition (finished/shed/cancelled disjoint; every shed surfaced exactly
once), the arrived-queue bound, drain cleanliness (zero leaked pages)
and the oracle — every finished never-degraded session replays solo
bit-identically.

The seed sweep here runs >= 200 actions per seed across
{paged, contiguous} x {lychee, quest, streaming}; CI repeats it via the
module CLI and uploads the failing seed + action log as an artifact.

The ``TestRegressionJourneys`` scripts are deterministic journeys
distilled from fuzzing runs during development (each reproduces a
once-plausible failure mode: cancel racing a chunked admission, a
premium burst landing on a full paged pool, back-to-back cancel+resubmit
on a recycled slot). They pin the fixes forever at a fraction of the
sweep's cost.
"""
import jax
import pytest

from repro.models import model as MD
from repro.serving.journeys import (FakeClock, JourneyRunner, JourneySpec,
                                    journey_config)


def _engine(spec):
    from repro.serving import Engine
    cfg = journey_config(spec)
    params = MD.init_model(jax.random.key(0), cfg)
    return Engine(cfg, params, n_cache=spec.n_cache, donate_state=False)


_ENGINES = {}


def _shared_engine(spec):
    key = (spec.policy, spec.paged, spec.prefill_chunk)
    if key not in _ENGINES:
        _ENGINES[key] = _engine(spec)
    return _ENGINES[key]


# ---------------------------------------------------------------------------
# Seed sweep: the fuzz gate (>= 200 actions per seed, every policy x layout)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["lychee", "quest", "streaming"])
@pytest.mark.parametrize("paged", [False, True],
                         ids=["contiguous", "paged"])
def test_journey_seed_sweep(policy, paged):
    spec = JourneySpec(policy=policy, paged=paged)
    eng = _shared_engine(spec)
    runner = JourneyRunner(eng, seed=0, n_slots=spec.n_slots)
    runner.run(200)
    # the walk actually exercised the machinery it fuzzes
    assert runner.steps >= 100
    sched = runner.loop.sched
    assert len(sched.finished) >= 1
    assert (len(sched.finished) + len(sched.shed)
            + len(sched.cancelled)) == len(runner.sessions)


def test_journey_second_seed_contiguous():
    spec = JourneySpec(policy="lychee", paged=False)
    runner = JourneyRunner(_shared_engine(spec), seed=1,
                           n_slots=spec.n_slots)
    runner.run(200)
    assert runner.steps >= 100


def test_journey_monolithic_admission_paged():
    """No chunking: admissions are atomic, preemption can't trigger —
    the invariants must hold in that regime too."""
    spec = JourneySpec(policy="lychee", paged=True, prefill_chunk=0)
    runner = JourneyRunner(_shared_engine(spec), seed=2,
                           n_slots=spec.n_slots)
    runner.run(120)
    assert len(runner.loop.sched.finished) >= 1


def test_journey_determinism_same_seed_same_outcome():
    """The whole point of seeded journeys: identical seed -> identical
    action log, terminal partition and per-session tokens."""
    spec = JourneySpec(policy="lychee", paged=False)
    eng = _shared_engine(spec)
    outs = []
    for _ in range(2):
        r = JourneyRunner(eng, seed=7, n_slots=spec.n_slots)
        r.run(80)
        outs.append((
            r.log,
            {u: s.outcome for u, s in r.sessions.items()},
            {u: [t.sampled for t in s.turns]
             for u, s in r.sessions.items()},
        ))
    assert outs[0][0] == outs[1][0], "action logs diverged"
    assert outs[0][1] == outs[1][1], "outcomes diverged"
    assert outs[0][2] == outs[1][2], "sampled tokens diverged"


# ---------------------------------------------------------------------------
# Deterministic regression journeys (fuzzer-derived scripts)
# ---------------------------------------------------------------------------

def _submit_args(priority=1, lens=(24,), gens=(4,), temps=(0.0,),
                 target=0.0):
    return dict(priority=priority, n_turns=len(lens), lens=list(lens),
                gens=list(gens), temps=list(temps), target=target)


class TestRegressionJourneys:
    SPEC = JourneySpec(policy="lychee", paged=True)

    def _runner(self, seed=0):
        return JourneyRunner(_shared_engine(self.SPEC), seed=seed,
                             n_slots=self.SPEC.n_slots)

    def test_cancel_races_chunked_admission(self):
        """Cancel landing while the session's chunked prefill is still in
        flight: the job must be dropped at the chunk boundary with every
        page returned (the mid-prefill teardown-order regression)."""
        r = self._runner()
        r.replay([
            ("submit", _submit_args(lens=(48,), gens=(8,))),
            ("submit", _submit_args(lens=(48,), gens=(8,))),
            ("step", {}),                      # both admissions in flight
            ("cancel", {"uid": 0}),
            ("step", {}), ("step", {}),
            ("submit", _submit_args(lens=(24,), gens=(2,))),
        ])
        assert r.sessions[0].outcome == "cancelled"
        assert r.sessions[2].outcome == "finished"

    def test_premium_burst_on_full_pool(self):
        """A premium burst arriving with every page claimed: deferral +
        SLO ordering must admit the premiums without corrupting the
        refcount ledger or shedding priority 0."""
        r = self._runner(seed=1)
        r.replay([
            ("submit", _submit_args(priority=2, lens=(48,), gens=(6,))),
            ("submit", _submit_args(priority=2, lens=(48,), gens=(6,))),
            ("step", {}), ("step", {}), ("step", {}),
            ("submit", _submit_args(priority=0, lens=(24,), gens=(3,),
                                    target=0.2)),
            ("submit", _submit_args(priority=0, lens=(24,), gens=(3,),
                                    target=0.2)),
            ("sleep", {"dt": 0.3}),
            ("step", {}), ("step", {}),
        ])
        for uid in (2, 3):
            assert r.sessions[uid].outcome == "finished", \
                "premium session did not complete"

    def test_cancel_then_resubmit_on_recycled_slot(self):
        """Back-to-back cancel + resubmit landing on the just-freed slot:
        slot state (position, sampling vectors, pages) must be fully
        recycled — the stale-slot_t regression."""
        r = self._runner(seed=2)
        r.replay([
            ("submit", _submit_args(lens=(24,), gens=(16,),
                                    temps=(0.8,))),
            ("step", {}), ("step", {}), ("step", {}), ("step", {}),
            ("cancel", {"uid": 0}),
            ("step", {}),
            ("submit", _submit_args(lens=(8,), gens=(4,), temps=(0.8,))),
            ("step", {}),
        ])
        assert r.sessions[0].outcome == "cancelled"
        assert r.sessions[1].outcome == "finished"
        assert len(r.sessions[1].turns[0].sampled) == 4

    def test_cancel_queued_under_overload(self):
        """Cancelling a session that is still queued while the loop is
        shedding around it: the cancel must win (surfaced as cancelled,
        not shed) and the terminal partition stays disjoint."""
        r = self._runner(seed=3)
        r.replay([
            ("submit", _submit_args(lens=(24,), gens=(6,))),
            ("submit", _submit_args(lens=(24,), gens=(6,))),
            ("submit", _submit_args(priority=2, lens=(24,), gens=(6,),
                                    target=0.2)),
            ("cancel", {"uid": 2}),
            ("sleep", {"dt": 1.0}),
            ("step", {}), ("step", {}),
        ])
        assert r.sessions[2].outcome == "cancelled"
        assert 2 in r.loop.sched.cancelled
        assert 2 not in r.loop.sched.shed


# ---------------------------------------------------------------------------
# FakeClock sanity (the determinism the whole module rests on)
# ---------------------------------------------------------------------------

def test_fake_clock_is_virtual():
    clk = FakeClock()
    assert clk.now_s() == 0.0
    clk.sleep(2.5)
    clk.sleep(-1.0)          # negative sleeps never rewind time
    assert clk.now_s() == 2.5
