"""Paged KV pool tests: geometry, translation, allocator properties,
prefix trie, and engine-level paged-vs-contiguous bit-identity.

The allocator property tests use hypothesis when it is installed and fall
back to a fixed sweep of seeds otherwise, so the invariants (no leak, no
double hand-out, refcount == readers, free+used == n_pages) are always
exercised in tier-1.

The engine tests are the acceptance gate of the paged subsystem: greedy
serve() output must be BIT-IDENTICAL between the paged pool and the
contiguous per-slot layout for every sparse policy (GQA and MLA), under
monolithic and chunked admission and across multi-turn extends — plus the
prefix-cache guarantees (full hit = zero forwards, identical tokens) and
page-pressure deferral.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LycheeConfig, get_config
from repro.core.paging import (PageSpec, append_rows, resolve_page_spec,
                               slot_gather_rows, slot_write_rows,
                               translate_starts)
from repro.models import model as MD
from repro.serving.engine import Engine
from repro.serving.pagepool import PagePool
from repro.serving.scheduler import Session, Turn

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

N_CACHE = 160


def _ly(policy="lychee"):
    return LycheeConfig(budget=64, sink=4, buffer_size=16, max_coarse=8,
                        top_kg=4, full_attn_layers=0, policy=policy)


# ---------------------------------------------------------------------------
# Geometry: resolve_page_spec
# ---------------------------------------------------------------------------
def test_resolve_page_spec_auto():
    cfg = _ly()
    spec = resolve_page_spec(384, cfg, n_slots=2)
    assert spec.page_tokens % max(cfg.max_chunk, cfg.quest_page, 1) == 0
    assert 384 % spec.page_tokens == 0
    assert spec.page_tokens >= spec.slack
    assert spec.max_pages == 384 // spec.page_tokens
    assert spec.n_pages == 2 * spec.max_pages          # break-even sizing
    assert spec.page_rows == spec.page_tokens + spec.slack
    assert spec.dump_page == spec.n_pages              # outside the pool
    assert spec.pool_rows == (spec.n_pages + 1) * spec.page_rows
    assert spec.logical_rows == 384


def test_resolve_page_spec_validation():
    cfg = _ly()
    with pytest.raises(ValueError):                    # does not divide
        resolve_page_spec(160, cfg, page_tokens=48)
    with pytest.raises(ValueError):                    # < slack
        resolve_page_spec(160, cfg, page_tokens=8)
    with pytest.raises(ValueError):                    # pool < one slot
        resolve_page_spec(160, cfg, page_tokens=32, pool_pages=3)


# ---------------------------------------------------------------------------
# Translation: table <-> physical rows
# ---------------------------------------------------------------------------
def _spec(P=32, slack=16, n_pages=8, max_pages=5):
    return PageSpec(page_tokens=P, slack=slack, n_pages=n_pages,
                    max_pages=max_pages)


def test_translate_is_base_swap():
    sp = _spec()
    tbl = jnp.asarray([[3, 0, 6, 2, 7]], jnp.int32)
    starts = jnp.asarray([[[0, 31, 32, 100, 159]]], jnp.int32)  # (1,1,5)
    phys = np.asarray(translate_starts(tbl, starts, sp))[0, 0]
    ref = [3 * 48 + 0, 3 * 48 + 31, 0 * 48 + 0, 2 * 48 + 4, 7 * 48 + 31]
    assert phys.tolist() == ref
    # over-range starts clip into the last logical page
    over = jnp.asarray([[[999]]], jnp.int32)
    assert np.asarray(translate_starts(tbl, over, sp)).item() == 7 * 48 + 31


def test_write_gather_roundtrip():
    """Scattering a contiguous image through a table row and gathering it
    back is the identity, and halo rows duplicate the next page's head."""
    sp = _spec()
    rng = np.random.default_rng(0)
    tbl_row = jnp.asarray(rng.permutation(sp.n_pages)[:sp.max_pages],
                          jnp.int32)
    img = rng.standard_normal((sp.logical_rows, 4)).astype(np.float32)
    pool = np.zeros((sp.pool_rows, 4), np.float32)
    direct, halo = (np.asarray(a) for a in slot_write_rows(tbl_row, sp))
    pool[direct] = img
    pool[halo] = img                        # halo dup (dump rows harmless)
    grows = np.asarray(slot_gather_rows(tbl_row, sp))
    assert np.array_equal(pool[grows], img)
    # halo contract: rows [P, P+slack) of phys page p == next logical
    # page's first slack rows
    row = np.asarray(tbl_row)
    for lp in range(1, sp.max_pages):
        halo_rows = row[lp - 1] * sp.page_rows + sp.page_tokens \
            + np.arange(sp.slack)
        head_rows = row[lp] * sp.page_rows + np.arange(sp.slack)
        assert np.array_equal(pool[halo_rows], pool[head_rows])


def test_append_rows_reference():
    """append_rows against a scalar reference over every t, including the
    page-0 no-left-neighbour dump routing."""
    sp = _spec()
    rng = np.random.default_rng(1)
    tbl = jnp.asarray(rng.permutation(sp.n_pages)[:sp.max_pages],
                      jnp.int32)[None]
    for t in range(sp.max_pages * sp.page_tokens):
        d, h = append_rows(tbl, jnp.asarray([t], jnp.int32), sp)
        page, off = t // sp.page_tokens, t % sp.page_tokens
        assert int(d[0]) == int(tbl[0, page]) * sp.page_rows + off
        if off < sp.slack and page >= 1:
            ref = int(tbl[0, page - 1]) * sp.page_rows + sp.page_tokens + off
        else:
            ref = sp.dump_row
        assert int(h[0]) == ref


# ---------------------------------------------------------------------------
# Allocator properties (hypothesis when available, seeded sweep otherwise)
# ---------------------------------------------------------------------------
def _check_allocator_journey(seed):
    """Random alloc/incref/decref/evict journey; after every op the pool's
    books must balance: free + in-use == n_pages, refcount == our reader
    ledger, freed pages really return, alloc is all-or-nothing."""
    rng = np.random.default_rng(seed)
    sp = _spec(n_pages=int(rng.integers(4, 17)))
    pool = PagePool(sp, bytes_per_page=1024, prefix_cache=False)
    ledger = np.zeros(sp.n_pages, np.int64)    # our independent refcounts
    held = []                                  # groups we hold a ref on

    def check():
        assert pool.pages_free + pool.pages_in_use == sp.n_pages
        assert np.array_equal(pool._ref, ledger)
        assert pool.pages_in_use == int((ledger > 0).sum())
        assert sorted(pool._free) == [p for p in range(sp.n_pages)
                                      if ledger[p] == 0]
        assert pool.bytes_saved() == \
            int(np.maximum(ledger - 1, 0).sum()) * 1024
        assert pool.shared_pages == int((ledger > 1).sum())

    for _ in range(120):
        op = rng.integers(0, 3)
        if op == 0:                                        # alloc
            n = int(rng.integers(1, sp.n_pages + 2))
            before = pool.pages_free
            got = pool.alloc(n)
            if n > before:
                assert got is None                         # all-or-nothing
                assert pool.pages_free == before           # state unchanged
            else:
                assert got is not None and len(got) == n
                assert len(set(got)) == n                  # no dup hand-out
                assert all(ledger[p] == 0 for p in got)    # were free
                for p in got:
                    ledger[p] = 1
                held.append(list(got))
        elif op == 1 and held:                             # incref a group
            g = held[int(rng.integers(len(held)))]
            pool.incref(g)
            for p in g:
                ledger[p] += 1
            held.append(list(g))
        elif op == 2 and held:                             # decref a group
            g = held.pop(int(rng.integers(len(held))))
            pool.decref(g)
            for p in g:
                ledger[p] -= 1
        check()
    for g in held:                                         # drain: no leak
        pool.decref(g)
        for p in g:
            ledger[p] -= 1
    check()
    assert pool.pages_free == sp.n_pages
    assert pool.peak_in_use <= sp.n_pages


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_allocator_journey(seed):
        _check_allocator_journey(seed)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_allocator_journey(seed):
        _check_allocator_journey(seed)


def test_double_free_and_bad_incref_assert():
    pool = PagePool(_spec(), prefix_cache=False)
    pages = pool.alloc(2)
    pool.decref(pages)
    with pytest.raises(AssertionError):
        pool.decref(pages)                    # double free
    with pytest.raises(AssertionError):
        pool.incref([pages[0]])               # incref of a free page


# ---------------------------------------------------------------------------
# Radix prefix cache (host trie; sub/logits stand-ins)
# ---------------------------------------------------------------------------
def _register(pool, tokens, uid=0):
    P = pool.spec.page_tokens
    pages = pool.alloc(-(-len(tokens) // P))
    assert pages is not None
    return pool.register(np.asarray(tokens, np.int32), pages,
                         n_safe=0, sub={"t": len(tokens)}, logits="L",
                         uid=uid)


def test_prefix_full_and_partial_lookup():
    pool = PagePool(_spec(P=8, slack=4, n_pages=16, max_pages=8),
                    bytes_per_page=64)
    rng = np.random.default_rng(2)
    prompt = rng.integers(5, 900, 21).astype(np.int32)   # 2 full pages + 5
    _register(pool, prompt)

    kind, entry, keep = pool.lookup(prompt)              # exact
    assert kind == "full" and keep == 21 and entry.logits == "L"

    longer = np.concatenate([prompt, rng.integers(5, 900, 10)]) \
        .astype(np.int32)                                # shares 2 pages
    kind, entry, keep = pool.lookup(longer)
    assert kind == "partial"
    assert keep == 16 and keep % 8 == 0 and keep < len(longer)

    # exact-length prompt whose LAST page differs: trie depth matches on
    # the 2 full pages only -> partial, never a false full hit
    mutated = prompt.copy()
    mutated[-1] += 1
    kind, _, keep = pool.lookup(mutated)
    assert kind == "partial" and keep == 16

    # first-page mismatch -> miss
    other = prompt.copy()
    other[0] += 1
    assert pool.lookup(other)[0] is None

    # sub-page prompts can never share (no full page to share)
    assert pool.lookup(prompt[:5])[0] is None

    st_ = pool.stats()
    assert st_.prefix_lookups == 5
    assert st_.prefix_hits == 1 and st_.prefix_partial_hits == 2
    assert 0 < st_.prefix_hit_rate < 1
    assert st_.to_dict()["prefix_entries"] == 1


def test_prefix_partial_keep_leaves_a_suffix():
    """A prompt that is an exact multiple of P and fully covered by a
    longer entry must keep one page back so the suffix extend still
    produces the first-sample logits."""
    pool = PagePool(_spec(P=8, slack=4, n_pages=16, max_pages=8))
    rng = np.random.default_rng(3)
    donor = rng.integers(5, 900, 32).astype(np.int32)    # 4 pages
    _register(pool, donor)
    kind, _, keep = pool.lookup(donor[:16])              # covered prefix
    assert kind == "partial"                              # not its terminal
    assert keep == 8                                      # ((16-1)//8)*8


def test_prefix_eviction_lru_protect_and_clear():
    pool = PagePool(_spec(P=8, slack=4, n_pages=16, max_pages=8),
                    bytes_per_page=64)
    rng = np.random.default_rng(4)
    a = _register(pool, rng.integers(5, 900, 16).astype(np.int32), uid=0)
    b = _register(pool, rng.integers(5, 900, 16).astype(np.int32), uid=1)
    assert pool.pages_in_use == 4
    pool.lookup(a.tokens)                                # touch a: b is LRU
    assert pool.evict_lru() is True
    assert pool.lookup(b.tokens)[0] is None              # b gone
    assert pool.lookup(a.tokens)[0] == "full"            # a intact
    assert pool.pages_in_use == 2                        # b's pages freed
    assert pool.evict_lru(protect=a) is False            # nothing evictable
    pool.clear_prefix_cache()
    assert pool.pages_in_use == 0 and pool.stats().prefix_entries == 0
    assert pool.stats().prefix_evictions == 1            # clear != evict


def test_prefix_cache_disabled():
    pool = PagePool(_spec(), prefix_cache=False)
    assert pool.register(np.arange(32, dtype=np.int32), [], 0, None,
                         None) is None
    assert pool.lookup(np.arange(32, dtype=np.int32)) == (None, None, 0)
    assert pool.stats().prefix_lookups == 0


# ---------------------------------------------------------------------------
# Engine: paged serve is bit-identical to contiguous serve
# ---------------------------------------------------------------------------
def _sessions(rng, n, prompt_len=70, max_new=6, turns=1):
    out = []
    for i in range(n):
        ts = [Turn(prompt=rng.integers(5, 900, prompt_len).astype(np.int32),
                   max_new=max_new) for _ in range(turns)]
        out.append(Session(uid=i, turns=ts, arrival_s=0.0))
    return out


def _toks(res):
    return {u: [t.tokens for t in s.turns] for u, s in res.requests.items()}


def _gqa_cfg(policy, chunk=0):
    cfg = get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=_ly(policy))
    return cfg.replace(serving=cfg.serving.replace(prefill_chunk=chunk))


@pytest.fixture(scope="module")
def gqa_params():
    return MD.init_model(jax.random.key(0), _gqa_cfg("lychee"))


def _assert_paged_matches_contiguous(cfg, params, sessions, n_slots=2):
    eng_c = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    r_c = eng_c.serve(copy.deepcopy(sessions), n_slots=n_slots,
                      mode="continuous")
    assert r_c.pool is None
    cfg_p = cfg.replace(serving=cfg.serving.replace(paged=True))
    eng_p = Engine(cfg_p, params, n_cache=N_CACHE, donate_state=False)
    assert eng_p.paged
    r_p = eng_p.serve(copy.deepcopy(sessions), n_slots=n_slots,
                      mode="continuous")
    assert r_p.pool is not None
    assert _toks(r_c) == _toks(r_p)
    assert r_p.pool.pages_in_use == 0                    # all freed
    assert r_p.pool.peak_pages_in_use > 0
    return r_p


@pytest.mark.parametrize("policy",
                         ["lychee", "quest", "clusterkv", "streaming"])
def test_paged_bitwise_gqa(policy, gqa_params):
    rng = np.random.default_rng(3)
    _assert_paged_matches_contiguous(_gqa_cfg(policy), gqa_params,
                                     _sessions(rng, 4))


def test_paged_bitwise_chunked_admission(gqa_params):
    rng = np.random.default_rng(3)
    _assert_paged_matches_contiguous(_gqa_cfg("lychee", chunk=32),
                                     gqa_params, _sessions(rng, 4))


@pytest.mark.parametrize("policy", ["lychee", "quest"])
def test_paged_bitwise_multiturn_extend(policy, gqa_params):
    rng = np.random.default_rng(3)
    sess = _sessions(rng, 4, prompt_len=48, max_new=4, turns=2)
    _assert_paged_matches_contiguous(_gqa_cfg(policy), gqa_params, sess)


def test_paged_bitwise_mla():
    cfg = get_config("deepseek-v3-671b", reduced=True).replace(
        dtype="float32", lychee=_ly(), pattern=("mla",))
    params = MD.init_model(jax.random.key(0), cfg)
    rng = np.random.default_rng(3)
    _assert_paged_matches_contiguous(cfg, params, _sessions(rng, 4))


def test_dense_policy_falls_back_contiguous(gqa_params):
    cfg = _gqa_cfg("dense")
    cfg_p = cfg.replace(serving=cfg.serving.replace(paged=True))
    assert not MD.can_page(cfg_p)
    eng = Engine(cfg_p, gqa_params, n_cache=N_CACHE, donate_state=False)
    assert not eng.paged
    rng = np.random.default_rng(3)
    sess = _sessions(rng, 2)
    r = eng.serve(copy.deepcopy(sess), n_slots=2, mode="continuous")
    assert r.pool is None
    assert all(len(s.turns[0].tokens) == 6 for s in r.requests.values())


def test_prefix_cache_full_hit_zero_forwards(gqa_params):
    """Session 1 repeats session 0's prompt exactly -> full hit, spliced
    with ZERO forward passes, tokens bit-identical to contiguous. Session
    2 overlaps the first 40 tokens -> partial hit, still sound."""
    cfg = _gqa_cfg("lychee")
    rng = np.random.default_rng(5)
    shared = rng.integers(5, 900, 70).astype(np.int32)
    sess = _sessions(rng, 3)
    sess[0].turns[0].prompt = shared.copy()
    sess[1].turns[0].prompt = shared.copy()
    sess[2].turns[0].prompt = np.concatenate(
        [shared[:40], rng.integers(5, 900, 25).astype(np.int32)])

    cfg_p = cfg.replace(serving=cfg.serving.replace(
        paged=True, page_tokens=32, pool_pages=12))
    eng = Engine(cfg_p, gqa_params, n_cache=N_CACHE, donate_state=False)
    # n_slots=1 serializes admissions, so uid0 registers before uid1 looks
    r = eng.serve(copy.deepcopy(sess), n_slots=1, mode="continuous")
    st_ = r.pool
    assert st_.prefix_lookups == 3
    assert st_.prefix_hits >= 1                  # uid1 exact
    assert st_.prefix_partial_hits >= 1          # uid2 40-token overlap
    assert st_.peak_bytes_saved > 0              # sharing actually happened

    eng_c = Engine(cfg, gqa_params, n_cache=N_CACHE, donate_state=False)
    r_c = eng_c.serve(copy.deepcopy(sess), n_slots=1, mode="continuous")
    assert _toks(r)[0] == _toks(r_c)[0]
    assert _toks(r)[1] == _toks(r_c)[1]          # full hit: bit-identical
    assert len(_toks(r)[2][0]) == len(_toks(r_c)[2][0])


def test_hit_protection_degrades_to_miss(gqa_params):
    """A prefix hit whose sharing plan cannot be funded — the entry
    itself holds the pool's pages and is the only eviction candidate —
    must degrade to a miss (evicting the entry) instead of deferring
    forever. Session 1 shares only page 0 of session 0's registered
    120-token prompt, so n_share == 0 while the entry pins 4 of the 6
    pool pages; without the miss fallback serve() livelocks here."""
    cfg = _gqa_cfg("lychee")
    cfg_p = cfg.replace(serving=cfg.serving.replace(
        paged=True, page_tokens=32, pool_pages=6))
    eng = Engine(cfg_p, gqa_params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(9)
    a = rng.integers(5, 900, 120).astype(np.int32)
    b = np.concatenate([a[:32], rng.integers(5, 900, 88)]).astype(np.int32)
    sess = [Session(uid=0, turns=[Turn(prompt=a, max_new=24)],
                    arrival_s=0.0),
            Session(uid=1, turns=[Turn(prompt=b, max_new=24)],
                    arrival_s=0.0)]
    r = eng.serve(copy.deepcopy(sess), n_slots=1, mode="continuous")
    assert all(len(s.turns[0].tokens) == 24 for s in r.requests.values())
    assert r.pool.prefix_evictions >= 1           # the entry was dropped
    assert r.pool.deferred_admissions == 0        # degraded, not deferred
    # only session 1's own registration still pins pages at serve end
    assert r.pool.prefix_entries == 1 and r.pool.pages_in_use == 4


def test_pool_pressure_defers_admission(gqa_params):
    """pool_pages = one slot's worth: two 3-page sessions cannot coexist,
    so the second admission defers until the first finishes — and every
    session still completes. Concurrency is bounded by pages, not slots."""
    cfg = _gqa_cfg("lychee")
    cfg_p = cfg.replace(serving=cfg.serving.replace(
        paged=True, page_tokens=32, pool_pages=5, prefix_cache=False))
    eng = Engine(cfg_p, gqa_params, n_cache=N_CACHE, donate_state=False)
    rng = np.random.default_rng(7)
    sess = _sessions(rng, 3)                     # 70 + 6 -> 3 pages each
    r = eng.serve(copy.deepcopy(sess), n_slots=2, mode="continuous")
    assert r.pool.deferred_admissions >= 1
    assert all(len(s.turns[0].tokens) == 6 for s in r.requests.values())
    assert r.pool.pages_in_use == 0
