"""CachePolicy API tests: the equivalence and lifecycle contracts that make
the policy redesign safe.

* the registry exposes exactly the five paper-comparison policies;
* the ``lychee`` policy is a BIT-IDENTICAL wrapper over the pre-policy
  index machinery (build == build_index+pad_index, select == retrieve_spans,
  update == maybe_lazy_update) — the refactor cannot have changed the
  paper's numbers;
* the ``dense`` policy's incremental decode matches a full-prefix forward
  (the exactness oracle: decoding token by token equals teacher forcing);
* every policy serves a continuous-batching trace with recycled slots and
  produces per-request greedy outputs identical to the request served alone
  (the slot-splice invariant, per policy);
* ``reset``/``pad`` round-trips: resetting a slot leaves other slots'
  leaves bit-identical and the reset state is all-zero; padded build states
  carry the same static shapes as ``empty`` at cache capacity (the
  prompt-length-independence that makes slot splicing legal);
* quest/clusterkv streaming updates fold appended tokens into the state
  (pages extend; members append).
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LycheeConfig, get_config
from repro.core import build_index, chunk_sequence, pad_index
from repro.core import synthetic_delimiter_table
from repro.core.policy import (list_policies, make_policy, policy_for,
                               spans_to_tokens)
from repro.core.retrieval import retrieve_spans
from repro.core.update import maybe_lazy_update
from repro.models import model as MD
from repro.serving import Engine, make_trace

POLICY_NAMES = ("lychee", "quest", "clusterkv", "streaming", "dense")
STATEFUL = ("lychee", "quest", "clusterkv")
N_CACHE = 128


def _ly(policy="lychee", **kw):
    base = dict(policy=policy, enabled=policy != "dense", budget=64, sink=4,
                buffer_size=16, max_coarse=8, top_kg=4, full_attn_layers=0,
                quest_page=8, ckv_tokens_per_cluster=8)
    base.update(kw)
    return LycheeConfig(**base)


def _cfg(policy="lychee"):
    return get_config("granite-3-8b", reduced=True).replace(
        dtype="float32", lychee=_ly(policy))


@pytest.fixture(scope="module")
def params():
    # params are policy-independent: one init serves every engine below
    return MD.init_model(jax.random.key(0), _cfg())


def test_registry_exposes_the_five_paper_policies():
    assert set(list_policies()) == set(POLICY_NAMES)
    with pytest.raises(KeyError):
        make_policy("nope", _ly())
    # enabled=False forces dense regardless of the configured name
    assert policy_for(_ly("lychee", enabled=False)).is_dense
    assert policy_for(_ly("quest")).name == "quest"


# ---------------------------------------------------------------------------
# lychee policy == the pre-policy index machinery, bit for bit
# ---------------------------------------------------------------------------
def test_lychee_policy_is_bit_identical_wrapper():
    ly = _ly()
    rng = np.random.default_rng(0)
    H, S, d = 2, 96, 16
    keys = jnp.asarray(rng.standard_normal((H, S, d)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, 997, size=(S,)), jnp.int32)
    table = jnp.asarray(synthetic_delimiter_table(997))
    layout = chunk_sequence(tokens, table, ly)
    pol = make_policy("lychee", ly)

    ref = pad_index(build_index(keys, layout, ly), N_CACHE, ly)
    got = pol.build(keys, layout, N_CACHE)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    probe = jnp.asarray(rng.standard_normal((H, d)), jnp.float32)
    s_ref, l_ref, _ = retrieve_spans(ref, probe, ly)
    s_got, l_got = pol.select(got, probe, S)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_got))
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_got))
    assert pol.span_len == ly.max_chunk

    # update at the lazy-graft cadence (t % max_chunk == 0) and off it
    for t in (ly.max_chunk * 5, ly.max_chunk * 5 + 3):
        u_ref = maybe_lazy_update(ref, keys, t, ly)
        u_got = pol.update(got, keys, t)
        for a, b in zip(jax.tree.leaves(u_ref), jax.tree.leaves(u_got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# dense policy == full-prefix forward (incremental decode exactness)
# ---------------------------------------------------------------------------
def test_dense_policy_decode_matches_full_prefix_forward(params):
    cfg = _cfg("dense")
    rng = np.random.default_rng(1)
    S = 48
    prompt = rng.integers(0, cfg.vocab, size=(1, S)).astype(np.int32)
    logits, state = MD.prefill(params, jnp.asarray(prompt), cfg, N_CACHE)
    seq = prompt.copy()
    for _ in range(3):
        tok = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        seq = np.concatenate([seq, tok[:, None]], axis=1)
        logits, state = MD.decode_step(params, jnp.asarray(tok), state, cfg)
        # teacher-forced forward over the full prefix must agree with the
        # incremental decode step (same math, different summation order)
        ref, _ = MD.prefill(params, jnp.asarray(seq), cfg, N_CACHE)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# every policy end-to-end: recycled slots, serve == solo generate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_policy_serve_matches_request_served_alone(params, policy):
    cfg = _cfg(policy)
    engine = Engine(cfg, params, n_cache=N_CACHE, donate_state=False)
    assert engine.policy == policy
    trace = make_trace(np.random.default_rng(2), 4, cfg.vocab,
                       prompt_lens=(24, 48), gen_lens=(4, 6))
    res = engine.serve(copy.deepcopy(trace), n_slots=2, mode="continuous")
    assert len(res.requests) == 4          # slots recycled mid-stream
    for req in trace:
        alone = engine.generate(req.prompt[None], req.max_new)
        assert res.requests[req.uid].tokens == alone.tokens[0].tolist(), \
            f"policy {policy}: req {req.uid} diverged from solo serving"


# ---------------------------------------------------------------------------
# slot lifecycle: reset / pad round-trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_reset_slot_roundtrip_per_policy(params, policy):
    cfg = _cfg(policy)
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab, size=(2, 64)).astype(np.int32)
    _, state = MD.prefill(params, jnp.asarray(prompts), cfg, N_CACHE)
    cache0 = state["groups"][0]
    if policy in STATEFUL:
        assert "policy_state" in cache0
    else:
        assert "policy_state" not in cache0

    state2 = MD.reset_slot(state, 0)
    # slot 1 survives bit-identically
    for a, b in zip(jax.tree.leaves(MD.slice_slot(state, 1)),
                    jax.tree.leaves(MD.slice_slot(state2, 1))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # slot 0 is genuinely empty: zero leaves == policy.reset contract
    for leaf in jax.tree.leaves(MD.slice_slot(state2, 0)):
        assert not np.asarray(leaf).any()
    if policy in STATEFUL:
        pol = policy_for(cfg.lychee)
        st0 = jax.tree.map(lambda l: l[0, 0], cache0["policy_state"])
        ref = pol.reset(st0)
        got = jax.tree.map(lambda l: l[0, 0],
                           state2["groups"][0]["policy_state"])
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("policy", STATEFUL)
def test_build_pads_to_cache_capacity_shapes(policy):
    """States built from different prompt lengths carry IDENTICAL leaf
    shapes (== empty(n_cache)), the precondition for write_slot splicing."""
    ly = _ly(policy)
    pol = make_policy(policy, ly)
    rng = np.random.default_rng(4)
    H, d = 2, 16
    table = jnp.asarray(synthetic_delimiter_table(997))
    shapes = []
    for S in (24, 64):
        keys = jnp.asarray(rng.standard_normal((H, S, d)), jnp.float32)
        layout = None
        if pol.needs_layout:
            tokens = jnp.asarray(rng.integers(0, 997, size=(S,)), jnp.int32)
            layout = chunk_sequence(tokens, table, ly)
        st = pol.build(keys, layout, N_CACHE)
        shapes.append([tuple(l.shape) for l in jax.tree.leaves(st)])
    assert shapes[0] == shapes[1]
    empty = pol.empty(N_CACHE, H, d)
    assert shapes[0] == [tuple(l.shape) for l in jax.tree.leaves(empty)]
    # pad on an already-capacity-sized state is a no-op
    st = pol.build(keys, layout, N_CACHE)
    padded = pol.pad(st, N_CACHE)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(padded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# streaming updates do real work (quest pages extend; clusterkv appends)
# ---------------------------------------------------------------------------
def test_quest_update_extends_tail_page():
    ly = _ly("quest")
    pol = make_policy("quest", ly)
    rng = np.random.default_rng(5)
    H, S, d = 2, 40, 8
    keys = jnp.asarray(rng.standard_normal((H, N_CACHE, d)), jnp.float32)
    st = pol.build(keys[:, :S], None, N_CACHE, n_tokens=S)
    page = ly.quest_page
    p_new = S // page                       # first page past the prefill
    assert not bool(st.pvalid[0, p_new])
    st2 = pol.update(st, keys, S + 1)       # token appended at position S
    assert bool(st2.pvalid[0, p_new])
    np.testing.assert_allclose(np.asarray(st2.kmin[:, p_new]),
                               np.asarray(keys[:, S]), rtol=1e-6)
    # a second token in the same page tightens elementwise bounds
    st3 = pol.update(st2, keys, S + 2)
    lo = np.minimum(np.asarray(keys[:, S]), np.asarray(keys[:, S + 1]))
    hi = np.maximum(np.asarray(keys[:, S]), np.asarray(keys[:, S + 1]))
    np.testing.assert_allclose(np.asarray(st3.kmin[:, p_new]), lo, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(st3.kmax[:, p_new]), hi, rtol=1e-6)
    # fully-built pages are untouched
    np.testing.assert_array_equal(np.asarray(st3.kmin[:, 0]),
                                  np.asarray(st.kmin[:, 0]))


def test_clusterkv_update_appends_member_to_nearest_centroid():
    ly = _ly("clusterkv")
    pol = make_policy("clusterkv", ly)
    rng = np.random.default_rng(6)
    H, S, d = 1, 64, 8
    keys = jnp.asarray(rng.standard_normal((H, N_CACHE, d)), jnp.float32)
    st = pol.build(keys[:, :S], None, N_CACHE, n_tokens=S)
    total0 = int(np.asarray(st.nmember).sum())
    st2 = pol.update(st, keys, S + 1)
    assert int(np.asarray(st2.nmember).sum()) == total0 + 1
    # position S now appears in exactly one member list
    members = np.asarray(st2.members)
    assert (members == S).sum() == 1
    # centroids stay unit-norm after the moving-average shift
    norms = np.linalg.norm(np.asarray(st2.centroid), axis=-1)
    valid = np.asarray(st2.cvalid)
    np.testing.assert_allclose(norms[valid], 1.0, atol=1e-5)
    # updating an all-empty state is a gated no-op
    z = pol.reset(st)
    z2 = pol.update(z, keys, S + 1)
    for a, b in zip(jax.tree.leaves(z), jax.tree.leaves(z2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quest_select_clips_tail_page_at_valid_length():
    """Selected spans never cover positions >= t, even when t is not
    page-aligned — direct span->token consumers (benchmarks) rely on it."""
    ly = _ly("quest")
    pol = make_policy("quest", ly)
    rng = np.random.default_rng(7)
    H, S, d = 2, 100, 8                      # 100 % quest_page(8) != 0
    keys = jnp.asarray(rng.standard_normal((H, S, d)), jnp.float32)
    st = pol.build(keys, None, S)
    probe = jnp.asarray(rng.standard_normal((H, d)), jnp.float32)
    ti, tm = spans_to_tokens(*pol.select(st, probe, S), pol.span_len)
    sel = np.asarray(ti)[np.asarray(tm)]
    assert sel.size and sel.max() < S


def test_spans_to_tokens_expansion():
    starts = jnp.asarray([[0, 10], [4, 0]], jnp.int32)
    lens = jnp.asarray([[2, 3], [1, 0]], jnp.int32)
    tok, mask = spans_to_tokens(starts, lens, 4)
    assert tok.shape == mask.shape == (2, 8)
    got = [int(t) for t, m in zip(np.asarray(tok[0]), np.asarray(mask[0]))
           if m]
    assert got == [0, 1, 10, 11, 12]
    assert [int(t) for t, m in zip(np.asarray(tok[1]), np.asarray(mask[1]))
            if m] == [4]
