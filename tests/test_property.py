"""Property-based tests (hypothesis) on the system's core invariants.

These sweep randomized shapes/contents far beyond the fixed unit tests:

* Eqn. 2 UB soundness under arbitrary unit vectors and radii bookkeeping.
* Chunking is always a partition: lengths within [1, max_chunk], contiguous
  cover, forced-split fallback.
* k-means invariants: unit-norm centroids, assignment optimality w.r.t.
  final centroids, radius covers every member.
* Lazy-update soundness: after ANY sequence of grafts, the UB at both index
  levels still bounds every member score (the property that makes streaming
  decode safe).
* MoE dispatch: per-(row, expert) capacity respected; combine weights
  nonnegative and ≤1; dropped tokens only when over capacity.
* Per-slot vectorized sampler: top-k keeps exactly k logits live, the
  top-p mask always contains the row argmax, ``temperature <= 0`` equals
  argmax, and vectorized per-slot parameters match per-row scalar calls
  (row independence — the property that lets mixed greedy/sampled batches
  share one dispatch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import LycheeConfig
from repro.core import (build_index, chunk_sequence, spherical_kmeans,
                        synthetic_delimiter_table)
from repro.core.pooling import l2_normalize
from repro.core.update import lazy_update
from repro.serving.sampler import sample, slot_keys, top_k_mask, top_p_mask

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# Chunking partition property
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(
    n=st.integers(min_value=9, max_value=400),
    vocab=st.integers(min_value=16, max_value=300),
    min_chunk=st.integers(min_value=2, max_value=8),
    extra=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_chunking_is_partition(n, vocab, min_chunk, extra, seed):
    rng = np.random.default_rng(seed)
    cfg = LycheeConfig(min_chunk=min_chunk, max_chunk=min_chunk + extra)
    table = jnp.asarray(synthetic_delimiter_table(vocab, seed=seed % 7))
    tokens = jnp.asarray(rng.integers(0, vocab, size=(n,)), jnp.int32)
    lay = chunk_sequence(tokens, table, cfg)
    starts = np.asarray(lay.start)
    lens = np.asarray(lay.length)
    valid = np.asarray(lay.valid)
    pos = 0
    for s, ln, v in zip(starts, lens, valid):
        if not v:
            continue
        assert s == pos, "chunks must be contiguous"
        assert 1 <= ln <= cfg.max_chunk
        pos += ln
    assert pos == n, "chunks must cover the sequence exactly"
    # seg_id consistency: token i belongs to the chunk that contains it
    seg = np.asarray(lay.seg_id)
    for s, ln, i in zip(starts, lens, range(len(starts))):
        if lens[i] > 0:
            assert (seg[s:s + ln] == i).all()


# ---------------------------------------------------------------------------
# Spherical k-means invariants
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(
    m=st.integers(min_value=4, max_value=120),
    d=st.integers(min_value=2, max_value=48),
    l=st.integers(min_value=1, max_value=24),
    frac_valid=st.floats(min_value=0.3, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kmeans_invariants(m, d, l, frac_valid, seed):
    rng = np.random.default_rng(seed)
    pts = l2_normalize(jnp.asarray(rng.standard_normal((m, d)), jnp.float32))
    mask = jnp.asarray(rng.random(m) < frac_valid)
    pts = pts * mask[:, None]
    res = spherical_kmeans(pts, mask, l, iters=5)
    cent = np.asarray(res.centroid)
    # valid centroids are unit norm
    v = np.asarray(res.valid)
    if v.any():
        nrm = np.linalg.norm(cent[v], axis=-1)
        np.testing.assert_allclose(nrm, 1.0, atol=1e-3)
    # radius covers every member
    assign = np.asarray(res.assign)
    radius = np.asarray(res.radius)
    pn = np.asarray(pts)
    mk = np.asarray(mask)
    for i in range(m):
        if not mk[i]:
            continue
        a = assign[i]
        dist = np.linalg.norm(pn[i] - cent[a])
        assert dist <= radius[a] + 1e-4
    # sizes sum to the number of valid points
    assert int(np.asarray(res.size).sum()) == int(mk.sum())


# ---------------------------------------------------------------------------
# UB soundness after arbitrary lazy-update sequences
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=64, max_value=200),
    d=st.sampled_from([16, 32]),
    n_updates=st.integers(min_value=0, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ub_sound_after_lazy_updates(n, d, n_updates, seed):
    rng = np.random.default_rng(seed)
    cfg = LycheeConfig(min_chunk=8, max_chunk=16, max_coarse=8,
                       sink=0, buffer_size=0)
    H = 1
    keys = jnp.asarray(rng.standard_normal((H, n, d)), jnp.float32)
    table = jnp.asarray(synthetic_delimiter_table(53, seed=1))
    tokens = jnp.asarray(rng.integers(0, 53, size=(n,)), jnp.int32)
    layout = chunk_sequence(tokens, table, cfg)
    index = build_index(keys, layout, cfg)

    for u in range(n_updates):
        nk = l2_normalize(jnp.asarray(
            rng.standard_normal((H, d)), jnp.float32))
        index = lazy_update(index, nk, n + u * cfg.max_chunk,
                            cfg.max_chunk, cfg)

    q = np.asarray(rng.standard_normal(d), np.float32)
    qn = np.linalg.norm(q)
    ck = np.asarray(index.chunk_key[0])
    fc = np.asarray(index.fine_centroid[0])
    fr = np.asarray(index.fine_radius[0])
    fv = np.asarray(index.fine_valid[0])
    # fine-level UB bounds every member chunk score
    for l_ in range(fc.shape[0]):
        if not fv[l_]:
            continue
        ub = float(fc[l_] @ q + qn * fr[l_])
        members = np.asarray(index.fine_chunks[0, l_])
        for mbr in members[members >= 0]:
            if bool(index.chunk_valid[mbr]):
                assert float(ck[mbr] @ q) <= ub + 1e-3
    # coarse-level UB bounds every child centroid score
    cc = np.asarray(index.coarse_centroid[0])
    cr = np.asarray(index.coarse_radius[0])
    cv = np.asarray(index.coarse_valid[0])
    f2c = np.asarray(index.fine2coarse[0])
    for l_ in range(fc.shape[0]):
        if not fv[l_]:
            continue
        g = f2c[l_]
        if not cv[g]:
            continue
        ub_g = float(cc[g] @ q + qn * cr[g])
        assert float(fc[l_] @ q) <= ub_g + 1e-3


# ---------------------------------------------------------------------------
# MoE dispatch capacity property
# ---------------------------------------------------------------------------
@settings(**SETTINGS)
@given(
    s=st.integers(min_value=4, max_value=64),
    e=st.sampled_from([4, 8]),
    k=st.integers(min_value=1, max_value=2),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_moe_dispatch_capacity(s, e, k, seed):
    from repro.models.moe import _dispatch_row
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((s, e)).astype(np.float32)
    p = jax.nn.softmax(jnp.asarray(logits), -1)
    top_p, top_e = jax.lax.top_k(p, k)
    C = max(1, int(s * k / e * 1.25))
    tt, tp = _dispatch_row(top_e, top_p, e, C, s)
    tt, tp = np.asarray(tt), np.asarray(tp)
    assert tt.shape == (e, C)
    # every real slot points at a valid token; weights in [0, 1]
    real = tt < s
    assert (tp[~real] == 0).all()
    assert (tp >= 0).all() and (tp <= 1.0 + 1e-6).all()
    # no token appears twice within one expert row
    for row in range(e):
        toks = tt[row][real[row]]
        assert len(set(toks.tolist())) == len(toks)


# ---------------------------------------------------------------------------
# Per-slot vectorized sampler invariants
# ---------------------------------------------------------------------------
def _rand_logits(rng, b, v):
    """Logits with distinct values per row (ties are measure-zero but a
    shrunk hypothesis example must not manufacture them)."""
    base = rng.standard_normal((b, v)).astype(np.float32)
    jitter = rng.permuted(np.arange(b * v).reshape(b, v), axis=1)
    return jnp.asarray(base + 1e-4 * jitter, jnp.float32)


@settings(**SETTINGS)
@given(
    b=st.integers(min_value=1, max_value=6),
    v=st.integers(min_value=4, max_value=128),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sampler_topk_keeps_exactly_k(b, v, seed):
    rng = np.random.default_rng(seed)
    logits = _rand_logits(rng, b, v)
    ks = rng.integers(0, v + 1, size=(b,))          # 0 = disabled
    mask = np.asarray(top_k_mask(logits, jnp.asarray(ks, jnp.int32)))
    for r in range(b):
        expect = v if ks[r] == 0 else min(int(ks[r]), v)
        assert mask[r].sum() == expect
        # the kept set is the top-k by value
        order = np.argsort(np.asarray(logits)[r])[::-1]
        assert mask[r][order[:expect]].all()


@settings(**SETTINGS)
@given(
    b=st.integers(min_value=1, max_value=6),
    v=st.integers(min_value=4, max_value=128),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sampler_topp_mask_contains_argmax_and_covers_p(b, v, seed):
    rng = np.random.default_rng(seed)
    logits = _rand_logits(rng, b, v)
    ps = rng.uniform(0.0, 1.0, size=(b,)).astype(np.float32)
    mask = np.asarray(top_p_mask(logits, jnp.asarray(ps)))
    ln = np.asarray(logits)
    probs = np.exp(ln - ln.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    for r in range(b):
        assert mask[r][ln[r].argmax()], "nucleus must contain the argmax"
        # kept mass reaches p, and is minimal (dropping the smallest kept
        # logit would fall below p)
        kept = probs[r][mask[r]]
        assert kept.sum() >= ps[r] - 1e-5
        if mask[r].sum() > 1:
            assert kept.sum() - kept.min() < ps[r] + 1e-5


@settings(**SETTINGS)
@given(
    b=st.integers(min_value=1, max_value=6),
    v=st.integers(min_value=4, max_value=128),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sampler_zero_temperature_is_argmax(b, v, seed):
    rng = np.random.default_rng(seed)
    logits = _rand_logits(rng, b, v)
    keys = slot_keys(jax.random.key(seed % 997),
                     jnp.arange(b, dtype=jnp.int32),
                     jnp.zeros((b,), jnp.int32))
    for temp in (0.0, -1.0):
        tok = sample(keys, logits, jnp.full((b,), temp, jnp.float32),
                     jnp.asarray(rng.integers(0, v, size=(b,)), jnp.int32),
                     jnp.asarray(rng.uniform(0.1, 1.0, size=(b,)),
                                 jnp.float32))
        np.testing.assert_array_equal(
            np.asarray(tok), np.asarray(jnp.argmax(logits, -1)))


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(min_value=2, max_value=5),
    v=st.integers(min_value=8, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_sampler_vectorized_matches_per_row_scalar_calls(b, v, seed):
    """Row independence: sampling a (B, V) batch with per-slot parameter
    vectors equals B separate single-row calls with the same keys — the
    invariant that makes co-scheduled sampled requests deterministic."""
    rng = np.random.default_rng(seed)
    logits = _rand_logits(rng, b, v)
    temp = jnp.asarray(rng.uniform(0.0, 1.5, size=(b,)), jnp.float32)
    top_k = jnp.asarray(rng.integers(0, v, size=(b,)), jnp.int32)
    top_p = jnp.asarray(rng.uniform(0.2, 1.0, size=(b,)), jnp.float32)
    keys = slot_keys(jax.random.key(seed % 991),
                     jnp.arange(b, dtype=jnp.int32),
                     jnp.asarray(rng.integers(0, 100, size=(b,)), jnp.int32))
    batched = np.asarray(sample(keys, logits, temp, top_k, top_p))
    for r in range(b):
        solo = np.asarray(sample(keys[r:r + 1], logits[r:r + 1],
                                 temp[r:r + 1], top_k[r:r + 1],
                                 top_p[r:r + 1]))
        assert batched[r] == solo[0]
