"""Sharding-rule tests: param specs and decode-state specs obey the
policies in DESIGN.md §5, on a small host mesh (no 512-device init — these
run inside the normal test process)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.sharding.rules import decode_state_specs, param_specs

pytestmark = pytest.mark.skipif(
    len(jax.devices()) != 1, reason="spec construction only; any devices")


def _mesh(shape=(2, 2), axes=("data", "model")):
    # AbstractMesh: enough for spec construction, no devices needed.
    # Signature changed across jax versions: old takes a shape_tuple of
    # (name, size) pairs, new takes (shape, axis_names).
    try:
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def test_param_specs_tp_and_fsdp():
    cfg = get_config("granite-3-8b")
    mesh = _mesh()
    params = {
        "wq": jax.ShapeDtypeStruct((4096, 4096), jnp.bfloat16),
        "wo": jax.ShapeDtypeStruct((4096, 4096), jnp.bfloat16),
        "norm": {"scale": jax.ShapeDtypeStruct((4096,), jnp.bfloat16)},
    }
    specs = param_specs(params, cfg, mesh)
    assert specs["wq"] == P(None, "model")
    assert specs["wo"] == P("model", None)
    assert all(a is None for a in specs["norm"]["scale"])

    cfg_f = cfg.replace(fsdp=True)
    specs = param_specs(params, cfg_f, cfg and mesh)
    assert specs["wq"] == P("data", "model")


def test_param_specs_expert_parallel_divisibility():
    cfg = get_config("deepseek-v3-671b")      # 256 experts
    mesh = _mesh((2, 2))
    params = {"we_gate": jax.ShapeDtypeStruct((256, 64, 128), jnp.bfloat16)}
    specs = param_specs(params, cfg, mesh)
    assert specs["we_gate"][0] == "model"     # 256 % 2 == 0 -> EP

    cfg8 = get_config("mixtral-8x22b")        # 8 experts on 16-way model
    mesh16 = _mesh((2, 16))
    params8 = {"we_gate": jax.ShapeDtypeStruct((8, 64, 128), jnp.bfloat16)}
    specs = param_specs(params8, cfg8, mesh16)
    assert specs["we_gate"][0] is None        # TP-inside-expert instead


def test_decode_state_specs_batched_decode():
    mesh = _mesh((4, 2))
    state = {
        "groups": ({"k": jax.ShapeDtypeStruct((3, 8, 4, 64, 16),
                                              jnp.bfloat16),
                    "policy_state": {"chunk_key": jax.ShapeDtypeStruct(
                        (3, 8, 4, 32, 16), jnp.float32)}},),
        "t": jax.ShapeDtypeStruct((8,), jnp.int32),   # per-slot positions
    }
    specs = decode_state_specs(state, mesh, ("data",), ("model",))
    kspec = specs["groups"][0]["k"]

    def _ax(a):
        return a if isinstance(a, tuple) else (a,) if a else ()
    # (G, B, H, N, d): batch on data, ctx on model
    assert _ax(kspec[1]) == ("data",)
    assert _ax(kspec[3]) == ("model",)
    ck = specs["groups"][0]["policy_state"]["chunk_key"]
    assert _ax(ck[3]) == ("model",)           # M dim on ctx axes
    # (B,) per-slot counters ride the batch axes like the token vector
    assert _ax(specs["t"][0]) == ("data",)


def test_decode_state_specs_context_parallel():
    mesh = _mesh((4, 2))
    state = {"prelude": [{"k": jax.ShapeDtypeStruct((1, 4, 64, 16),
                                                    jnp.bfloat16)}]}
    specs = decode_state_specs(state, mesh, None, ("data", "model"))
    kspec = specs["prelude"][0]["k"]
    assert kspec[2] == ("data", "model")      # ctx over everything
    assert kspec[0] is None                   # batch=1 unsharded


def test_decode_state_specs_nondivisible_falls_back():
    mesh = _mesh((4, 2))
    state = {"prelude": [{"k": jax.ShapeDtypeStruct((1, 4, 63, 16),
                                                    jnp.bfloat16)}]}
    specs = decode_state_specs(state, mesh, None, ("data", "model"))
    assert specs["prelude"][0]["k"][2] is None    # 63 % 8 != 0 -> replicate
