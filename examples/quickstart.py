"""Quickstart: LycheeCluster on a toy cache in ~40 lines.

Builds the structure-aware chunk index over a synthetic KV cache, runs one
hierarchical retrieval + budgeted sparse attention step, grafts a dynamic
chunk, and shows the budget-sufficient case matching full attention.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LycheeConfig
from repro.core import (build_index, chunk_sequence, full_decode_attention,
                        retrieve, sparse_decode_attention,
                        synthetic_delimiter_table)
from repro.core.update import maybe_lazy_update

rng = np.random.default_rng(0)
N, H, G, d = 512, 2, 2, 64
cfg = LycheeConfig(budget=128, sink=8, buffer_size=32, max_coarse=16)

# 1. a KV cache and the token stream it came from
keys = jnp.asarray(rng.standard_normal((H, N, d)), jnp.float32)
values = jnp.asarray(rng.standard_normal((H, N, d)), jnp.float32)
tokens = jnp.asarray(rng.integers(0, 997, size=(N,)), jnp.int32)

# 2. prefill phase: structure-aware chunking + hierarchical index (Alg. 1)
layout = chunk_sequence(tokens, jnp.asarray(synthetic_delimiter_table(997)),
                        cfg)
index = build_index(keys, layout, cfg)
print(f"chunks={int(layout.count)}  fine clusters="
      f"{int(index.fine_valid.sum())//H}  coarse units="
      f"{int(index.coarse_valid.sum())//H}")

# 3. decode phase: top-down pruning (Eqn. 2) + exact sparse attention
q = jnp.asarray(rng.standard_normal((H * G, d)), jnp.float32)
probe = q.reshape(H, G, d).mean(1)
ret = retrieve(index, probe, cfg)
out = sparse_decode_attention(q, keys, values, ret.token_idx,
                              ret.token_mask, N, cfg, scale=d ** -0.5)
print("sparse attention out:", out.shape,
      f"retrieved {int(ret.token_mask.sum())//H} tokens/head "
      f"of {N} (budget {cfg.budget})")

# 4. lazy incremental update: graft a dynamic chunk after 16 new tokens
index2 = maybe_lazy_update(index, keys, (N // 16) * 16, cfg)
print("chunks after lazy update:", int(index2.chunk_count))

# 5. budget-sufficient => identical to full attention (paper App. F.1)
big = LycheeConfig(budget=10**6, top_kg=64, max_coarse=64, sink=8,
                   buffer_size=32)
index_big = build_index(keys, layout, big)
ret = retrieve(index_big, probe, big)
out_big = sparse_decode_attention(q, keys, values, ret.token_idx,
                                  ret.token_mask, N, big, scale=d ** -0.5)
full = full_decode_attention(q, keys, values, N, scale=d ** -0.5)
print("max |lychee - full| (budget ≥ context):",
      float(jnp.abs(out_big - full).max()))
