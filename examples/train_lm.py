"""End-to-end training driver: train a ~100M-parameter xLSTM-125M-family
model (or any --arch at reduced scale) on the synthetic LM stream for a few
hundred steps with the full substrate: data pipeline -> train_step (AdamW,
schedule, remat) -> checkpointing.

Run:  PYTHONPATH=src python examples/train_lm.py \
          [--arch xlstm-125m] [--steps 200] [--batch 8] [--seq 256] [--full]

``--full`` uses the published architecture shape (xlstm-125m is ~125M params
and trains on CPU in reasonable time at short seq); otherwise the reduced
config keeps the smoke-scale shape.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as MD
from repro.training import synthetic_lm_batches
from repro.training.checkpoint import save
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="experiments/train_lm_ckpt")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full).replace(
        dtype="float32")
    params = MD.init_model(jax.random.key(0), cfg)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(params))
    print(f"arch={cfg.name}  params={n_params/1e6:.1f}M  "
          f"steps={args.steps}  batch={args.batch}x{args.seq}")

    step_fn, init_state = make_train_step(
        cfg, base_lr=3e-4, total_steps=args.steps)
    opt = init_state(params)
    data = synthetic_lm_batches(cfg.vocab, args.batch, args.seq)

    rng = np.random.default_rng(0)
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(next(data))}
        if cfg.n_patches:
            batch["patches"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.n_patches, cfg.d_model)) * 0.02,
                jnp.float32)
        if cfg.is_encdec:
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.n_audio_frames, cfg.d_model)) * 0.02,
                jnp.float32)
        params, opt, metrics = step_fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if i % args.log_every == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")

    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'LEARNING' if last < first - 0.1 else 'check config'})")
    save(args.ckpt, params, step=args.steps)
    print("checkpoint saved to", args.ckpt)


if __name__ == "__main__":
    main()
