"""End-to-end serving driver (the paper's scenario): batched long-prompt
requests, LycheeCluster-managed decode vs full attention.

Serves a reduced-config model (random weights — the timing story does not
depend on weight values) with a batch of long prompts, generating with the
batched engine under (a) full attention and (b) LycheeCluster, and prints
per-token decode latency for both plus the retrieval statistics.

Run:  PYTHONPATH=src python examples/serve_longcontext.py \
          [--arch granite-3-8b] [--ctx 2048] [--gen 64] [--batch 2]

With --stream the same engine instead replays a mixed-length request trace
through the continuous-batching scheduler (admission into freed slots via
the per-slot prefill splice), printing throughput and latency percentiles:

      PYTHONPATH=src python examples/serve_longcontext.py --stream \
          [--requests 8] [--rate 1.0]

With --multiturn it runs the session API end to end: a long first turn,
then a short follow-up whose prompt delta is appended onto the slot's live
KV cache and hierarchical index (``extend_slot`` — the lazy-update
streaming path, no re-prefill), with per-turn sampling parameters and the
``on_token`` streaming callback; it then re-runs the same
session with ``reuse="reprefill"`` to show the turn-2 TTFT difference:

      PYTHONPATH=src python examples/serve_longcontext.py --multiturn \
          [--ctx 2048] [--gen 32]

With --shared-prefix it serves ``--requests`` sessions that all send the
SAME system prompt, once from contiguous per-slot caches and once from
the paged KV pool with the radix prefix cache: session 0 pays the
prefill and registers its pages; each later session is an exact prefix
hit, admitted by splicing the shared pages + cached snapshot with ZERO
forward passes (greedy output bit-identical). Prints per-session TTFT
for both engines and the pool's sharing/hit-rate counters:

      PYTHONPATH=src python examples/serve_longcontext.py --shared-prefix \
          [--ctx 1024] [--gen 16] [--requests 4]
"""
import argparse

import jax
import numpy as np

from repro.configs.base import LycheeConfig, get_config
from repro.models import model as MD
from repro.serving import (Engine, SamplerParams, Session, Turn, make_trace)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--ctx", type=int, default=2048)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--stream", action="store_true")
    ap.add_argument("--multiturn", action="store_true",
                    help="two-turn session demo: extend_slot KV/index "
                         "reuse vs re-prefill, streaming, stop sequences")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="N identical-prompt sessions through the paged "
                         "KV pool + prefix cache vs contiguous slots")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s); 0 = offline")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    lychee = LycheeConfig(budget=256, sink=16, buffer_size=64,
                          max_coarse=32, top_kg=8, full_attn_layers=0)
    cfg = get_config(args.arch, reduced=True).replace(
        dtype="float32", lychee=lychee)
    params = MD.init_model(jax.random.key(0), cfg)
    n_cache = args.ctx + (cfg.n_patches or 0) + args.gen + 32

    if args.shared_prefix:
        # --- paged prefix sharing in one screen ------------------------
        # Every session sends the same system prompt. Contiguous slots
        # re-prefill it each time; the paged pool serves later sessions
        # from the radix prefix cache: shared pages + a spliced snapshot
        # + the stored admission logits — zero forwards, greedy output
        # bit-identical to the cold admission.
        import copy
        prefix = rng.integers(0, cfg.vocab,
                              size=(args.ctx,)).astype(np.int32)
        sessions = [Session(uid=i, turns=[Turn(prompt=prefix.copy(),
                                               max_new=args.gen)])
                    for i in range(args.requests)]
        pc = (-(-(args.ctx + args.gen) // 128) + 1) * 128  # paged n_cache
        cfg_p = cfg.replace(serving=cfg.serving.replace(paged=True))
        results = {}
        for name, c in (("contiguous", cfg), ("paged+prefix", cfg_p)):
            engine = Engine(c, params, n_cache=pc)
            engine.serve(copy.deepcopy(sessions), n_slots=1)  # warm jits
            results[name] = engine.serve(copy.deepcopy(sessions), n_slots=1)
        for name, r in results.items():
            ttfts = " ".join(
                f"{1e3 * r.requests[i].turns[0].ttft_s:7.1f}"
                for i in range(args.requests))
            print(f"[{name:13s}] per-session TTFT ms: {ttfts}")
        same = all(results["contiguous"].requests[i].turns[0].tokens
                   == results["paged+prefix"].requests[i].turns[0].tokens
                   for i in range(args.requests))
        st = results["paged+prefix"].pool
        print(f"greedy outputs identical across engines: {same}")
        print(f"prefix cache: {st.prefix_hits}/{st.prefix_lookups} exact "
              f"hits (rate {st.prefix_hit_rate:.2f})   "
              f"peak sharing saved {st.peak_bytes_saved / 1024:.0f} KiB "
              f"of {st.bytes_per_page * st.n_pages / 1024:.0f} KiB pool")
        return

    if args.multiturn:
        # --- the session API in one screen -----------------------------
        # Turn 1: a long context processed once (greedy). Turn 2: a short
        # follow-up delta — only these tokens are prefilled; the history's
        # KV rows and the hierarchical index are REUSED (lychee grafts the
        # generated tokens in as dynamic chunks via lazy_update). Each turn
        # carries its own SamplerParams; on_token streams tokens as they
        # are sampled. (Turns also take stop=((tok, ...),) sequences that
        # end a turn early — see tests/test_session.py.)
        session = Session(uid=0, turns=[
            Turn(prompt=rng.integers(0, cfg.vocab, size=(args.ctx,))
                 .astype(np.int32), max_new=args.gen),
            Turn(prompt=rng.integers(0, cfg.vocab, size=(args.ctx // 16,))
                 .astype(np.int32), max_new=args.gen,
                 sampling=SamplerParams(temperature=0.8, top_k=50)),
        ])
        engine = Engine(cfg, params,
                        n_cache=session.total_len() + 64)
        import copy
        for reuse in ("extend", "reprefill"):    # warm BOTH jit paths
            engine.serve(copy.deepcopy([session]), n_slots=1, reuse=reuse)
        streamed = {}
        res = {}
        for reuse in ("extend", "reprefill"):
            streamed[reuse] = []
            res[reuse] = engine.serve(
                copy.deepcopy([session]), n_slots=1, reuse=reuse,
                on_token=lambda uid, tok, out=streamed[reuse]:
                out.append(tok))
        for reuse, r in res.items():
            t2 = r.requests[0].turns[1]
            print(f"[{reuse:9s}] turn-2 TTFT {1e3 * t2.ttft_s:7.1f}ms   "
                  f"tokens {t2.tokens[:8]} ...")
        sp = (res["reprefill"].requests[0].turns[1].ttft_s
              / res["extend"].requests[0].turns[1].ttft_s)
        print(f"turn-2 TTFT speedup (extend vs re-prefill): {sp:.2f}x "
              f"at history={args.ctx}+{args.gen}")
        print(f"streamed {len(streamed['extend'])} tokens via on_token "
              f"(extend run)")
        return

    if args.stream:
        trace = make_trace(rng, args.requests, cfg.vocab,
                           prompt_lens=(args.ctx // 4, args.ctx),
                           gen_lens=(args.gen // 2, args.gen),
                           rate_rps=args.rate)
        engine = Engine(cfg, params, n_cache=n_cache)
        res = engine.serve(trace, n_slots=args.batch, mode="continuous",
                           verbose=True)
        print(f"[stream] {res.total_new_tokens} tokens in {res.wall_s:.2f}s"
              f" = {res.tokens_per_s:.1f} tok/s   "
              f"p50 {res.p50_latency_s:.2f}s  p99 {res.p99_latency_s:.2f}s")
        return

    prompts = rng.integers(0, cfg.vocab,
                           size=(args.batch, args.ctx)).astype(np.int32)

    extras = {}
    if cfg.n_patches:
        extras["patches"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model))
            .astype(np.float32) * 0.02)
    if cfg.is_encdec:
        extras["frames"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, cfg.n_audio_frames,
                                 cfg.d_model)).astype(np.float32) * 0.02)

    results = {}
    for name, c in [("lychee", cfg),
                    ("full", cfg.replace(lychee=LycheeConfig(enabled=False)))]:
        engine = Engine(c, params, n_cache=n_cache)
        res = engine.generate(prompts, args.gen,
                              SamplerParams(temperature=0.8, top_k=50),
                              extras=extras)
        results[name] = res
        print(f"[{name:6s}] prefill {res.prefill_s:.2f}s   "
              f"decode {res.decode_s:.2f}s   TPOT {res.tpot_ms:.1f}ms")
    sp = results["full"].tpot_ms / results["lychee"].tpot_ms
    print(f"decode speedup (lychee vs full): {sp:.2f}x at ctx={args.ctx} "
          f"budget={lychee.budget}")
    print("sample generation (lychee):",
          results["lychee"].tokens[0, :16].tolist())
    if sp < 1.0:
        print("note: on CPU the retrieval overhead crosses over around "
              "ctx≈8k (see `python -m benchmarks.run --only tpot`: 5.3x at "
              "8k, 14x at 16k for the attention op); at small ctx full "
              "attention is cheap enough to win. TPU-target magnitudes "
              "come from the §Roofline dry-run pipeline.")


if __name__ == "__main__":
    main()
